"""Property-based tests (hypothesis) on the TBN core invariants and the
Pallas kernels (interpret mode).

hypothesis is a dev-only dependency (requirements-dev.txt / the ``dev``
extra); the whole module is skipped when it is not installed so the tier-1
command still passes from a clean checkout.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.packing import pack_bits, packed_len, storage_bytes, unpack_bits
from repro.core.tiling import (
    compute_alpha,
    construct_binary,
    expand_alpha,
    export_tile,
    fold_inputs_reference,
    plan_tiling,
    reconstruct_from_tile,
    tiled_matmul_reference,
    tiled_weight,
)

SETTINGS = dict(max_examples=40, deadline=None)


# strategy: (n_out, n_in, p) with p | n_out (aligned) and N >= 1
aligned_shapes = st.tuples(
    st.sampled_from([2, 4, 8]),                 # p
    st.integers(1, 6),                          # rows per tile
    st.integers(1, 24),                         # n_in
).map(lambda t: (t[0] * t[1], t[2], t[0]))

unaligned_shapes = st.tuples(
    st.integers(2, 7),                          # n_out
    st.integers(2, 12),                         # n_in
    st.sampled_from([2, 3, 4, 6]),              # p
).filter(lambda t: (t[0] * t[1]) % t[2] == 0)


def mk_spec(n_out, n_in, p, alpha_mode="tile", alpha_source="W"):
    return plan_tiling(
        (n_out, n_in), p=p, min_size=0,
        alpha_mode=alpha_mode, alpha_source=alpha_source,
    )


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestTilingInvariants:
    @given(aligned_shapes, st.integers(0, 100))
    @settings(**SETTINGS)
    def test_plan_arithmetic(self, dims, _):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        assert spec.p * spec.q == n_out * n_in
        assert spec.aligned_rows
        assert spec.stored_bits == spec.q + 32 * spec.n_alpha

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_reconstruction_equals_training_weight(self, dims, seed):
        """reconstruct(export(W)) == tiled training weight — the shipped
        representation is exactly what training optimized (any p | N)."""
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        w = rand(seed, (n_out, n_in))
        bhat = tiled_weight(w, spec)
        t, alpha = export_tile(w, spec)
        rec = reconstruct_from_tile(t, alpha, spec)
        np.testing.assert_allclose(np.asarray(bhat), np.asarray(rec), rtol=1e-6)

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_tile_replication_structure(self, dims, seed):
        """Every tile replica in B is identical (the paper's core claim)."""
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        w = rand(seed, (n_out, n_in))
        b = construct_binary(w, spec).reshape(spec.p, spec.q)
        for i in range(1, spec.p):
            np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(b[i]))

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_binary_values_pm1(self, dims, seed):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        b = np.asarray(construct_binary(rand(seed, (n_out, n_in)), spec))
        assert set(np.unique(b)).issubset({-1.0, 1.0})

    @given(aligned_shapes, st.integers(0, 10_000),
           st.sampled_from(["layer", "tile"]))
    @settings(**SETTINGS)
    def test_tiled_matmul_reference_matches_dense(self, dims, seed, amode):
        """Tile-reuse matmul (p-fold fewer FLOPs) == dense B_hat matmul."""
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode=amode)
        w = rand(seed, (n_out, n_in))
        x = rand(seed + 1, (3, n_in))
        t, alpha = export_tile(w, spec)
        y_fast = tiled_matmul_reference(x, t, alpha, spec)
        y_ref = x @ np.asarray(tiled_weight(w, spec)).T
        np.testing.assert_allclose(
            np.asarray(y_fast), y_ref, rtol=2e-5, atol=2e-5
        )

    @given(aligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_fold_inputs_reference_matches_dense(self, dims, seed):
        """Input-folding variant: y = x @ W_hat for (n_in, n_out) layout."""
        n_in, n_out, p = dims          # leading dim is the contraction here
        spec = mk_spec(n_in, n_out, p)
        w = rand(seed, (n_in, n_out))
        x = rand(seed + 1, (3, n_in))
        t, alpha = export_tile(w, spec)
        y_fast = fold_inputs_reference(x, t, alpha, spec)
        y_ref = x @ np.asarray(tiled_weight(w, spec))
        np.testing.assert_allclose(
            np.asarray(y_fast), y_ref, rtol=3e-5, atol=3e-5
        )

    @given(st.integers(1, 40))
    @settings(**SETTINGS)
    def test_lambda_policy_threshold(self, n):
        spec = plan_tiling((n, 10), p=2, min_size=200)
        if n * 10 < 200:
            assert spec is None
        elif (n * 10) % 2 == 0:
            assert spec is not None


class TestAlphaInvariants:
    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_alpha_layer_is_mean_abs(self, dims, seed):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode="layer")
        w = rand(seed, (n_out, n_in))
        alpha = compute_alpha(w, spec)
        np.testing.assert_allclose(
            float(alpha[0]), float(jnp.mean(jnp.abs(w))), rtol=1e-6
        )

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_tile_alphas_average_to_layer_alpha(self, dims, seed):
        """mean over per-tile alphas == the single layer alpha (Eq.7/Eq.9)."""
        n_out, n_in, p = dims
        w = rand(seed, (n_out, n_in))
        a_tile = compute_alpha(w, mk_spec(n_out, n_in, p, alpha_mode="tile"))
        a_layer = compute_alpha(w, mk_spec(n_out, n_in, p, alpha_mode="layer"))
        np.testing.assert_allclose(
            float(jnp.mean(a_tile)), float(a_layer[0]), rtol=1e-5
        )

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_expand_alpha_constant_within_tile(self, dims, seed):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode="tile")
        alpha = jnp.abs(rand(seed, (spec.p,))) + 0.1
        e = np.asarray(expand_alpha(alpha, spec)).reshape(spec.p, spec.q)
        for i in range(spec.p):
            assert np.all(e[i] == e[i, 0])


class TestSTE:
    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_identity_ste_passes_gradient_through(self, dims, seed):
        """Paper Eq. 6: dL/dW == dL/dB elementwise for the identity STE
        (alpha from the separate tensor A so the product rule is isolated)."""
        n_out, n_in, p = dims
        spec = plan_tiling((n_out, n_in), p=p, min_size=0,
                           alpha_mode="layer", alpha_source="A")
        w = rand(seed, (n_out, n_in))
        a = rand(seed + 7, (n_out, n_in))

        def f(w):
            alpha = jax.lax.stop_gradient(compute_alpha(a, spec))
            g = jnp.arange(1.0, 1.0 + w.size).reshape(w.shape)
            return jnp.sum(construct_binary(w, spec) * expand_alpha(alpha, spec) * g)

        grad = jax.grad(f)(w)
        alpha = float(compute_alpha(a, spec)[0])
        expected = alpha * np.arange(1.0, 1.0 + w.size).reshape(w.shape)
        np.testing.assert_allclose(np.asarray(grad), expected, rtol=1e-5)


class TestPacking:
    @given(st.integers(1, 400), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_pack_unpack_roundtrip(self, q, seed):
        t = jnp.sign(rand(seed, (q,)))
        t = jnp.where(t == 0, 1.0, t)
        packed = pack_bits(t)
        assert packed.shape == (packed_len(q),)
        got = unpack_bits(packed, q)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(got))

    @given(st.integers(1, 4000), st.integers(1, 8))
    @settings(**SETTINGS)
    def test_storage_bytes_exact(self, q, n_alpha):
        assert storage_bytes(q, n_alpha) == packed_len(q) * 4 + 4 * n_alpha

    @given(st.integers(2, 200), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_batched_packing(self, q, seed):
        t = jnp.sign(rand(seed, (3, q)))
        t = jnp.where(t == 0, 1.0, t)
        got = unpack_bits(pack_bits(t), q)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(got))


class TestConvPacking:
    @given(
        st.integers(1, 8),                      # r
        st.integers(1, 40),                     # c_in
        st.sampled_from([(1, 1), (3, 3), (5, 3)]),
        st.integers(0, 10_000),
    )
    @settings(**SETTINGS)
    def test_conv_layout_roundtrip(self, r, c_in, kernel, seed):
        """pack_conv_tile/unpack_conv_tile invert each other for any filter
        count / channel count / kernel shape (word padding included)."""
        from repro.core.packing import pack_conv_tile, unpack_conv_tile

        kh, kw = kernel
        q = r * c_in * kh * kw
        t = jnp.sign(rand(seed, (q,)))
        t = jnp.where(t == 0, 1.0, t)
        packed = pack_conv_tile(t, r, c_in, kh, kw)
        assert packed.shape == (kh * kw, r, packed_len(c_in))
        bank = unpack_conv_tile(packed, r, c_in, kh, kw)
        np.testing.assert_array_equal(
            np.asarray(bank), np.asarray(t.reshape(r, c_in, kh, kw))
        )

    @given(
        st.sampled_from([2, 3, 4]),             # p
        st.integers(1, 4),                      # r
        st.integers(1, 12),                     # c_in
        st.integers(0, 10_000),
    )
    @settings(**SETTINGS)
    def test_conv_layout_bits_equal_flat_bits(self, p, r, c_in, seed):
        """The conv layout is a pure relayout of the flat shipped tile: the
        same q bits, no information added or lost."""
        from repro.core.packing import pack_conv_tile, unpack_conv_tile
        from repro.core.tiling import export_tile

        kh = kw = 3
        spec = plan_tiling((p * r, c_in, kh, kw), p=p, min_size=0,
                           alpha_mode="tile", alpha_source="W")
        w = rand(seed, (p * r, c_in, kh, kw))
        t, _ = export_tile(w, spec)
        packed = pack_conv_tile(t, r, c_in, kh, kw)
        bank = unpack_conv_tile(packed, r, c_in, kh, kw)
        np.testing.assert_array_equal(
            np.asarray(bank.reshape(-1)), np.asarray(t)
        )


class TestSubBitAccounting:
    @given(st.sampled_from([2, 4, 8, 16]), st.integers(6, 12))
    @settings(**SETTINGS)
    def test_bits_per_param_below_one(self, p, log2n):
        """The headline claim: stored bits/param < 1 (sub-bit) once the
        layer clears the alpha overhead."""
        n_out = 2 ** log2n
        n_in = 2 ** log2n
        spec = plan_tiling((n_out, n_in), p=p, min_size=0, alpha_mode="tile")
        if spec.q >= 32 * spec.n_alpha:   # alpha overhead amortized
            assert spec.bits_per_param < 1.0
            assert spec.bits_per_param >= 1.0 / p


class TestKernelProperties:
    """Property tests on the Pallas kernels (moved from test_kernels.py so
    that module stays hypothesis-free)."""

    @staticmethod
    def _rand_tile_packed(key, r, k):
        t = jnp.where(jax.random.bernoulli(key, 0.5, (r * k,)), 1.0, -1.0)
        return pack_bits(t).reshape(r, k // 32), t

    @settings(max_examples=25, deadline=None)
    @given(
        r=st.sampled_from([8, 16, 32]),
        k=st.sampled_from([32, 64, 128]),
        m=st.integers(1, 16),
        seed=st.integers(0, 2**16),
    )
    def test_property_kernel_linear_in_x(self, r, k, m, seed):
        """Kernel output is linear in x: f(a*x1 + x2) == a*f(x1) + f(x2)."""
        from repro.kernels import tiled_matmul_unique

        key = jax.random.PRNGKey(seed)
        k1, k2, kt = jax.random.split(key, 3)
        x1 = jax.random.normal(k1, (m, k))
        x2 = jax.random.normal(k2, (m, k))
        packed, _ = self._rand_tile_packed(kt, r, k)
        f = lambda x: tiled_matmul_unique(
            x, packed, r=r, block_m=max(8, m), block_r=8, block_k=32,
            interpret=True,
        )
        mpad = (-m) % max(8, m)
        x1p, x2p = (jnp.pad(v, ((0, mpad), (0, 0))) for v in (x1, x2))
        lhs = f(2.5 * x1p + x2p)
        rhs = 2.5 * f(x1p) + f(x2p)
        np.testing.assert_allclose(
            np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=25, deadline=None)
    @given(
        p=st.sampled_from([2, 4, 8]),
        q=st.sampled_from([32, 96, 256]),
        seed=st.integers(0, 2**16),
    )
    def test_property_construct_sign_invariance(self, p, q, seed):
        """Scaling W by a positive constant never changes the tile bits and
        scales alpha linearly (invariant of Eqs. 2-3, 7-9)."""
        from repro.kernels import tile_construct_pallas

        w = jax.random.normal(jax.random.PRNGKey(seed), (p, q))
        pk1, a1 = tile_construct_pallas(w, interpret=True)
        pk2, a2 = tile_construct_pallas(3.0 * w, interpret=True)
        np.testing.assert_array_equal(np.asarray(pk1), np.asarray(pk2))
        np.testing.assert_allclose(
            np.asarray(a2), 3.0 * np.asarray(a1), rtol=1e-5
        )

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 16]),
        r=st.sampled_from([8, 16]),
        p=st.sampled_from([2, 4]),
        seed=st.integers(0, 2**16),
    )
    def test_property_replicate_scale_blocks(self, m, r, p, seed):
        """Every output block i equals alpha_i/alpha_j times block j."""
        from repro.kernels.ref import replicate_scale_ref

        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        u = jax.random.normal(k1, (m, r))
        alpha = jnp.abs(jax.random.normal(k2, (p,))) + 0.5
        y = np.asarray(replicate_scale_ref(u, alpha, p)).reshape(m, p, r)
        a = np.asarray(alpha)
        for i in range(1, p):
            np.testing.assert_allclose(
                y[:, i], y[:, 0] * (a[i] / a[0]), rtol=1e-5
            )


class TestSamplingRowEquivalence:
    """The batch sampler must be row-for-row the scalar sampler: row i of
    ``sample_logits_batch(logits, keys, temps, ks)`` equals
    ``sample_logits(logits[i:i+1], keys[i], temperature=temps[i],
    top_k=ks[i])`` — over greedy (t=0) rows and the top-k edge cases
    k in {0, V-1, V, V+1} (0 = off, >= V = no restriction)."""

    V = 9

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        rows=st.lists(
            st.tuples(
                st.sampled_from([0.0, 0.3, 1.0, 2.5]),      # temperature
                st.sampled_from([0, 1, 3, V - 1, V, V + 1]),  # top_k
            ),
            min_size=1, max_size=5,
        ),
    )
    def test_batch_rowwise_equals_scalar(self, seed, rows):
        import jax.random as jrandom

        from repro.serve.sampling import sample_logits, sample_logits_batch

        b, v = len(rows), self.V
        logits = jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 3.0
        temps = jnp.array([t for t, _ in rows], jnp.float32)
        ks = jnp.array([k for _, k in rows], jnp.int32)
        keys = jnp.stack([
            jrandom.fold_in(jrandom.PRNGKey(seed + 1), i) for i in range(b)
        ])
        got = np.asarray(sample_logits_batch(
            logits, keys, temperature=temps, top_k=ks))
        for i, (t, k) in enumerate(rows):
            want = sample_logits(
                logits[i:i + 1], keys[i], temperature=t, top_k=k)
            assert int(got[i]) == int(want[0]), (i, t, k, got, want)
            assert 0 <= int(got[i]) < v
            if t > 0 and 0 < k < v:
                topk_ids = np.argsort(-np.asarray(logits[i]))[:k]
                assert int(got[i]) in topk_ids


class TestKVPoolInvariants:
    """Fuzz the page pool's refcount machinery against a shadow model:
    refcounts never go negative, double-frees are impossible, and the
    free list + referenced pages always partition the pool exactly."""

    @settings(max_examples=60, deadline=None)
    @given(
        n_pages=st.integers(1, 12),
        ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 10_000)),
                     max_size=60),
    )
    def test_pool_refcount_invariants(self, n_pages, ops):
        from repro.serve.kvpool import KVPool

        pool = KVPool(n_pages, page_tokens=4)
        live = {}                                  # pid -> expected refcount
        for op, pick in ops:
            if op == 0:                            # alloc
                pid = pool.alloc()
                if live and len(live) == n_pages:
                    assert pid is None
                else:
                    assert pid is not None and pid not in live
                    live[pid] = 1
            elif op == 1 and live:                 # retain a live page
                pid = sorted(live)[pick % len(live)]
                pool.retain(pid)
                live[pid] += 1
            elif op == 2 and live:                 # release a live page
                pid = sorted(live)[pick % len(live)]
                pool.release(pid)
                live[pid] -= 1
                if live[pid] == 0:
                    del live[pid]
            pool.check()
            assert pool.used_pages == len(live)
        for pid, rc in list(live.items()):
            for _ in range(rc):
                pool.release(pid)
        pool.check()
        assert pool.free_pages == pool.n_pages
        # operating on a dead page must fail loudly, not corrupt state
        if n_pages:
            with pytest.raises(ValueError):
                pool.release(0)
            pool.check()


class TestPrefixTrieRoundTrip:
    """Insert/match/evict round-trips on random token sequences: a match
    returns exactly the longest inserted page run (capped one token short
    of the prompt), the trie's page pins account for every used page, and
    evicting everything returns the pool to fully free."""

    seqs = st.lists(
        st.lists(st.integers(0, 5), min_size=1, max_size=14),
        min_size=1, max_size=5,
    )

    @settings(max_examples=50, deadline=None)
    @given(seqs=seqs, pt=st.sampled_from([2, 3, 4]))
    def test_insert_match_roundtrip(self, seqs, pt):
        from repro.serve.kvpool import KVPool
        from repro.serve.prefix import PrefixTrie

        pool = KVPool(64, pt)
        trie = PrefixTrie(pt, pool=pool, max_nodes=64)
        for i, seq in enumerate(seqs):
            n_pub = len(seq) // pt
            pages = [pool.alloc() for _ in range(n_pub)]
            trie.insert(seq[: n_pub * pt], pages, {}, now=i)
            for p in pages:                        # the "slot" retires
                pool.release(p)
            pool.check()
        assert pool.used_pages == len(trie.held_pages())
        for seq in seqs:
            path = trie.match(seq)
            # the sequence's own insert pinned len(seq)//pt pages; the
            # match is additionally capped at (len(seq)-1)//pt so at
            # least one token always remains to prefill
            assert len(path) == (len(seq) - 1) // pt
            got = [t for n in path for t in n.key]
            assert got == [int(t) for t in seq[: len(path) * pt]]
        trie.clear()
        pool.check()
        assert pool.free_pages == pool.n_pages and len(trie) == 0

    @settings(max_examples=40, deadline=None)
    @given(seq=st.lists(st.integers(0, 5), min_size=4, max_size=16),
           snap_at=st.integers(0, 4), pt=st.sampled_from([2, 4]))
    def test_snapshot_gated_match_depth(self, seq, snap_at, pt):
        """require_snapshot answers with the deepest node that HAS one —
        snapshotless deeper nodes must not be matched (a recurrent model
        could not restore state there)."""
        from repro.serve.prefix import PrefixTrie

        trie = PrefixTrie(pt, pool=None, max_nodes=64)
        n_pub = len(seq) // pt
        snaps = {(snap_at + 1) * pt: object()} if snap_at < n_pub else {}
        trie.insert(seq[: n_pub * pt], None, snaps, now=0)
        path = trie.match(seq, require_snapshot=True)
        n_match_cap = (len(seq) - 1) // pt
        want = (snap_at + 1
                if (snap_at < n_pub and snap_at + 1 <= n_match_cap) else 0)
        assert len(path) == want
        assert len(trie.match(seq)) == n_match_cap  # pages-only unchanged

    @settings(max_examples=30, deadline=None)
    @given(seqs=seqs, pt=st.sampled_from([2, 4]), cap=st.integers(1, 4))
    def test_eviction_is_leaf_only_and_bounded(self, seqs, pt, cap):
        """The node cap holds through arbitrary inserts, and eviction
        never orphans a child (leaves die first)."""
        from repro.serve.kvpool import KVPool
        from repro.serve.prefix import PrefixTrie

        pool = KVPool(64, pt)
        trie = PrefixTrie(pt, pool=pool, max_nodes=cap)
        for i, seq in enumerate(seqs):
            n_pub = len(seq) // pt
            pages = [pool.alloc() for _ in range(n_pub)]
            trie.insert(seq[: n_pub * pt], pages, {}, now=i)
            for p in pages:
                pool.release(p)
            assert len(trie) <= cap
            for n in trie._nodes:                  # no orphans
                assert n.parent is trie.root or n.parent in trie._nodes
            pool.check()
        trie.clear()
        assert pool.free_pages == pool.n_pages


@functools.lru_cache(maxsize=1)
def _leak_test_engine_build():
    from repro.configs import build_model, get_config
    from repro.nn import module as mod
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.serve.weights import export_serving_params

    cfg = get_config("granite-8b").reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    return cfg, sm, sp


class TestEnginePageLeaks:
    """End-to-end pool accounting: after ``run_until_drained`` on random
    workloads the only page references left are the trie's pins."""

    @settings(max_examples=5, deadline=None)
    @given(
        prompts=st.lists(
            st.lists(st.integers(0, 20), min_size=1, max_size=20),
            min_size=1, max_size=3,
        ),
        prefix_cache=st.booleans(),
    )
    def test_no_leaked_pages_after_run_until_drained(self, prompts,
                                                     prefix_cache):
        from repro.serve.engine import BatchedEngine, ServeConfig
        from repro.serve.sampling import SamplingParams

        cfg, sm, sp = _leak_test_engine_build()
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=32, chunk_tokens=8, page_tokens=4,
            prefix_cache=prefix_cache))
        for p in prompts:
            eng.submit(p, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        eng.pool.check()
        held = len(eng.trie.held_pages()) if eng.trie is not None else 0
        assert eng.pool.used_pages == held
        if eng.trie is not None:
            eng.trie.clear()
            eng.pool.check()
            assert eng.pool.used_pages == 0


class TestSchedulerFuzz:
    """Random priority/preempt/resume/abort schedules against the live
    engine, cross-checked invariant-by-invariant: the resume queue never
    references a live slot (or a finished request), the pool's refcount
    partition survives every op, and a full drain leaves no parked
    entries, no leaked pages, and no dangling snapshot refs. Every
    submitted request finishing inside the bounded drain IS the
    no-starvation check — the batch class cannot be starved by the
    interactive flood the schedule throws at it."""

    ops_strategy = st.lists(
        st.tuples(st.integers(0, 3),            # submit/step/preempt/abort
                  st.integers(0, 10_000), st.integers(0, 10_000)),
        min_size=1, max_size=30)

    @settings(max_examples=8, deadline=None)
    @given(ops=ops_strategy, prefix_cache=st.booleans(),
           preempt=st.booleans())
    def test_scheduler_invariants_under_fuzz(self, ops, prefix_cache,
                                             preempt):
        from repro.serve.engine import BatchedEngine, ServeConfig
        from repro.serve.sampling import SamplingParams

        cfg, sm, sp = _leak_test_engine_build()
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=32, chunk_tokens=8, page_tokens=4,
            prefix_cache=prefix_cache, priorities=True, preempt=preempt,
            starvation_limit=2, max_preempts=2))
        classes = ("interactive", "batch")
        inflight = []

        def check():
            live = {id(r) for r in eng._live.values()}
            parked = [p.req for p in eng._parked]
            assert live.isdisjoint(id(r) for r in parked), \
                "resume queue holds a live slot"
            assert all(not r.done for r in parked), \
                "resume queue holds a finished request"
            assert len({p.req.rid for p in eng._parked}) == len(parked)
            eng.pool.check()

        for kind, a, b in ops:
            if kind == 0:
                prompt = [(a * 7 + i) % 23 for i in range(a % 14 + 1)]
                inflight.append(eng.submit(
                    np.asarray(prompt, np.int32),
                    SamplingParams(max_tokens=b % 4 + 1,
                                   priority=classes[a % 2])))
            elif kind == 1:
                eng.step()
            elif kind == 2 and eng._live:
                assert eng.preempt_slot(sorted(eng._live)[a % len(eng._live)])
            elif kind == 3 and inflight:
                eng.abort(inflight[a % len(inflight)])  # False if done: fine
            check()
        ticks = 0
        while eng.has_work:
            assert ticks < 500, "drain wedged: starvation or lost request"
            eng.step()
            check()
            ticks += 1
        assert not eng._parked
        assert all(r.done for r in inflight)
        held = len(eng.trie.held_pages()) if eng.trie is not None else 0
        assert eng.pool.used_pages == held
        if eng.trie is not None:
            eng.trie.clear()
            eng.pool.check()
            assert eng.pool.used_pages == 0


class TestRowsConstruction:
    @given(aligned_shapes, st.integers(0, 10_000),
           st.sampled_from(["layer", "tile"]),
           st.sampled_from(["W", "A"]))
    @settings(**SETTINGS)
    def test_rows_equals_flat(self, dims, seed, amode, asrc):
        """Axis-sum construction (tiled_weight_rows) is bit-identical to
        the paper's flat (p, q) construction for row-aligned specs."""
        from repro.core.tiling import tiled_weight_rows

        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode=amode, alpha_source=asrc)
        w = rand(seed, (n_out, n_in))
        a = rand(seed + 3, (n_out, n_in)) if asrc == "A" else None
        ref = tiled_weight(w, spec, a=a)
        got = tiled_weight_rows(w, spec, a=a)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=1e-6, atol=1e-7)

    @given(aligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_rows_batched_matches_vmap(self, dims, seed):
        from repro.core.tiling import tiled_weight_rows

        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_source="W")
        w = rand(seed, (3, n_out, n_in))
        got = tiled_weight_rows(w, spec)
        ref = jax.vmap(lambda we: tiled_weight(we, spec))(w)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=1e-6, atol=1e-7)

    @given(aligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_rows_identity_ste_gradient(self, dims, seed):
        from repro.core.tiling import tiled_weight_rows

        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode="layer", alpha_source="A")
        w = rand(seed, (n_out, n_in))
        a = rand(seed + 3, (n_out, n_in))
        g_ref = jax.grad(lambda w: jnp.sum(
            tiled_weight(w, spec, a=jax.lax.stop_gradient(a))))(w)
        g_got = jax.grad(lambda w: jnp.sum(
            tiled_weight_rows(w, spec, a=jax.lax.stop_gradient(a))))(w)
        np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_got),
                                   rtol=1e-5, atol=1e-6)

"""Property-based tests (hypothesis) on the TBN core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.packing import pack_bits, packed_len, storage_bytes, unpack_bits
from repro.core.tiling import (
    TileSpec,
    compute_alpha,
    construct_binary,
    expand_alpha,
    export_tile,
    fold_inputs_reference,
    plan_tiling,
    reconstruct_from_tile,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)

SETTINGS = dict(max_examples=40, deadline=None)


# strategy: (n_out, n_in, p) with p | n_out (aligned) and N >= 1
aligned_shapes = st.tuples(
    st.sampled_from([2, 4, 8]),                 # p
    st.integers(1, 6),                          # rows per tile
    st.integers(1, 24),                         # n_in
).map(lambda t: (t[0] * t[1], t[2], t[0]))

unaligned_shapes = st.tuples(
    st.integers(2, 7),                          # n_out
    st.integers(2, 12),                         # n_in
    st.sampled_from([2, 3, 4, 6]),              # p
).filter(lambda t: (t[0] * t[1]) % t[2] == 0)


def mk_spec(n_out, n_in, p, alpha_mode="tile", alpha_source="W"):
    return plan_tiling(
        (n_out, n_in), p=p, min_size=0,
        alpha_mode=alpha_mode, alpha_source=alpha_source,
    )


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestTilingInvariants:
    @given(aligned_shapes, st.integers(0, 100))
    @settings(**SETTINGS)
    def test_plan_arithmetic(self, dims, _):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        assert spec.p * spec.q == n_out * n_in
        assert spec.aligned_rows
        assert spec.stored_bits == spec.q + 32 * spec.n_alpha

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_reconstruction_equals_training_weight(self, dims, seed):
        """reconstruct(export(W)) == tiled training weight — the shipped
        representation is exactly what training optimized (any p | N)."""
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        w = rand(seed, (n_out, n_in))
        bhat = tiled_weight(w, spec)
        t, alpha = export_tile(w, spec)
        rec = reconstruct_from_tile(t, alpha, spec)
        np.testing.assert_allclose(np.asarray(bhat), np.asarray(rec), rtol=1e-6)

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_tile_replication_structure(self, dims, seed):
        """Every tile replica in B is identical (the paper's core claim)."""
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        w = rand(seed, (n_out, n_in))
        b = construct_binary(w, spec).reshape(spec.p, spec.q)
        for i in range(1, spec.p):
            np.testing.assert_array_equal(np.asarray(b[0]), np.asarray(b[i]))

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_binary_values_pm1(self, dims, seed):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p)
        b = np.asarray(construct_binary(rand(seed, (n_out, n_in)), spec))
        assert set(np.unique(b)).issubset({-1.0, 1.0})

    @given(aligned_shapes, st.integers(0, 10_000),
           st.sampled_from(["layer", "tile"]))
    @settings(**SETTINGS)
    def test_tiled_matmul_reference_matches_dense(self, dims, seed, amode):
        """Tile-reuse matmul (p-fold fewer FLOPs) == dense B_hat matmul."""
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode=amode)
        w = rand(seed, (n_out, n_in))
        x = rand(seed + 1, (3, n_in))
        t, alpha = export_tile(w, spec)
        y_fast = tiled_matmul_reference(x, t, alpha, spec)
        y_ref = x @ np.asarray(tiled_weight(w, spec)).T
        np.testing.assert_allclose(
            np.asarray(y_fast), y_ref, rtol=2e-5, atol=2e-5
        )

    @given(aligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_fold_inputs_reference_matches_dense(self, dims, seed):
        """Input-folding variant: y = x @ W_hat for (n_in, n_out) layout."""
        n_in, n_out, p = dims          # leading dim is the contraction here
        spec = mk_spec(n_in, n_out, p)
        w = rand(seed, (n_in, n_out))
        x = rand(seed + 1, (3, n_in))
        t, alpha = export_tile(w, spec)
        y_fast = fold_inputs_reference(x, t, alpha, spec)
        y_ref = x @ np.asarray(tiled_weight(w, spec))
        np.testing.assert_allclose(
            np.asarray(y_fast), y_ref, rtol=3e-5, atol=3e-5
        )

    @given(st.integers(1, 40))
    @settings(**SETTINGS)
    def test_lambda_policy_threshold(self, n):
        spec = plan_tiling((n, 10), p=2, min_size=200)
        if n * 10 < 200:
            assert spec is None
        elif (n * 10) % 2 == 0:
            assert spec is not None


class TestAlphaInvariants:
    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_alpha_layer_is_mean_abs(self, dims, seed):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode="layer")
        w = rand(seed, (n_out, n_in))
        alpha = compute_alpha(w, spec)
        np.testing.assert_allclose(
            float(alpha[0]), float(jnp.mean(jnp.abs(w))), rtol=1e-6
        )

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_tile_alphas_average_to_layer_alpha(self, dims, seed):
        """mean over per-tile alphas == the single layer alpha (Eq.7/Eq.9)."""
        n_out, n_in, p = dims
        w = rand(seed, (n_out, n_in))
        a_tile = compute_alpha(w, mk_spec(n_out, n_in, p, alpha_mode="tile"))
        a_layer = compute_alpha(w, mk_spec(n_out, n_in, p, alpha_mode="layer"))
        np.testing.assert_allclose(
            float(jnp.mean(a_tile)), float(a_layer[0]), rtol=1e-5
        )

    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_expand_alpha_constant_within_tile(self, dims, seed):
        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode="tile")
        alpha = jnp.abs(rand(seed, (spec.p,))) + 0.1
        e = np.asarray(expand_alpha(alpha, spec)).reshape(spec.p, spec.q)
        for i in range(spec.p):
            assert np.all(e[i] == e[i, 0])


class TestSTE:
    @given(unaligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_identity_ste_passes_gradient_through(self, dims, seed):
        """Paper Eq. 6: dL/dW == dL/dB elementwise for the identity STE
        (alpha from the separate tensor A so the product rule is isolated)."""
        n_out, n_in, p = dims
        spec = plan_tiling((n_out, n_in), p=p, min_size=0,
                           alpha_mode="layer", alpha_source="A")
        w = rand(seed, (n_out, n_in))
        a = rand(seed + 7, (n_out, n_in))

        def f(w):
            alpha = jax.lax.stop_gradient(compute_alpha(a, spec))
            g = jnp.arange(1.0, 1.0 + w.size).reshape(w.shape)
            return jnp.sum(construct_binary(w, spec) * expand_alpha(alpha, spec) * g)

        grad = jax.grad(f)(w)
        alpha = float(compute_alpha(a, spec)[0])
        expected = alpha * np.arange(1.0, 1.0 + w.size).reshape(w.shape)
        np.testing.assert_allclose(np.asarray(grad), expected, rtol=1e-5)


class TestPacking:
    @given(st.integers(1, 400), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_pack_unpack_roundtrip(self, q, seed):
        t = jnp.sign(rand(seed, (q,)))
        t = jnp.where(t == 0, 1.0, t)
        packed = pack_bits(t)
        assert packed.shape == (packed_len(q),)
        got = unpack_bits(packed, q)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(got))

    @given(st.integers(1, 4000), st.integers(1, 8))
    @settings(**SETTINGS)
    def test_storage_bytes_exact(self, q, n_alpha):
        assert storage_bytes(q, n_alpha) == packed_len(q) * 4 + 4 * n_alpha

    @given(st.integers(2, 200), st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_batched_packing(self, q, seed):
        t = jnp.sign(rand(seed, (3, q)))
        t = jnp.where(t == 0, 1.0, t)
        got = unpack_bits(pack_bits(t), q)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(got))


class TestSubBitAccounting:
    @given(st.sampled_from([2, 4, 8, 16]), st.integers(6, 12))
    @settings(**SETTINGS)
    def test_bits_per_param_below_one(self, p, log2n):
        """The headline claim: stored bits/param < 1 (sub-bit) once the
        layer clears the alpha overhead."""
        n_out = 2 ** log2n
        n_in = 2 ** log2n
        spec = plan_tiling((n_out, n_in), p=p, min_size=0, alpha_mode="tile")
        if spec.q >= 32 * spec.n_alpha:   # alpha overhead amortized
            assert spec.bits_per_param < 1.0
            assert spec.bits_per_param >= 1.0 / p


class TestRowsConstruction:
    @given(aligned_shapes, st.integers(0, 10_000),
           st.sampled_from(["layer", "tile"]),
           st.sampled_from(["W", "A"]))
    @settings(**SETTINGS)
    def test_rows_equals_flat(self, dims, seed, amode, asrc):
        """Axis-sum construction (tiled_weight_rows) is bit-identical to
        the paper's flat (p, q) construction for row-aligned specs."""
        from repro.core.tiling import tiled_weight_rows

        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode=amode, alpha_source=asrc)
        w = rand(seed, (n_out, n_in))
        a = rand(seed + 3, (n_out, n_in)) if asrc == "A" else None
        ref = tiled_weight(w, spec, a=a)
        got = tiled_weight_rows(w, spec, a=a)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=1e-6, atol=1e-7)

    @given(aligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_rows_batched_matches_vmap(self, dims, seed):
        from repro.core.tiling import tiled_weight_rows

        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_source="W")
        w = rand(seed, (3, n_out, n_in))
        got = tiled_weight_rows(w, spec)
        ref = jax.vmap(lambda we: tiled_weight(we, spec))(w)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                   rtol=1e-6, atol=1e-7)

    @given(aligned_shapes, st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_rows_identity_ste_gradient(self, dims, seed):
        from repro.core.tiling import tiled_weight_rows

        n_out, n_in, p = dims
        spec = mk_spec(n_out, n_in, p, alpha_mode="layer", alpha_source="A")
        w = rand(seed, (n_out, n_in))
        a = rand(seed + 3, (n_out, n_in))
        g_ref = jax.grad(lambda w: jnp.sum(
            tiled_weight(w, spec, a=jax.lax.stop_gradient(a))))(w)
        g_got = jax.grad(lambda w: jnp.sum(
            tiled_weight_rows(w, spec, a=jax.lax.stop_gradient(a))))(w)
        np.testing.assert_allclose(np.asarray(g_ref), np.asarray(g_got),
                                   rtol=1e-5, atol=1e-6)

"""Pallas kernel validation: interpret-mode vs pure-jnp oracles.

Per the deliverable: sweep shapes/dtypes and assert_allclose against the
ref.py oracle for every kernel. The hypothesis property tests on the same
kernels live in tests/test_property.py (skipped when hypothesis is absent,
so this module always runs from a clean checkout).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_bits, plan_tiling, unpack_bits
from repro.kernels import (
    tbn_dense_train,
    tile_construct,
    tile_construct_pallas,
    tiled_dense_infer,
    tiled_matmul_unique,
)
from repro.kernels.ref import (
    tile_construct_ref,
    tiled_matmul_ref,
    tiled_matmul_unique_ref,
    tiled_matvec_unique_ref,
)


def _rand_tile_packed(key, r, k):
    t = jnp.where(jax.random.bernoulli(key, 0.5, (r * k,)), 1.0, -1.0)
    return pack_bits(t).reshape(r, k // 32), t


# --------------------------------------------------------------------------
# tiled_matmul kernel
# --------------------------------------------------------------------------
SHAPES = [
    # (M, K, r) — pre-padded to block multiples (ops.py pads otherwise)
    (8, 32, 8),
    (128, 128, 128),
    (128, 512, 128),
    (256, 256, 64),
    (64, 1024, 256),
]


@pytest.mark.parametrize("m,k,r", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_matmul_unique_matches_ref(m, k, r, dtype):
    kx, kt = jax.random.split(jax.random.PRNGKey(m * 7 + k + r))
    x = jax.random.normal(kx, (m, k), dtype)
    packed, t = _rand_tile_packed(kt, r, k)
    bm, br, bk = min(128, m), min(128, r), min(512, k)
    # make blocks divide
    while m % bm:
        bm //= 2
    while r % br:
        br //= 2
    while k % bk or bk % 32:
        bk //= 2
    got = tiled_matmul_unique(
        x, packed, r=r, block_m=bm, block_r=br, block_k=bk, interpret=True
    )
    want = tiled_matmul_unique_ref(x.astype(jnp.float32), packed.reshape(-1), r=r)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=1e-2)


@pytest.mark.parametrize("p", [2, 4, 8])
@pytest.mark.parametrize("alpha_mode", ["layer", "tile"])
def test_tiled_dense_infer_matches_dense_reconstruction(p, alpha_mode):
    n_out, n_in, m = 64 * p, 96, 16
    spec = plan_tiling(
        (n_out, n_in), p=p, min_size=1, alpha_mode=alpha_mode, alpha_source="W"
    )
    key = jax.random.PRNGKey(p)
    kx, kt, ka = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m, n_in))
    t = jnp.where(jax.random.bernoulli(kt, 0.5, (spec.q,)), 1.0, -1.0)
    packed = pack_bits(t)
    alpha = jax.random.uniform(ka, (spec.n_alpha,)) + 0.1
    want = tiled_matmul_ref(x, packed, alpha, n_out=n_out, p=p)
    # pure-JAX structured path (dry-run path)
    got_jnp = tiled_dense_infer(x, packed, alpha, spec, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want), rtol=1e-4, atol=1e-4)
    # pallas interpret path (padding exercised: n_in=96 < block_k)
    got_pl = tiled_dense_infer(x, packed, alpha, spec, use_pallas=True)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_tiled_dense_infer_batched_leading_dims():
    spec = plan_tiling((128, 64), p=4, min_size=1, alpha_source="W")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 64))
    t = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), 0.5, (spec.q,)), 1.0, -1.0)
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (4,))) + 0.1
    y = tiled_dense_infer(x, pack_bits(t), alpha, spec, use_pallas=True)
    assert y.shape == (2, 3, 128)
    y2 = tiled_dense_infer(x, pack_bits(t), alpha, spec, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# decode matvec kernel (small-m fast path)
# --------------------------------------------------------------------------
MATVEC_SHAPES = [
    # (n_in, r) — word-padded rows, non-dividing r/k exercised via ops pads
    (96, 24),
    (512, 128),
    (1504, 300),
]


@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("n_in,r", MATVEC_SHAPES)
def test_decode_matvec_matches_ref(m, n_in, r):
    """ops._dense_unique_local routes m <= MATVEC_MAX_M to the decode
    matvec kernel; its result must match the row-packed oracle."""
    from repro.kernels import MATVEC_MAX_M
    from repro.kernels.ops import _dense_unique_local

    assert m <= MATVEC_MAX_M
    kx, kt = jax.random.split(jax.random.PRNGKey(m * 13 + n_in + r))
    x = jax.random.normal(kx, (m, n_in))
    t = jnp.where(jax.random.bernoulli(kt, 0.5, (r, n_in)), 1.0, -1.0)
    packed = pack_bits(t)                       # (r, ceil(n_in/32))
    want = tiled_matvec_unique_ref(x, packed, n_in=n_in)
    got = _dense_unique_local(
        x, packed, n_in=n_in, use_pallas=True,
        block_m=128, block_r=128, block_k=512,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("m", [1, 3, 8])
def test_decode_matvec_kernel_direct(m):
    """Direct kernel call at pre-padded shapes (no ops padding)."""
    from repro.kernels import tiled_matvec_unique
    from repro.kernels.tiled_matvec import sublane_rounded

    n_in, r = 256, 64
    kx, kt = jax.random.split(jax.random.PRNGKey(m))
    mp = sublane_rounded(m, jnp.float32)
    x = jax.random.normal(kx, (mp, n_in))
    t = jnp.where(jax.random.bernoulli(kt, 0.5, (r, n_in)), 1.0, -1.0)
    packed = pack_bits(t)
    got = tiled_matvec_unique(x, packed, r=r, block_r=64, block_k=256,
                              interpret=True)
    want = tiled_matvec_unique_ref(x, packed, n_in=n_in)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_decode_dispatch_matches_matmul_blocking():
    """tiled_dense_infer at decode m equals the same call forced through
    the reference math — the dispatch changes blocking, not results."""
    spec = plan_tiling((256, 64), p=4, min_size=1, alpha_source="W")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    t = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), 0.5,
                                       (spec.rows_per_tile, 64)), 1.0, -1.0)
    rows = pack_bits(t)                          # row-packed serve form
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (4,))) + 0.1
    got = tiled_dense_infer(x, rows, alpha, spec, use_pallas=True)
    want = tiled_dense_infer(x, rows, alpha, spec, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# tile_construct kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("p,q", [(2, 64), (4, 128), (8, 4096), (4, 8192), (3, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tile_construct_pallas_matches_ref(p, q, dtype):
    w = jax.random.normal(jax.random.PRNGKey(p * q), (p, q), dtype)
    bq = min(1024, q)
    got_packed, got_alpha = tile_construct_pallas(w, block_q=bq, interpret=True)
    want_packed, want_alpha = tile_construct_ref(w.astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(got_packed), np.asarray(want_packed))
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got_alpha), np.asarray(want_alpha), rtol=rtol)


@pytest.mark.parametrize("alpha_source", ["W", "A"])
@pytest.mark.parametrize("alpha_mode", ["layer", "tile"])
def test_tile_construct_wrapper_matches_core(alpha_source, alpha_mode):
    from repro.core import compute_alpha, tile_vector

    spec = plan_tiling(
        (40, 50), p=4, min_size=1, alpha_mode=alpha_mode, alpha_source=alpha_source
    )  # q = 500: not a multiple of 32 -> exercises padding
    kw, ka = jax.random.split(jax.random.PRNGKey(3))
    w = jax.random.normal(kw, (40, 50))
    a = jax.random.normal(ka, (40, 50))
    for use_pallas in (False, True):
        packed, alpha = tile_construct(w, spec, a=a, use_pallas=use_pallas)
        t = unpack_bits(packed, spec.q)
        np.testing.assert_array_equal(np.asarray(t), np.asarray(tile_vector(w, spec)))
        src = a if alpha_source == "A" else w
        np.testing.assert_allclose(
            np.asarray(alpha), np.asarray(compute_alpha(src, spec)), rtol=1e-5
        )


def test_construct_with_separate_alpha_source():
    w = jax.random.normal(jax.random.PRNGKey(4), (4, 256))
    a = jax.random.normal(jax.random.PRNGKey(5), (4, 256))
    _, alpha_w = tile_construct_pallas(w, interpret=True)
    _, alpha_a = tile_construct_pallas(w, a, interpret=True)
    np.testing.assert_allclose(
        np.asarray(alpha_a), np.abs(np.asarray(a)).mean(1), rtol=1e-5
    )
    assert not np.allclose(np.asarray(alpha_w), np.asarray(alpha_a))


# --------------------------------------------------------------------------
# fused training forward
# --------------------------------------------------------------------------
@pytest.mark.parametrize("alpha_source", ["W", "A"])
def test_tbn_dense_train_forward_and_grad_match_reference(alpha_source):
    from repro.core import tiled_weight

    spec = plan_tiling(
        (64, 48), p=4, min_size=1, alpha_mode="tile", alpha_source=alpha_source
    )
    kx, kw, ka = jax.random.split(jax.random.PRNGKey(6), 3)
    x = jax.random.normal(kx, (10, 48))
    w = jax.random.normal(kw, (64, 48))
    a = jax.random.normal(ka, (64, 48)) if alpha_source == "A" else w

    def ref(x, w, a):
        bhat = tiled_weight(w, spec, a=(a if alpha_source == "A" else None))
        return jnp.einsum("mk,ok->mo", x, bhat)

    y_ref = ref(x, w, a)
    y_fused = tbn_dense_train(x, w, a, spec)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref), rtol=1e-4, atol=1e-4)

    gref = jax.grad(lambda w, a: (ref(x, w, a) ** 2).sum(), argnums=(0, 1))(w, a)
    gfused = jax.grad(
        lambda w, a: (tbn_dense_train(x, w, a, spec) ** 2).sum(), argnums=(0, 1)
    )(w, a)
    for g1, g2 in zip(gref, gfused):
        np.testing.assert_allclose(np.asarray(g2), np.asarray(g1), rtol=1e-3, atol=1e-4)



"""Fault tolerance: checkpoint roundtrip, retention, async save, recovery
with injected failures, watchdog/straggler detection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataPipeline
from repro.ft.checkpoint import (
    CheckpointManager,
    available_steps,
    latest_step,
    restore_checkpoint,
    restore_into,
    save_checkpoint,
)
from repro.ft.recovery import RecoveryManager
from repro.ft.watchdog import HeartbeatTable, StepWatchdog


def tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (4, 3)), "b": jnp.zeros((3,))},
        "opt": {"mu": jnp.ones((4, 3)), "step": jnp.int32(7)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = tiny_state()
        save_checkpoint(tmp_path, 42, state)
        step, restored = restore_into(state, tmp_path)
        assert step == 42
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), b),
            state, restored,
        )

    def test_latest_and_retention(self, tmp_path):
        mgr = CheckpointManager(
            tmp_path, save_every=1, max_to_keep=2, async_save=False
        )
        state = tiny_state()
        for s in (1, 2, 3, 4):
            mgr.save(s, state)
        assert latest_step(tmp_path) == 4
        assert available_steps(tmp_path) == [3, 4]

    def test_async_save_visible_after_wait(self, tmp_path):
        mgr = CheckpointManager(tmp_path, save_every=1, async_save=True)
        mgr.save(5, tiny_state())
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_restore_detects_shape_mismatch(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"w": jnp.zeros((2, 2))})
        with pytest.raises(KeyError):
            restore_into({"w2": jnp.zeros((2, 2))}, tmp_path)

    def test_metadata_roundtrip(self, tmp_path):
        save_checkpoint(tmp_path, 9, tiny_state(), metadata={"lr": 0.1})
        _, _, meta = restore_checkpoint(tmp_path)
        assert meta == {"lr": 0.1}

    def test_atomic_no_partial_dirs(self, tmp_path):
        save_checkpoint(tmp_path, 3, tiny_state())
        leftovers = [p for p in tmp_path.iterdir() if p.name.startswith(".tmp")]
        assert leftovers == []


class TestRecovery:
    def _setup(self, tmp_path, fail_at=None, save_every=2):
        from repro.optim import constant, sgd_momentum

        opt = sgd_momentum(constant(0.1), momentum=0.0)

        def make_state():
            from repro.train.step import init_state

            params = {"w": jnp.ones((3,))}
            return init_state(params, opt)

        def gen(step):
            return {"x": jnp.full((3,), float(step))}

        def make_data(start):
            return DataPipeline(gen, start_step=start, prefetch=1)

        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            if fail_at is not None and calls["n"] == fail_at:
                raise RuntimeError("injected node failure")
            new_params = jax.tree.map(
                lambda p, g: p - 0.1 * g, state.params, {"w": batch["x"]}
            )
            return state._replace(
                params=new_params, step=state.step + 1
            ), {"loss": jnp.sum(batch["x"])}

        ckpt = CheckpointManager(
            tmp_path, save_every=save_every, max_to_keep=3, async_save=False
        )
        rm = RecoveryManager(
            ckpt, make_state=make_state, make_data=make_data, max_restarts=2
        )
        return rm, step_fn, calls

    def test_runs_to_completion(self, tmp_path):
        rm, step_fn, _ = self._setup(tmp_path)
        final = rm.run(step_fn, 5)
        assert int(final.step) == 5
        assert rm.restarts == 0

    def test_restart_after_injected_failure(self, tmp_path):
        rm, step_fn, calls = self._setup(tmp_path, fail_at=4)
        final = rm.run(step_fn, 6)
        assert rm.restarts == 1
        assert int(final.step) == 6

    def test_deterministic_replay(self, tmp_path):
        # run with failure == run without failure (same data stream replay)
        rm1, f1, _ = self._setup(tmp_path / "a", fail_at=4)
        out1 = rm1.run(f1, 6)
        rm2, f2, _ = self._setup(tmp_path / "b")
        out2 = rm2.run(f2, 6)
        np.testing.assert_allclose(
            np.asarray(out1.params["w"]), np.asarray(out2.params["w"]),
            rtol=1e-6,
        )

    def test_gives_up_after_max_restarts(self, tmp_path):
        rm, step_fn, calls = self._setup(tmp_path)

        def always_fail(state, batch):
            raise RuntimeError("dead host")

        with pytest.raises(RuntimeError):
            rm.run(always_fail, 3)
        assert rm.restarts == 3  # max_restarts=2 -> third raise propagates


class TestWatchdog:
    def test_flags_straggler_step(self):
        t = {"now": 0.0}
        wd = StepWatchdog(window=8, threshold=2.0, clock=lambda: t["now"])
        for _ in range(4):
            wd.start_step(); t["now"] += 1.0
            _, slow = wd.end_step()
            assert not slow
        wd.start_step(); t["now"] += 5.0
        _, slow = wd.end_step()
        assert slow
        assert len(wd.straggler_steps) == 1

    def test_hang_detection(self):
        t = {"now": 0.0}
        wd = StepWatchdog(hang_timeout_s=10.0, clock=lambda: t["now"])
        wd.start_step()
        t["now"] += 5.0
        assert wd.check() is None
        t["now"] += 20.0
        assert wd.check() == pytest.approx(25.0)

    def test_heartbeat_eviction(self):
        t = {"now": 0.0}
        hb = HeartbeatTable(timeout_s=30.0, clock=lambda: t["now"])
        hb.beat("host0"); hb.beat("host1")
        t["now"] = 20.0
        hb.beat("host0")
        t["now"] = 45.0
        assert hb.stragglers() == ["host1"]
        hb.evict("host1")
        assert hb.hosts == ["host0"]


class TestElasticRestore:
    def test_cross_shape_placement(self, tmp_path):
        """Checkpoint written once restores onto a different 'mesh'
        (single device here; placement API exercises the device_put path)."""
        from repro.ft.checkpoint import place

        state = tiny_state()
        save_checkpoint(tmp_path, 10, state)
        step, host = restore_into(state, tmp_path)
        dev = jax.devices()[0]
        sharding = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(dev), state
        )
        placed = place(host, sharding)
        assert all(
            leaf.devices() == {dev}
            for leaf in jax.tree_util.tree_leaves(placed)
        )

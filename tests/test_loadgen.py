"""Loadgen determinism + statistical smoke (benchmarks/loadgen.py).

The load benchmark's credibility rests on the trace being (a) exactly
reproducible from its seed and (b) actually Poisson at the requested
rate — a generator that silently produced uniform gaps would understate
tail latency (no bursts), and one that drifted per-host would make the
AOT on/off comparison incomparable.
"""
import dataclasses

import numpy as np
import pytest

from benchmarks.loadgen import (
    LoadSpec,
    TimedRequest,
    generate,
    summarize,
    summarize_by_class,
)


class TestDeterminism:
    def test_same_seed_identical_schedule(self):
        spec = LoadSpec(qps=20.0, n_requests=64, seed=7,
                        shared_prefix_ratio=0.5, shared_prefix_len=6,
                        n_prefix_groups=3)
        a, b = generate(spec), generate(spec)
        assert a == b  # frozen dataclasses: full field-wise equality
        # and a fresh spec object with the same fields is the same trace
        assert generate(dataclasses.replace(spec)) == a

    def test_different_seed_different_schedule(self):
        a = generate(LoadSpec(seed=0, n_requests=16))
        b = generate(LoadSpec(seed=1, n_requests=16))
        assert [r.prompt for r in a] != [r.prompt for r in b]
        assert [r.at_s for r in a] != [r.at_s for r in b]

    def test_per_request_seeds_unique_and_stable(self):
        reqs = generate(LoadSpec(seed=3, n_requests=32))
        seeds = [r.seed for r in reqs]
        assert len(set(seeds)) == len(seeds)
        assert seeds == [r.seed for r in generate(LoadSpec(seed=3,
                                                           n_requests=32))]


class TestPoissonShape:
    def test_interarrival_rate_and_cv(self):
        """n=2000 gaps: mean within 10% of 1/qps, CV ~ 1 (exponential)."""
        qps = 50.0
        reqs = generate(LoadSpec(qps=qps, n_requests=2000, seed=0))
        at = np.array([r.at_s for r in reqs])
        gaps = np.diff(np.concatenate([[0.0], at]))
        assert gaps.min() > 0
        mean = gaps.mean()
        assert abs(mean - 1.0 / qps) < 0.10 / qps, mean
        cv = gaps.std() / mean
        assert 0.9 < cv < 1.1, cv  # exponential => CV = 1

    def test_arrivals_monotone(self):
        at = [r.at_s for r in generate(LoadSpec(qps=5.0, n_requests=100))]
        assert at == sorted(at)


class TestMixes:
    def test_lengths_drawn_from_mixes(self):
        spec = LoadSpec(n_requests=200, seed=1,
                        prompt_mix=((4, 1.0), (9, 1.0)),
                        output_mix=((3, 1.0), (7, 1.0)))
        reqs = generate(spec)
        assert {len(r.prompt) for r in reqs} == {4, 9}
        assert {r.max_tokens for r in reqs} == {3, 7}

    def test_shared_prefix_population(self):
        spec = LoadSpec(n_requests=400, seed=2, shared_prefix_ratio=0.5,
                        shared_prefix_len=8, n_prefix_groups=2)
        reqs = generate(spec)
        grouped = [r for r in reqs if r.prefix_group is not None]
        # binomial(400, .5): +-5 sigma band
        assert 150 < len(grouped) < 250, len(grouped)
        # every grouped request actually starts with its group's prefix,
        # and the two groups have distinct prefixes
        prefixes = {}
        for r in grouped:
            prefixes.setdefault(r.prefix_group, r.prompt[:8])
            assert r.prompt[:8] == prefixes[r.prefix_group]
        assert len(set(prefixes.values())) == 2

    def test_all_shared_when_ratio_one(self):
        reqs = generate(LoadSpec(n_requests=32, shared_prefix_ratio=1.0,
                                 shared_prefix_len=4))
        assert all(r.prefix_group is not None for r in reqs)

    def test_vocab_bound(self):
        reqs = generate(LoadSpec(n_requests=64, vocab=17, seed=5,
                                 shared_prefix_ratio=0.5,
                                 shared_prefix_len=4))
        for r in reqs:
            assert all(0 <= t < 17 for t in r.prompt)


class TestPriorityMix:
    MIX = (("interactive", 0.25), ("batch", 0.75))

    def test_priority_mix_deterministic(self):
        spec = LoadSpec(n_requests=64, seed=9, priority_mix=self.MIX)
        assert ([r.priority for r in generate(spec)]
                == [r.priority for r in generate(spec)])

    def test_class_proportions_track_weights(self):
        spec = LoadSpec(n_requests=800, seed=4, priority_mix=self.MIX)
        reqs = generate(spec)
        n_int = sum(1 for r in reqs if r.priority == "interactive")
        assert all(r.priority in ("interactive", "batch") for r in reqs)
        # binomial(800, .25): mean 200, sigma ~ 12.2 -> +-5 sigma band
        assert 139 < n_int < 261, n_int

    def test_class_draw_does_not_perturb_traffic(self):
        """Classes come from a dedicated rng stream: adding a priority_mix
        to an otherwise-equal spec leaves arrivals, prompts, lengths and
        per-request seeds byte-identical — so FIFO vs priority benchmark
        variants replay the SAME traffic, classes aside."""
        base = LoadSpec(n_requests=48, seed=11, shared_prefix_ratio=0.5,
                        shared_prefix_len=6)
        mixed = dataclasses.replace(base, priority_mix=self.MIX)
        for a, b in zip(generate(base), generate(mixed)):
            assert (a.at_s, a.prompt, a.max_tokens, a.seed, a.prefix_group) \
                == (b.at_s, b.prompt, b.max_tokens, b.seed, b.prefix_group)
            assert a.priority is None and b.priority is not None

    def test_payload_priority_field(self):
        spec = LoadSpec(n_requests=4, seed=0, priority_mix=(("batch", 1.0),))
        for req in generate(spec):
            assert req.payload(spec)["priority"] == "batch"
        plain = LoadSpec(n_requests=1)
        assert "priority" not in generate(plain)[0].payload(plain)

    def test_priority_mix_validation(self):
        with pytest.raises(ValueError):
            LoadSpec(priority_mix=())
        with pytest.raises(ValueError):
            LoadSpec(priority_mix=(("interactive", 0.0),))

    def test_summarize_by_class_partitions(self):
        results = [
            dict(index=0, status=200, priority="interactive", tokens=[1],
                 ttft_s=0.010, itls_s=[], end_s=0.5),
            dict(index=1, status=200, priority="batch", tokens=[2, 3],
                 ttft_s=0.200, itls_s=[0.01], end_s=1.0),
            dict(index=2, status=429, priority="batch", tokens=[],
                 ttft_s=None, itls_s=[], end_s=0.1),
            dict(index=3, status=200, tokens=[4],    # no class -> default
                 ttft_s=0.050, itls_s=[], end_s=0.2),
        ]
        by = summarize_by_class(results)
        assert set(by) == {"interactive", "batch", "default"}
        assert by["interactive"]["completed"] == 1
        assert by["batch"]["requests"] == 2 and by["batch"]["rejected"] == 1
        assert by["interactive"]["ttft_p50_ms"] < by["batch"]["ttft_p50_ms"]


class TestPayloadAndSpec:
    def test_payload_fields(self):
        spec = LoadSpec(n_requests=1, temperature=1.0, top_k=8)
        (req,) = generate(spec)
        body = req.payload(spec)
        assert body["prompt"] == list(req.prompt)
        assert body["max_tokens"] == req.max_tokens
        assert body["temperature"] == 1.0
        assert body["top_k"] == 8
        assert body["seed"] == req.seed
        # greedy spec omits top_k
        g = LoadSpec(n_requests=1)
        assert "top_k" not in generate(g)[0].payload(g)

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            LoadSpec(qps=0.0)
        with pytest.raises(ValueError):
            LoadSpec(shared_prefix_ratio=1.5)
        with pytest.raises(ValueError):
            LoadSpec(shared_prefix_ratio=0.5, shared_prefix_len=0)


class TestSummarize:
    def test_percentiles_and_rate(self):
        results = [
            dict(index=0, status=200, tokens=[1, 2, 3], ttft_s=0.010,
                 itls_s=[0.002, 0.004], end_s=0.5),
            dict(index=1, status=200, tokens=[4, 5], ttft_s=0.030,
                 itls_s=[0.006], end_s=1.0),
            dict(index=2, status=429, tokens=[], ttft_s=None,
                 itls_s=[], end_s=0.1),
        ]
        s = summarize(results)
        assert s["requests"] == 3 and s["completed"] == 2
        assert s["rejected"] == 1
        assert s["tokens"] == 5
        assert s["ttft_p50_ms"] == pytest.approx(20.0)
        assert s["itl_p50_ms"] == pytest.approx(4.0)
        assert s["sustained_tok_s"] == pytest.approx(5.0)
        # p99 keys exist (CI asserts on the bench JSON having them)
        assert "ttft_p99_ms" in s and "itl_p99_ms" in s

    def test_empty(self):
        s = summarize([])
        assert s["completed"] == 0 and s["ttft_p99_ms"] is None

    def test_timed_request_frozen(self):
        (req,) = generate(LoadSpec(n_requests=1))
        assert isinstance(req, TimedRequest)
        with pytest.raises(dataclasses.FrozenInstanceError):
            req.at_s = 0.0

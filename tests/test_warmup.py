"""AOT warmup wall: a warmed engine's first real tick traces NOTHING.

The probe is ``repro.serve.engine.TRACE_COUNTS`` — a module counter
bumped inside the Python bodies of the jitted tick functions. Those
bodies only run at trace time, so a stable counter across a full
submit+drain is a direct zero-new-compiles proof, independent of any
JAX cache internals.

Models here are built FRESH (no cross-module lru_cache): warmup must be
the first thing that ever traces these callables, otherwise the test
would pass vacuously off another test's warm jit cache.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import build_model, get_config
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import TRACE_COUNTS, BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.weights import export_serving_params

PROMPTS = [[3, 9, 4, 11, 7, 2, 5], [1, 2], [8, 8, 8, 8, 8, 8, 8, 8, 8, 8]]


def fresh_engine(arch, **cfg_kw):
    cfg = get_config(arch).reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    kw = dict(n_slots=2, max_len=32, chunk_tokens=8, page_tokens=8)
    kw.update(cfg_kw)
    return sm, sp, BatchedEngine(sm, sp, ServeConfig(**kw))


@pytest.fixture(scope="module")
def warmed():
    """One fresh granite engine, warmed once; (engine, timings)."""
    sm, sp, eng = fresh_engine("granite-8b")
    timings = eng.warmup()
    return sm, sp, eng, timings


class TestWarmup:
    def test_timings_cover_entry_points(self, warmed):
        _, _, eng, timings = warmed
        assert {"decode_tick", "extend_tick", "reset_slot"} <= set(timings)
        assert all(t > 0 for t in timings.values())
        assert eng.aot_warm

    def test_zero_new_traces_after_warmup(self, warmed):
        _, _, eng, _ = warmed
        before = dict(TRACE_COUNTS)
        reqs = [eng.submit(p, SamplingParams(max_tokens=4)) for p in PROMPTS]
        eng.run_until_drained()
        assert dict(TRACE_COUNTS) == before, (
            "warmed engine traced during serving: "
            f"{ {k: TRACE_COUNTS[k] - before.get(k, 0) for k in TRACE_COUNTS if TRACE_COUNTS[k] != before.get(k, 0)} }")
        assert all(len(r.output) == 4 for r in reqs)
        assert eng.stats()["aot_warm"]

    def test_aot_outputs_match_jit_path(self, warmed):
        """The compiled-ahead executables are the SAME program: a second
        engine on the same model (lazy jit path, already traced) must
        produce identical tokens."""
        sm, sp, warm_eng, _ = warmed
        ref_eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=32, chunk_tokens=8, page_tokens=8))
        assert not ref_eng.aot_warm
        warm = [warm_eng.submit(p, SamplingParams(max_tokens=4))
                for p in PROMPTS]
        ref = [ref_eng.submit(p, SamplingParams(max_tokens=4))
               for p in PROMPTS]
        warm_eng.run_until_drained()
        ref_eng.run_until_drained()
        assert [r.output for r in warm] == [r.output for r in ref]


class TestStatefulWarmup:
    def test_snapshot_restore_warm(self):
        """Stateful family + prefix cache: warmup must also cover the
        snapshot/restore pair, and a prefix HIT after warmup (the restore
        path) still traces nothing."""
        _, _, eng = fresh_engine("mamba2-370m", prefix_cache=True)
        assert eng.trie is not None and eng._stateful
        timings = eng.warmup()
        assert {"snapshot_slot", "restore_slot"} <= set(timings)
        before = dict(TRACE_COUNTS)
        shared = [5, 6, 7, 8, 9, 10, 11, 12]
        a = eng.submit(shared + [1], SamplingParams(max_tokens=2))
        eng.run_until_drained()
        b = eng.submit(shared + [2], SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert eng.stats()["prefix_hits"] >= 1  # restore path exercised
        assert dict(TRACE_COUNTS) == before
        assert len(a.output) == 2 and len(b.output) == 2


class TestWarmupFailure:
    def test_failure_names_entry_point(self, warmed):
        """A lower/compile failure must say WHICH executable and shapes —
        a silent partial warmup just moves the stall back into serving.
        Throwaway engines on the fixture's model: Boom raises at lower()
        so no tracing happens before the error path under test."""
        sm, sp, _, _ = warmed
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=3, max_len=32, chunk_tokens=8, page_tokens=8))

        class Boom:
            def lower(self, *a, **k):
                raise ValueError("no lowering today")

        eng._decode = Boom()
        with pytest.raises(RuntimeError,
                           match=r"decode_tick.*tokens int32\[3,1\]"):
            eng.warmup()
        assert not eng.aot_warm or "decode_tick" not in eng._aot
        eng2 = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=32, chunk_tokens=8, page_tokens=8))
        eng2._extend = Boom()
        with pytest.raises(RuntimeError,
                           match=r"extend_tick.*block int32\[2,8\]"):
            eng2.warmup()

"""Chunked-prefill test wall: token parity vs the monolithic prefill
reference across chunk sizes and cache families, plus scheduler fairness.

Parity holds by construction: the engine streams raw prompt tokens (no
padding enters the context), every cache family's ``extend`` applies the
same per-token math at the same absolute positions regardless of chunk
boundaries, and token t of request r is always sampled with
``fold_in(fold_in(PRNGKey(seed), rid), t)`` — so the emitted tokens are a
pure function of (weights, prompt, sampling params, seed, rid),
independent of chunking, batch neighbors, and scheduling order.
"""
import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams, sample_logits_batch
from repro.serve.weights import export_serving_params

KEY = jax.random.PRNGKey(0)

# one arch per decode-cache family (reduced arch-smoke configs)
FAMILY_ARCHS = [
    "granite-8b",          # full attention KV cache
    "recurrentgemma-2b",   # sliding-window ring cache + RG-LRU state
    "mamba2-370m",         # SSM (h, conv) state
]
# chunk sizes that do not divide the 7-token prompt (2), divide it
# exactly (7), and exceed it (16 — the whole prompt lands in one chunk)
PROMPT = [3, 9, 4, 11, 7, 2, 5]
CHUNKS = (2, 7, 16)


@functools.lru_cache(maxsize=None)
def build_serve(arch, **cfg_over):
    """Model + exported serve params, cached: every test of an arch reuses
    one build (tests never mutate params)."""
    cfg = get_config(arch).reduced()
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), KEY)
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    return cfg, sm, sp


def monolithic_reference(sm, sp, prompt, n_tokens, *, seed=0, rid=0,
                         temperature=0.0, top_k=0):
    """The pre-chunking semantics: one whole-prompt prefill, then stepwise
    decode — sampling each token t with the engine's documented per-request
    key stream fold_in(fold_in(PRNGKey(seed), rid), t)."""
    req_key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    temps = jnp.array([temperature], jnp.float32)
    topks = jnp.array([top_k], jnp.int32)

    def sample(logits, t):
        k = jax.random.fold_in(req_key, t)[None]
        return int(sample_logits_batch(
            logits, k, temperature=temps, top_k=topks)[0])

    logits, caches, lengths = sm.prefill(
        sp, {"tokens": jnp.asarray([prompt], jnp.int32)}, 64)
    out = [sample(logits, 0)]
    for t in range(1, n_tokens):
        logits, caches, lengths = sm.decode_step(
            sp, jnp.array([[out[-1]]], jnp.int32), caches, lengths)
        out.append(sample(logits, t))
    return out


class TestChunkedMonolithicParity:
    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_greedy_parity_across_chunk_sizes(self, arch):
        """Greedy tokens are byte-identical to the monolithic reference for
        every chunk size, dividing the prompt length or not."""
        cfg, sm, sp = build_serve(arch)
        ref = monolithic_reference(sm, sp, PROMPT, 6)
        for chunk in CHUNKS:
            eng = BatchedEngine(sm, sp, ServeConfig(
                n_slots=3, max_len=64, chunk_tokens=chunk))
            r = eng.submit(PROMPT, SamplingParams(max_tokens=6))
            eng.run_until_drained()
            assert r.output == ref, (arch, chunk, r.output, ref)

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_seeded_stochastic_parity_across_chunk_sizes(self, arch):
        """Seeded temperature+top-k sampling is chunking-invariant AND
        matches the monolithic reference replayed through the same
        per-request key stream."""
        cfg, sm, sp = build_serve(arch)
        ref = monolithic_reference(sm, sp, PROMPT, 8, seed=3,
                                   temperature=1.0, top_k=5)
        for chunk in CHUNKS:
            eng = BatchedEngine(sm, sp, ServeConfig(
                n_slots=2, max_len=64, chunk_tokens=chunk, seed=3))
            r = eng.submit(PROMPT, SamplingParams(
                temperature=1.0, top_k=5, max_tokens=8))
            eng.run_until_drained()
            assert r.output == ref, (arch, chunk, r.output, ref)

    def test_int8_kv_parity_across_chunk_sizes(self):
        """The quantized KV family: chunked extend quantizes each new row
        with the same per-token scales a monolithic prefill computes."""
        cfg, sm, sp = build_serve("granite-8b", kv_dtype="int8")
        ref = monolithic_reference(sm, sp, PROMPT, 6)
        for chunk in (3, 7, 16):
            eng = BatchedEngine(sm, sp, ServeConfig(
                n_slots=2, max_len=64, chunk_tokens=chunk))
            r = eng.submit(PROMPT, SamplingParams(max_tokens=6))
            eng.run_until_drained()
            assert r.output == ref, (chunk, r.output, ref)

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_concurrent_prefill_does_not_perturb_tokens(self, arch):
        """A request whose prefill streams in WHILE another slot decodes
        produces exactly its solo tokens, and vice versa — per-request key
        streams plus masked decode/extend keep slots independent."""
        cfg, sm, sp = build_serve(arch)
        long_prompt = [int(x) for x in np.arange(1, 30) % cfg.vocab]

        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=64, chunk_tokens=8, seed=5))
        a = eng.submit(PROMPT, SamplingParams(temperature=0.7, max_tokens=10))
        eng.step()                     # a is decoding from tick 1 on
        b = eng.submit(long_prompt, SamplingParams(max_tokens=4))
        eng.run_until_drained()

        solo_a = monolithic_reference(sm, sp, PROMPT, 10, seed=5, rid=0,
                                      temperature=0.7)
        solo_b = monolithic_reference(sm, sp, long_prompt, 4, seed=5, rid=1)
        assert a.output == solo_a
        assert b.output == solo_b


class TestFairness:
    def _engine(self, n_slots=2, chunk=8):
        cfg, sm, sp = build_serve("granite-8b")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=n_slots, max_len=64, chunk_tokens=chunk))
        return cfg, eng

    def test_decoding_slot_emits_one_token_per_tick_during_prefill(self):
        """THE head-of-line regression: while a long prompt prefills in
        chunks, an already-decoding slot advances exactly one token on
        every engine tick (asserted on tick counts, not wall clock)."""
        cfg, eng = self._engine(chunk=8)
        a = eng.submit([1, 2, 3], SamplingParams(max_tokens=30))
        eng.step()
        assert len(a.output) == 1          # prompt fit one chunk
        long_prompt = [int(x) for x in np.arange(40) % cfg.vocab]
        b = eng.submit(long_prompt, SamplingParams(max_tokens=4))

        prefill_ticks = 0
        while not b.output:
            before = len(a.output)
            eng.step()
            prefill_ticks += 1
            assert len(a.output) == before + 1, (
                f"decoding slot stalled at tick {prefill_ticks} "
                f"while prompt prefilled"
            )
        # budget 8 minus 1 decode token -> 7 prompt tokens per tick
        assert prefill_ticks == math.ceil(len(long_prompt) / 7)
        # and b's first token landed the tick its last chunk did
        assert b.token_steps[0] == eng.steps - 1

    def test_prefill_head_cannot_starve_under_decode_load(self):
        """Decode-priority never starves prefill: with every budget token
        consumed by decoding slots, the head-of-queue prefill still gets
        one token per tick and completes."""
        cfg, eng = self._engine(n_slots=3, chunk=2)
        d1 = eng.submit([1, 2], SamplingParams(max_tokens=40))
        d2 = eng.submit([3, 4], SamplingParams(max_tokens=40))
        while not (d1.output and d2.output):
            eng.step()                      # both decoding from here on
        p = eng.submit([5, 6, 7, 8, 9], SamplingParams(max_tokens=2))
        for _ in range(5):                  # 5 prompt tokens at >= 1/tick
            eng.step()
        assert p.output, "prefill starved behind decode-saturated budget"
        eng.run_until_drained()
        assert all(r.done for r in (d1, d2, p))

    def test_fifo_prefill_budget_admission_order(self):
        """Two queued prompts share the leftover budget in admission
        order: the older request finishes its prefill no later than the
        younger one."""
        cfg, eng = self._engine(n_slots=3, chunk=8)
        first = eng.submit([int(x) for x in np.arange(20) % cfg.vocab],
                           SamplingParams(max_tokens=2))
        second = eng.submit([int(x) for x in np.arange(20, 40) % cfg.vocab],
                            SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert first.token_steps[0] <= second.token_steps[0]

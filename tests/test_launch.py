"""Launcher CLIs + examples: end-to-end smoke (reduced, CPU).

Every test here shells out to a fresh interpreter, so the whole module
carries the ``subprocess`` marker: CI runs it in the subprocess lane
(`-m subprocess --durations=15`), keeping the tier-1 lane fast. A plain
``pytest`` still runs everything."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.subprocess


def run_module(args, timeout=420):
    # Minimal env, but JAX_*/XLA_* must pass through: without e.g.
    # JAX_PLATFORMS=cpu, jax backend discovery blocks on non-CPU probing
    # and the subprocess hangs until the timeout.
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    env.update({k: v for k, v in os.environ.items()
                if k.startswith(("JAX_", "XLA_"))})
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        cwd="/root/repo", timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stderr[-2500:]
    return out.stdout


class TestTrainCLI:
    def test_train_resume_cycle(self, tmp_path):
        """20 steps, then resume to 30 from the checkpoint."""
        common = ["repro.launch.train", "--arch", "granite-8b", "--reduced",
                  "--batch", "2", "--seq", "32", "--log-every", "10",
                  "--ckpt-every", "10", "--ckpt-dir", str(tmp_path)]
        out = run_module(common + ["--steps", "20"])
        assert "done: 20 steps" in out
        out = run_module(common + ["--steps", "30"])
        # resumed from step 20 -> only 10 more steps run
        assert "final step=30" in out

    def test_train_bwnn_mode(self, tmp_path):
        out = run_module(
            ["repro.launch.train", "--arch", "mamba2-370m", "--reduced",
             "--steps", "5", "--batch", "2", "--seq", "32",
             "--mode", "bwnn", "--ckpt-dir", str(tmp_path)])
        assert "mode=bwnn" in out


class TestServeCLI:
    def test_serve_reduced(self):
        out = run_module(
            ["repro.launch.serve", "--arch", "granite-8b", "--reduced",
             "--requests", "3", "--max-tokens", "4", "--max-len", "48",
             "--chunk-tokens", "8"])
        assert "smaller" in out and "requests" in out
        # the chunked-prefill driver reports tail latency, not just rate
        assert "TTFT" in out and "chunk=8" in out


class TestDryrunCLI:
    def test_single_cell(self, tmp_path):
        out_file = tmp_path / "cell.json"
        run_module(
            ["repro.launch.dryrun", "--arch", "mamba2-370m",
             "--shape", "decode_32k", "--mesh", "single", "--no-roofline",
             "--out", str(out_file)], timeout=540)
        import json
        rec = json.loads(out_file.read_text())
        assert rec["status"] == "ok" and rec["fits_hbm"]

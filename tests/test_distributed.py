"""Distributed runtime: sharding rules, gradient compression, GPipe.

Multi-device cases run in a subprocess with 8 forced host devices (the
main pytest process must stay single-device per the dry-run contract).
They carry the ``subprocess`` marker so CI runs them in their own lane
(`-m subprocess`) while the tier-1 lane stays fast (`-m "not
subprocess"`); a plain ``pytest`` still runs everything."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest


from repro.distributed.compression import (
    ef_sign_encode,
    int8_decode,
    int8_encode,
    wire_bits,
)


def run_subprocess(body: str):
    """Run ``body`` under 8 virtual devices; body must print PASS."""
    prog = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        """
    ) + textwrap.dedent(body)
    out = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        cwd="/root/repo", timeout=480,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr[-3000:]}"
    assert "PASS" in out.stdout, out.stdout


class TestCodecs:
    def test_int8_roundtrip_error_bound(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (256,))
        q, s = int8_encode(g)
        err = np.abs(np.asarray(int8_decode(q, s) - g))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_ef_sign_error_feedback_identity(self):
        """payload + error == grad + previous error (nothing is lost)."""
        g = jax.random.normal(jax.random.PRNGKey(1), (64,))
        e0 = jax.random.normal(jax.random.PRNGKey(2), (64,)) * 0.1
        payload, e1 = ef_sign_encode(g, e0)
        np.testing.assert_allclose(
            np.asarray(payload + e1), np.asarray(g + e0), rtol=1e-5, atol=1e-6
        )

    def test_wire_bits_ordering(self):
        n = 10_000
        assert wire_bits("ef_sign", n) < wire_bits("int8", n) < wire_bits("none", n)


@pytest.mark.subprocess
class TestShardingRules:
    def test_divisible_spec_drops_ragged(self):
        run_subprocess("""
        from repro.distributed.sharding import param_shardings
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        logical = {"t": ("vocab", "embed")}
        abst = {"t": jax.ShapeDtypeStruct((50281, 64), jnp.float32)}
        sh = param_shardings(mesh, logical, abstract_tree=abst)
        assert sh["t"].spec == jax.sharding.PartitionSpec(None, "data"), sh["t"].spec
        print("PASS")
        """)

    def test_duplicate_axis_first_wins(self):
        run_subprocess("""
        from repro.distributed.sharding import param_shardings
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        logical = {"t": ("experts", "mlp", "embed")}
        abst = {"t": jax.ShapeDtypeStruct((8, 16, 32), jnp.float32)}
        sh = param_shardings(mesh, logical, abstract_tree=abst)
        # experts takes "model"; mlp (also model) must be dropped
        assert sh["t"].spec == jax.sharding.PartitionSpec("model", None, "data"), sh["t"].spec
        print("PASS")
        """)


@pytest.mark.subprocess
class TestCompressedDP:
    def test_ef_sign_dp_converges(self):
        """Explicit-DP shard_map step with EF-sign reaches the same loss
        region as exact reduction on a least-squares problem."""
        run_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (
            CompressionState, build_dp_train_step)
        from repro.optim import constant, sgd_momentum
        from repro.train.step import init_state

        mesh = jax.make_mesh((8,), ("data",))
        k = jax.random.PRNGKey(0)
        w_true = jax.random.normal(k, (16,))
        X = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        y = X @ w_true

        def loss_fn(params, batch):
            pred = batch["x"] @ params["w"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {}

        results = {}
        for kind in ("none", "ef_sign", "int8"):
            opt = sgd_momentum(constant(0.05), momentum=0.0)
            state = init_state({"w": jnp.zeros((16,))}, opt)
            comp = CompressionState.init(state.params, kind)
            step = build_dp_train_step(loss_fn, opt, mesh, compression=kind)
            for i in range(300):
                state, comp, m = step(state, comp, {"x": X, "y": y})
            results[kind] = float(m["loss"])
        assert results["none"] < 1e-3, results
        assert results["int8"] < 1e-2, results
        assert results["ef_sign"] < 5e-2, results
        print("PASS")
        """)


@pytest.mark.subprocess
class TestGPipe:
    def test_pipeline_matches_sequential(self):
        """4-stage GPipe output == running the stages sequentially."""
        run_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline import (
            build_gpipe_apply, bubble_fraction)

        mesh = jax.make_mesh((4,), ("stage",))
        S, M, MB, D = 4, 8, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), S)
        Ws = jnp.stack([jax.random.normal(k, (D, D)) * 0.3 for k in ks])

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        apply = build_gpipe_apply(
            stage_fn, mesh, params_spec=P("stage"),
        )
        x = jax.random.normal(jax.random.PRNGKey(9), (M, MB, D))
        y_pipe = apply(Ws, x)

        y_ref = x
        for s in range(S):
            y_ref = jnp.tanh(y_ref @ Ws[s])
        np.testing.assert_allclose(
            np.asarray(y_pipe), np.asarray(y_ref), rtol=2e-4, atol=2e-5)
        assert abs(bubble_fraction(8, 4) - 3/11) < 1e-9
        print("PASS")
        """)

    def test_pipeline_is_differentiable(self):
        run_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline import build_gpipe_apply

        mesh = jax.make_mesh((4,), ("stage",))
        S, M, MB, D = 4, 4, 2, 8
        Ws = jnp.stack([jax.random.normal(jax.random.PRNGKey(i), (D, D)) * 0.3
                        for i in range(S)])
        x = jax.random.normal(jax.random.PRNGKey(9), (M, MB, D))
        apply = build_gpipe_apply(stage_fn := (lambda w, h: jnp.tanh(h @ w)),
                                  mesh, params_spec=P("stage"))

        def loss_pipe(Ws):
            return jnp.sum(apply(Ws, x) ** 2)

        def loss_ref(Ws):
            h = x
            for s in range(S):
                h = jnp.tanh(h @ Ws[s])
            return jnp.sum(h ** 2)

        g_pipe = jax.grad(loss_pipe)(Ws)
        g_ref = jax.grad(loss_ref)(Ws)
        np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                                   rtol=5e-4, atol=5e-5)
        print("PASS")
        """)


@pytest.mark.subprocess
class TestShardedServe:
    def test_tp_logits_parity_and_tile_bytes(self):
        """Tensor-parallel serve (tile rows sharded over a 4-way model
        axis) reproduces the single-device logits through prefill AND
        decode, and each device holds exactly 1/TP of the tile bytes."""
        run_subprocess("""
        from repro.compat import make_auto_mesh
        from repro.configs import build_model, get_config
        from repro.distributed.sharding import axis_rules, param_shardings
        from repro.nn import module as mod
        from repro.nn.context import SERVE, TRAIN, ModelContext
        from repro.serve.weights import (
            export_serving_params, per_device_tile_bytes, tile_serving_bytes)

        TP = 4
        cfg = get_config("granite-8b").reduced()
        tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                           compute_dtype=jnp.float32))
        sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                           compute_dtype=jnp.float32,
                                           use_pallas=False))
        tp0 = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
        sp = export_serving_params(tm.specs(), sm.specs(), tp0, cfg.tbn)
        batch = {"tokens": jnp.array([[5, 3, 2, 7, 1, 4, 6, 2]], jnp.int32)}

        ref_lg, ref_c, ref_len = jax.jit(
            lambda p, b: sm.prefill(p, b, 16))(sp, batch)

        mesh = make_auto_mesh((TP,), ("model",))
        logical = mod.logical_axes(sm.specs())
        abstract = jax.tree.map(
            lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), sp)
        placed = jax.device_put(
            sp, param_shardings(mesh, logical, abstract_tree=abstract))
        with axis_rules(mesh):
            sh_lg, sh_c, sh_len = jax.jit(
                lambda p, b: sm.prefill(p, b, 16))(placed, batch)
        np.testing.assert_allclose(
            np.asarray(ref_lg), np.asarray(sh_lg), atol=1e-5)

        tok = jnp.argmax(ref_lg, -1)[:, None].astype(jnp.int32)
        t1 = t2 = tok
        for _ in range(4):
            ref_lg, ref_c, ref_len = jax.jit(sm.decode_step)(
                sp, t1, ref_c, ref_len)
            with axis_rules(mesh):
                sh_lg, sh_c, sh_len = jax.jit(sm.decode_step)(
                    placed, t2, sh_c, sh_len)
            np.testing.assert_allclose(
                np.asarray(ref_lg), np.asarray(sh_lg), atol=1e-5)
            t1 = jnp.argmax(ref_lg, -1)[:, None].astype(jnp.int32)
            t2 = jnp.argmax(sh_lg, -1)[:, None].astype(jnp.int32)
            assert (np.asarray(t1) == np.asarray(t2)).all()

        total = tile_serving_bytes(sp)
        per_dev = per_device_tile_bytes(placed)
        assert len(per_dev) == TP, per_dev
        for dev, nbytes in per_dev.items():
            assert nbytes * TP == total, (dev, nbytes, total)
        print("PASS")
        """)

    def test_tp_decode_matvec_parity(self):
        """The decode small-m dispatch engages inside the shard_map
        tensor-parallel wrapper (per-shard m stays tiny, per-shard r is
        r/TP) and matches the dense reconstruction oracle at m in
        {1, 3, 8} on a 4-way model mesh."""
        run_subprocess("""
        from repro.compat import make_auto_mesh
        from repro.core.packing import pack_bits
        from repro.core.tiling import plan_tiling
        from repro.distributed.sharding import axis_rules
        from repro.kernels.ops import tiled_dense_infer
        from repro.kernels.ref import tiled_matmul_ref

        mesh = make_auto_mesh((4,), ("model",))
        p_rep, n_in = 4, 128
        spec = plan_tiling((4 * 64, n_in), p=p_rep, min_size=1,
                           alpha_source="W")
        r = spec.rows_per_tile          # 64 -> 16 unique rows per shard
        t = jnp.where(jax.random.bernoulli(
            jax.random.PRNGKey(1), 0.5, (spec.q,)), 1.0, -1.0)
        rows = pack_bits(t.reshape(r, n_in))
        flat = pack_bits(t)
        alpha = jnp.abs(jax.random.normal(
            jax.random.PRNGKey(2), (spec.n_alpha,))) + 0.1
        for m in (1, 3, 8):
            x = jax.random.normal(jax.random.PRNGKey(m), (m, n_in))
            want = tiled_matmul_ref(x, flat, alpha, n_out=4 * 64, p=p_rep)
            with axis_rules(mesh):
                got = tiled_dense_infer(x, rows, alpha, spec,
                                        use_pallas=True)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
        print("PASS")
        """)

    def test_engine_mesh_per_slot_sampling_parity(self):
        """Per-slot sampling params survive the mesh path: a mixed batch
        (explicit greedy / temperature / top-k requests over a stochastic
        engine default) generates identical tokens single-device vs TP=4."""
        run_subprocess("""
        from repro.compat import make_auto_mesh
        from repro.configs import build_model, get_config
        from repro.nn import module as mod
        from repro.nn.context import SERVE, TRAIN, ModelContext
        from repro.serve.engine import BatchedEngine, ServeConfig
        from repro.serve.sampling import SamplingParams
        from repro.serve.weights import export_serving_params

        cfg = get_config("granite-8b").reduced()
        tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                           compute_dtype=jnp.float32))
        sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                           compute_dtype=jnp.float32,
                                           use_pallas=False))
        tp0 = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
        sp = export_serving_params(tm.specs(), sm.specs(), tp0, cfg.tbn)
        work = [
            ([1, 2, 3], SamplingParams(temperature=0.0, max_tokens=4)),
            ([4, 5], SamplingParams(temperature=1.0, max_tokens=4)),
            ([6, 7, 8], SamplingParams(temperature=1.0, top_k=2,
                                       max_tokens=4)),
        ]
        outs = {}
        for name, mesh in [
            ("single", None),
            ("tp", make_auto_mesh((4,), ("model",))),
        ]:
            eng = BatchedEngine(
                sm, sp,
                ServeConfig(n_slots=3, max_len=64, chunk_tokens=8,
                            temperature=0.7, seed=11),
                mesh=mesh,
            )
            reqs = [eng.submit(p, sp_) for p, sp_ in work]
            eng.run_until_drained()
            outs[name] = [r.output for r in reqs]
        assert outs["single"] == outs["tp"], outs
        print("PASS")
        """)

    def test_engine_mesh_token_parity(self):
        """BatchedEngine(mesh=...) generates the same tokens as the
        single-device engine for a batch of concurrent requests."""
        run_subprocess("""
        from repro.compat import make_auto_mesh
        from repro.configs import build_model, get_config
        from repro.nn import module as mod
        from repro.nn.context import SERVE, TRAIN, ModelContext
        from repro.serve.engine import BatchedEngine, ServeConfig
        from repro.serve.sampling import SamplingParams
        from repro.serve.weights import export_serving_params

        cfg = get_config("granite-8b").reduced()
        tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                           compute_dtype=jnp.float32))
        sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                           compute_dtype=jnp.float32,
                                           use_pallas=False))
        tp0 = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
        sp = export_serving_params(tm.specs(), sm.specs(), tp0, cfg.tbn)
        prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
        outs = {}
        for name, mesh in [
            ("single", None),
            ("tp", make_auto_mesh((2, 4), ("data", "model"))),
        ]:
            eng = BatchedEngine(
                sm, sp,
                ServeConfig(n_slots=3, max_len=64, chunk_tokens=8),
                mesh=mesh,
            )
            reqs = [eng.submit(p, SamplingParams(max_tokens=4))
                    for p in prompts]
            eng.run_until_drained()
            outs[name] = [r.output for r in reqs]
        assert outs["single"] == outs["tp"], outs
        print("PASS")
        """)


@pytest.mark.subprocess
class TestMultiDeviceTrainStep:
    def test_production_sharded_train_step_runs(self):
        """A reduced arch train step EXECUTES on a (2,4) host mesh with the
        production sharding rules (not just lowers — runs and updates)."""
        run_subprocess("""
        import dataclasses
        from repro.configs import get_config, build_model
        from repro.distributed.sharding import axis_rules, param_shardings
        from repro.launch.mesh import make_host_mesh
        from repro.nn import module as mod
        from repro.nn.context import TRAIN, ModelContext
        from repro.optim import adamw, cosine_with_warmup
        from repro.train.step import build_train_step, init_state

        cfg = get_config("granite-8b").reduced()
        cfg = dataclasses.replace(cfg, n_layers=2, vocab=128)
        model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN))
        params = mod.init_params(model.specs(), jax.random.PRNGKey(0))
        opt = adamw(cosine_with_warmup(1e-3, 2, 100))
        state = init_state(params, opt)
        step = build_train_step(model.train_forward, opt)
        mesh = make_host_mesh(2, 4)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
        with axis_rules(mesh):
            state2, metrics = jax.jit(step)(state, {"tokens": toks})
        assert jnp.isfinite(metrics["loss"]), metrics
        # params actually moved
        delta = jax.tree_util.tree_reduce(
            lambda a, b: a + float(jnp.sum(jnp.abs(b[0] - b[1]))),
            jax.tree.map(lambda a, b: (a, b), state.params, state2.params),
            0.0)
        assert delta > 0
        print("PASS")
        """)

"""Preemption parity wall: with preempt-and-resume exercised — forced at
arbitrary ticks, or naturally by the priority scheduler — emitted tokens
are byte-identical to the never-preempted engine, greedy and seeded
stochastic, on all three decode-cache families plus int8 KV.

Why parity holds by construction: parking a slot keeps every byte of its
progress — pool pages stay retained (K/V never moves; resume rewrites a
page-table row), the recurrent families snapshot at the EXACT preemption
position (snapshot/restore is position-exact; the page-boundary rule is
a trie-sharing concern, not a mechanical one), and the host registers
(offset, length, last token, PRNG fold count) ride in the parked record
— while sampling keys on (seed, rid, t) only, never on scheduling. So a
resumed slot emits exactly the tokens the uninterrupted run would have.

Plus the scheduler-policy walls: prefix-aware queue jumping, the
starvation (aging) floor, per-request preemption immunity, and
abort-while-parked resource reclamation.
"""
import numpy as np
import pytest

from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from test_prefix_cache import FAMILY_ARCHS, build_serve

# short prompts decode-dominate (mid-decode preemption), the long prompt
# spends >= 4 ticks in prefill at chunk_tokens=8 (mid-prefill preemption)
SHORT_PROMPTS = [[3, 9, 4, 11, 7, 2, 5], [8, 6, 1, 12, 0], [5, 5, 2, 8]]
LONG_PROMPT = list(range(36))


def make_engine(sm, sp, **cfg_over):
    base = dict(n_slots=2, max_len=64, chunk_tokens=8, page_tokens=4, seed=0)
    base.update(cfg_over)
    return BatchedEngine(sm, sp, ServeConfig(**base))


def baseline_run(sm, sp, prompts, *, max_tokens=6, temperature=0.0,
                 top_k=0, **cfg_over):
    eng = make_engine(sm, sp, **cfg_over)
    reqs = [eng.submit(np.asarray(p, np.int32), SamplingParams(
        max_tokens=max_tokens, temperature=temperature, top_k=top_k))
        for p in prompts]
    eng.run_until_drained()
    return eng, [r.output for r in reqs]


def chaos_run(sm, sp, prompts, *, preempt_every, max_tokens=6,
              temperature=0.0, top_k=0, max_ticks=800, **cfg_over):
    """Same submission order as ``baseline_run`` but every live slot is
    force-preempted every ``preempt_every`` ticks. Returns the engine,
    the outputs, and the set of phases that actually got parked (so
    callers can assert the chaos hit the states they aimed for)."""
    eng = make_engine(sm, sp, **cfg_over)
    reqs = [eng.submit(np.asarray(p, np.int32), SamplingParams(
        max_tokens=max_tokens, temperature=temperature, top_k=top_k))
        for p in prompts]
    parked_phases = set()
    i = 0
    while eng.has_work:
        assert i < max_ticks, "chaos schedule wedged the engine"
        if i % preempt_every == preempt_every - 1:
            for slot in list(eng._live):
                parked_phases.add(eng._phase[slot])
                assert eng.preempt_slot(slot)
        eng.step()
        i += 1
    return eng, [r.output for r in reqs], parked_phases


class TestPreemptParityWall:
    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_forced_mid_decode_parity(self, arch):
        """Greedy tokens survive preempt/resume at every 3rd tick — the
        resumed slot continues exactly where the uninterrupted run was."""
        cfg, sm, sp = build_serve(arch)
        _, base = baseline_run(sm, sp, SHORT_PROMPTS)
        eng, out, phases = chaos_run(sm, sp, SHORT_PROMPTS, preempt_every=3)
        assert out == base, (arch, out, base)
        assert "decode" in phases
        st = eng.stats()
        assert st["preempts"] > 0 and st["resumes"] == st["preempts"]
        assert st["parked"] == 0
        # preempted ticks are NOT preempt-free: the stub is real now
        assert st["preempt_free_ticks"] < st["work_ticks"]

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_seeded_stochastic_parity(self, arch):
        """Sampling keys on (seed, rid, t) only: a resumed slot replays
        the exact stochastic stream, not just the greedy argmax."""
        cfg, sm, sp = build_serve(arch)
        kw = dict(temperature=1.0, top_k=5, max_tokens=7, seed=3)
        _, base = baseline_run(sm, sp, SHORT_PROMPTS, **kw)
        _, out, _ = chaos_run(sm, sp, SHORT_PROMPTS, preempt_every=3, **kw)
        assert out == base, (arch, out, base)

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_preempt_while_prefilling_parity(self, arch):
        """Parking mid-prompt (offset strictly inside the prompt) and
        resuming continues the chunked prefill where it stopped."""
        cfg, sm, sp = build_serve(arch)
        prompts = [LONG_PROMPT, SHORT_PROMPTS[0]]
        _, base = baseline_run(sm, sp, prompts, max_tokens=4)
        eng, out, phases = chaos_run(sm, sp, prompts, preempt_every=2,
                                     max_tokens=4)
        assert out == base, (arch, out, base)
        assert "prefill" in phases    # the chaos really parked a prefill

    def test_int8_kv_parity(self):
        """Quantized KV: codes and scales page together, so a parked page
        run resumes bit-identical int8 state."""
        cfg, sm, sp = build_serve("granite-8b", kv_dtype="int8")
        _, base = baseline_run(sm, sp, SHORT_PROMPTS)
        _, out, _ = chaos_run(sm, sp, SHORT_PROMPTS, preempt_every=3)
        assert out == base

    def test_preempt_every_tick_still_drains(self):
        """The degenerate schedule — park everything, every tick — makes
        progress anyway: resume happens at tick top, decode still emits."""
        cfg, sm, sp = build_serve("granite-8b")
        _, base = baseline_run(sm, sp, SHORT_PROMPTS[:2])
        _, out, _ = chaos_run(sm, sp, SHORT_PROMPTS[:2], preempt_every=1)
        assert out == base

    def test_natural_priority_preempt_parity_and_overtake(self):
        """The scheduler's own preemption: a late interactive request on a
        saturated 1-slot engine preempts the decoding batch request,
        finishes first, and NO token of either stream changes."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp, n_slots=1, priorities=True, preempt=True)
        # equal-length batch prompts: equal prefill cost, so rid order
        # decides and rb takes the slot first (pure FIFO within the tie)
        rq_prompt = [8, 6, 1, 12, 0, 9, 2]
        rb = eng.submit(np.asarray(SHORT_PROMPTS[0], np.int32),
                        SamplingParams(max_tokens=12, priority="batch"))
        rq = eng.submit(np.asarray(rq_prompt, np.int32),
                        SamplingParams(max_tokens=4, priority="batch"))
        for _ in range(4):
            eng.step()            # rb is decoding; rq waits in the queue
        ri = eng.submit(np.asarray(SHORT_PROMPTS[2], np.int32),
                        SamplingParams(max_tokens=3, priority="interactive"))
        eng.run_until_drained()
        assert ri.token_steps[0] < rb.token_steps[-1], "no overtake"
        assert ri.token_steps[0] < rq.token_steps[0], "no queue jump"
        assert rb.preempt_count >= 1
        st = eng.stats()
        assert st["preempts"] >= 1 and st["resumes"] >= 1
        assert st["preempted_tokens"] > 0
        # rq's queueing wait lands in the batch column; the interactive
        # arrival cut straight to the slot
        assert (st["class_ttft_ticks"]["interactive"]
                < st["class_ttft_ticks"]["batch"])
        assert st["class_counts"] == {"batch": 2, "interactive": 1}
        # parity: same submissions on a plain FIFO engine
        eng2 = make_engine(sm, sp, n_slots=1)
        rb2 = eng2.submit(np.asarray(SHORT_PROMPTS[0], np.int32),
                          SamplingParams(max_tokens=12))
        rq2 = eng2.submit(np.asarray(rq_prompt, np.int32),
                          SamplingParams(max_tokens=4))
        ri2 = eng2.submit(np.asarray(SHORT_PROMPTS[2], np.int32),
                          SamplingParams(max_tokens=3))
        eng2.run_until_drained()
        assert rb.output == rb2.output and ri.output == ri2.output
        assert rq.output == rq2.output

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_chaos_leaves_no_pool_state(self, arch):
        """After a chaos drain: nothing parked, refcount partition holds,
        zero pages in use (no trie to pin any)."""
        cfg, sm, sp = build_serve(arch)
        eng, _, _ = chaos_run(sm, sp, SHORT_PROMPTS, preempt_every=2)
        assert not eng._parked
        if eng.pool is not None:
            eng.pool.check()
            assert eng.pool.used_pages == 0


class TestSchedulerPolicy:
    def test_prefix_aware_admission_jump(self):
        """A queued request whose prompt is largely trie-cached overtakes
        an OLDER uncached request of the same class — proportional cost
        ordering, driven by the non-pinning probe."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp, n_slots=1, prefix_cache=True,
                          priorities=True)
        warm = np.asarray(list(range(24)), np.int32)
        eng.submit(warm, SamplingParams(max_tokens=2))
        eng.run_until_drained()      # publishes warm's pages to the trie
        eng.submit(np.asarray(SHORT_PROMPTS[1], np.int32),
                   SamplingParams(max_tokens=2))
        eng.step()                   # filler occupies the only slot
        rng = np.random.default_rng(1)
        cold = eng.submit(rng.integers(100, 200, size=24).astype(np.int32),
                          SamplingParams(max_tokens=2))
        cached = eng.submit(warm, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert cached.admit_step < cold.admit_step
        assert cached.prefix_hit_tokens > 0

    def test_probe_does_not_pin(self):
        """The admission-ordering probe must not touch trie recency — a
        request merely WAITING in the queue must not keep its prefix warm
        (that would starve eviction). match() with a later clock does."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp, prefix_cache=True)
        warm = np.asarray(list(range(24)), np.int32)
        eng.submit(warm, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        trie = eng.trie
        assert len(trie) > 0
        before = {id(n): n.last_used for n in trie._nodes}
        depth = trie.probe(warm, require_snapshot=eng._stateful)
        assert depth > 0
        assert {id(n): n.last_used for n in trie._nodes} == before
        # probe predicts exactly what match serves
        path = trie.match(warm, require_snapshot=eng._stateful, now=999)
        assert depth == len(path) * trie.pt
        assert any(n.last_used == 999 for n in trie._nodes)

    def test_starvation_floor(self):
        """Priority mode ages: after ``starvation_limit`` consecutive
        overtakes of the oldest waiter, the oldest waiter is admitted —
        the batch class cannot starve under an interactive flood."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp, n_slots=1, priorities=True,
                          starvation_limit=2)
        blocker = eng.submit(np.asarray(SHORT_PROMPTS[0], np.int32),
                             SamplingParams(max_tokens=2,
                                            priority="interactive"))
        eng.step()                   # blocker holds the only slot
        batch = eng.submit(np.asarray(SHORT_PROMPTS[1], np.int32),
                           SamplingParams(max_tokens=2, priority="batch"))
        flood = [eng.submit(np.asarray(SHORT_PROMPTS[2], np.int32),
                            SamplingParams(max_tokens=2,
                                           priority="interactive"))
                 for _ in range(5)]
        eng.run_until_drained()
        del blocker
        overtook = sum(1 for r in flood if r.admit_step < batch.admit_step)
        assert overtook == 2, (overtook,
                               [r.admit_step for r in flood],
                               batch.admit_step)

    def test_preempt_immunity_cap(self):
        """A request preempted ``max_preempts`` times becomes immune: the
        next interactive arrival waits instead of thrashing it again."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp, n_slots=1, priorities=True, preempt=True,
                          max_preempts=1)
        rb = eng.submit(np.asarray(SHORT_PROMPTS[0], np.int32),
                        SamplingParams(max_tokens=16, priority="batch"))
        for _ in range(3):
            eng.step()
        eng.submit(np.asarray(SHORT_PROMPTS[2], np.int32),
                   SamplingParams(max_tokens=2, priority="interactive"))
        eng.step()                   # preempt pass parks rb, admits ri1
        assert rb.preempt_count == 1 and eng._parked
        while eng._parked:           # run the parked batch back in
            eng.step()
        # second interactive: batch is at its cap -> no second preemption
        ri2 = eng.submit(np.asarray(SHORT_PROMPTS[2], np.int32),
                         SamplingParams(max_tokens=2,
                                        priority="interactive"))
        eng.run_until_drained()
        assert rb.preempt_count == 1
        assert eng.stats()["preempts"] == 1
        assert ri2.done and ri2.finish_reason in ("length", "eos")

    def test_abort_parked_request_releases_everything(self):
        """Aborting a PARKED request frees its retained pages, fires
        on_finish with "aborted", and leaves the resume queue empty."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp)
        finished = []
        eng.on_finish = finished.append
        ra = eng.submit(np.asarray(SHORT_PROMPTS[0], np.int32),
                        SamplingParams(max_tokens=10))
        rb = eng.submit(np.asarray(SHORT_PROMPTS[1], np.int32),
                        SamplingParams(max_tokens=4))
        for _ in range(3):
            eng.step()
        slot = next(s for s, r in eng._live.items() if r is ra)
        assert eng.preempt_slot(slot)
        held = eng.pool.used_pages
        assert held > 0
        assert eng.abort(ra)
        assert ra.finish_reason == "aborted" and ra in finished
        assert not eng._parked
        assert eng.pool.used_pages < held
        eng.run_until_drained()
        assert rb.done and rb.finish_reason != "aborted"
        eng.pool.check()
        assert eng.pool.used_pages == 0

    def test_fifo_mode_unchanged_by_classes(self):
        """priorities=False stays strict FIFO even when requests carry
        classes — the flag, not the field, changes scheduling."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp, n_slots=1)
        first = eng.submit(np.asarray(SHORT_PROMPTS[0], np.int32),
                           SamplingParams(max_tokens=2, priority="batch"))
        second = eng.submit(np.asarray(SHORT_PROMPTS[1], np.int32),
                            SamplingParams(max_tokens=2,
                                           priority="interactive"))
        eng.run_until_drained()
        assert first.admit_step < second.admit_step

    def test_submit_rejects_unknown_class(self):
        cfg, sm, sp = build_serve("granite-8b")
        eng = make_engine(sm, sp)
        with pytest.raises(ValueError, match="priority class"):
            eng.submit(np.asarray([1, 2, 3], np.int32),
                       SamplingParams(priority="urgent"))

"""Encoder-decoder serving parity wall: engine == dense prefill+decode.

The engine serves EncDecModel with a budgeted ENCODE phase (one
fixed-shape batch=1 encoder call per admitted source, charged against
the tick's chunk budget), the encoder output written once into a
READ-ONLY cross-attention page pool with its own page-table rows, and a
digest-keyed EncoderCache so a repeated source maps the existing page
run and skips ENCODE entirely. Parity holds because the decoder-side
math is position-exact regardless of chunking (same argument as the
decoder-only wall), the encoder runs padded-to-capacity with masked-out
rows that are byte-neutral (NEG_INF -> exp underflow to exact 0), and a
cache hit re-reads the very same pages the original encode wrote.

The token-keyed prefix trie is OFF for cross models — decoder self-attn
K/V depends on the attended source, so sharing a prompt prefix across
different sources would be wrong (DESIGN.md §6.5); only the encoder
output is source-pure and reusable.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.packing import unpack_bits
from repro.core.tiling import tile_vector
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams, sample_logits_batch
from repro.serve.weights import export_serving_params

KEY = jax.random.PRNGKey(0)
ARCH = "seamless-m4t-large-v2"
PROMPTS = [[3, 9, 4, 11, 7, 2, 5], [8, 6, 1, 12, 0], [5, 5, 2, 8]]
CHUNKS = (2, 7, 16)
ENC_TOKENS = 16


@functools.lru_cache(maxsize=None)
def build_encdec():
    cfg = get_config(ARCH).reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), KEY)
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    return cfg, tm, tp, sm, sp


@functools.lru_cache(maxsize=None)
def sources():
    """Two distinct synthetic source clips (ragged lengths)."""
    cfg = build_encdec()[0]
    rng = np.random.default_rng(7)
    return tuple(
        rng.standard_normal((n, cfg.d_model)).astype(np.float32)
        for n in (11, 5)
    )


def dense_reference(sm, sp, prompt, frames, n_tokens, *, seed=0, rid=0,
                    temperature=0.0, top_k=0):
    """EncDecModel.prefill + decode_step, unpaged and unchunked, sampled
    with the engine's PRNG stream — the wall the engine must match."""
    req_key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    temps = jnp.array([temperature], jnp.float32)
    topks = jnp.array([top_k], jnp.int32)

    def sample(logits, t):
        k = jax.random.fold_in(req_key, t)[None]
        return int(sample_logits_batch(
            logits, k, temperature=temps, top_k=topks)[0])

    logits, caches, lengths = sm.prefill(
        sp, {"frames": jnp.asarray(frames)[None],
             "tokens": jnp.asarray([prompt], jnp.int32)}, 64)
    out = [sample(logits, 0)]
    for t in range(1, n_tokens):
        logits, caches, lengths = sm.decode_step(
            sp, jnp.array([[out[-1]]], jnp.int32), caches, lengths)
        out.append(sample(logits, t))
    return out


def engine_run(sm, sp, jobs, *, chunk_tokens=8, max_tokens=6,
               temperature=0.0, top_k=0, preempt_every=0, **cfg_over):
    """Drain [(prompt, frames), ...]; returns (engine, outputs, reqs)."""
    base = dict(n_slots=2, max_len=64, chunk_tokens=chunk_tokens,
                page_tokens=8, enc_tokens=ENC_TOKENS, seed=0,
                prefix_cache=True)
    base.update(cfg_over)
    eng = BatchedEngine(sm, sp, ServeConfig(**base))
    reqs = [eng.submit(np.asarray(p, np.int32), SamplingParams(
        max_tokens=max_tokens, temperature=temperature, top_k=top_k),
        frames=f) for p, f in jobs]
    i = 0
    while eng.has_work:
        assert i < 800, "engine wedged"
        if preempt_every and i % preempt_every == preempt_every - 1:
            for slot in list(eng._live):
                assert eng.preempt_slot(slot)
        eng.step()
        i += 1
    return eng, [r.output for r in reqs], reqs


class TestEncDecParityWall:
    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_greedy_parity_across_chunk_sizes(self, chunk):
        _, _, _, sm, sp = build_encdec()
        src = sources()
        jobs = [(p, src[i % 2]) for i, p in enumerate(PROMPTS)]
        refs = [dense_reference(sm, sp, p, f, 6, rid=i)
                for i, (p, f) in enumerate(jobs)]
        _, out, _ = engine_run(sm, sp, jobs, chunk_tokens=chunk)
        assert out == refs

    def test_seeded_stochastic_parity(self):
        _, _, _, sm, sp = build_encdec()
        src = sources()
        kw = dict(temperature=0.9, top_k=12)
        jobs = [(p, src[i % 2]) for i, p in enumerate(PROMPTS)]
        refs = [dense_reference(sm, sp, p, f, 6, rid=i, **kw)
                for i, (p, f) in enumerate(jobs)]
        _, out, _ = engine_run(sm, sp, jobs, **kw)
        assert out == refs

    def test_warm_encoder_reuse_parity(self):
        """Admissions AFTER the first over the same source skip ENCODE
        (page-run mapping, no encoder call) and still match their own
        dense reference byte-for-byte."""
        _, _, _, sm, sp = build_encdec()
        frames = sources()[0]
        jobs = [(p, frames) for p in PROMPTS]
        refs = [dense_reference(sm, sp, p, frames, 6, rid=i)
                for i, p in enumerate(PROMPTS)]
        eng, out, reqs = engine_run(sm, sp, jobs)
        assert out == refs
        st = eng.stats()
        assert st["encode_ticks"] == 1          # one real encode total
        assert st["enc_cache_hits"] == len(PROMPTS) - 1
        assert all(r.enc_reused for r in reqs[1:])

    @pytest.mark.parametrize("kw", [
        dict(), dict(temperature=0.9, top_k=12),
    ], ids=["greedy", "stochastic"])
    def test_preempt_resume_parity(self, kw):
        """Preemption parks cross-attention page rows alongside self-attn
        ones; resuming rewrites both tables and decode continues
        byte-exactly — never re-encoding the source."""
        _, _, _, sm, sp = build_encdec()
        src = sources()
        jobs = [(p, src[i % 2]) for i, p in enumerate(PROMPTS)]
        base_eng, base, _ = engine_run(sm, sp, jobs, **kw)
        chaos, out, _ = engine_run(sm, sp, jobs, preempt_every=3, **kw)
        assert out == base
        st = chaos.stats()
        assert st["preempts"] > 0 and st["resumes"] == st["preempts"]
        # parking never triggered a re-encode
        assert st["encode_ticks"] == base_eng.stats()["encode_ticks"]

    def test_distinct_sources_are_not_shared(self):
        """Same prompt over different sources must decode differently —
        the trie being off for cross models is load-bearing."""
        _, _, _, sm, sp = build_encdec()
        a, b = sources()
        jobs = [(PROMPTS[0], a), (PROMPTS[0], b)]
        eng, out, _ = engine_run(sm, sp, jobs)
        assert out[0] == dense_reference(sm, sp, PROMPTS[0], a, 6, rid=0)
        assert out[1] == dense_reference(sm, sp, PROMPTS[0], b, 6, rid=1)
        assert eng.stats()["enc_cache_hits"] == 0
        assert eng.trie is None                 # token trie disabled


class TestCrossCacheLivesInPool:
    def test_zero_dense_cross_rows(self):
        """Every cross-attention cache leaf is pool-form
        (L, n_pages, page_tokens, K, hd) — no (n_slots, max_len) rows."""
        cfg, _, _, sm, sp = build_encdec()
        eng, _, _ = engine_run(sm, sp, [(PROMPTS[0], sources()[0])])
        n_slots, max_len = eng.cfg.n_slots, eng.cfg.max_len
        leaves = jax.tree_util.tree_leaves(eng.caches["cross"])
        assert leaves, "no cross cache family"
        for leaf in leaves:
            assert leaf.ndim == 5
            assert leaf.shape[0] == cfg.dec_layers
            assert leaf.shape[1] == eng.xpool.n_pages
            assert leaf.shape[2] == eng.cfg.page_tokens
            assert leaf.shape[:2] != (n_slots, max_len)

    def test_cross_pages_refcounted_and_released(self):
        """After a full drain only the EncoderCache's published entries
        still hold cross pages; slot references are all gone."""
        _, _, _, sm, sp = build_encdec()
        eng, _, _ = engine_run(sm, sp,
                               [(p, sources()[0]) for p in PROMPTS[:2]])
        held = eng.enc_cache.held_pages()
        assert eng.xpool.used_pages == len(set(held))
        eng.enc_cache.clear()
        assert eng.xpool.used_pages == 0
        eng.pool.check()
        eng.xpool.check()

    def test_stats_reports_both_cache_families(self):
        _, _, _, sm, sp = build_encdec()
        eng, _, _ = engine_run(sm, sp, [(PROMPTS[0], sources()[0])])
        st = eng.stats()
        fams = st["cache_families"]
        assert set(fams) == {"self_attn", "cross_attn"}
        for f in fams.values():
            assert set(f) == {"pages", "in_use", "utilization"}
        assert st["encode_ticks"] >= 1
        assert "enc_cache_hits" in st and "enc_cache_entries" in st


class TestEncDecSubmitValidation:
    def test_frames_required(self):
        _, _, _, sm, sp = build_encdec()
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=8, page_tokens=8,
            enc_tokens=ENC_TOKENS))
        with pytest.raises(ValueError, match="frames"):
            eng.submit(np.asarray(PROMPTS[0], np.int32),
                       SamplingParams(max_tokens=2))

    def test_frames_overflow_rejected(self):
        cfg, _, _, sm, sp = build_encdec()
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=8, page_tokens=8,
            enc_tokens=ENC_TOKENS))
        too_long = np.zeros((ENC_TOKENS + 1, cfg.d_model), np.float32)
        with pytest.raises(ValueError):
            eng.submit(np.asarray(PROMPTS[0], np.int32),
                       SamplingParams(max_tokens=2), frames=too_long)

    def test_decoder_only_engine_rejects_frames(self):
        from test_chunked_prefill import build_serve

        _, sm, sp = build_serve("granite-8b")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=8))
        with pytest.raises(ValueError):
            eng.submit(np.asarray(PROMPTS[0], np.int32),
                       SamplingParams(max_tokens=2),
                       frames=np.zeros((4, 8), np.float32))


class TestEncDecExportRoundTrip:
    def test_cross_attn_tiles_roundtrip_bit_exact(self):
        """Decoder cross-attention (and encoder self-attention) packed
        tiles reconstruct the master sign structure exactly."""
        cfg, tm, tp, sm, sp = build_encdec()
        for path in (("dec", "cross_attn", "wq"), ("enc", "attn", "wk")):
            wt, st = tp, sp
            for k in path:
                wt, st = wt[k], st[k]
            w, packed = wt["w"], st["tile"]          # (L, out, in) / (L, r, words)
            spec = cfg.tbn.spec_for(tuple(w.shape[1:]))
            for layer in range(w.shape[0]):
                t_ref = tile_vector(w[layer], spec)
                t_got = unpack_bits(
                    packed[layer], w.shape[-1]).reshape(-1)
                np.testing.assert_array_equal(
                    np.asarray(t_ref), np.asarray(t_got),
                    err_msg=f"{'/'.join(path)} layer {layer}")

    def test_serve_bytes_smaller_than_masters(self):
        from repro.serve.weights import serving_bytes

        _, _, tp, _, sp = build_encdec()
        assert serving_bytes(sp) < serving_bytes(tp) / 4

"""Serving front-end test wall (repro.serve.server).

The load-bearing property: what a client reads off the SSE wire is
BYTE-IDENTICAL to what ``run_until_drained`` produces for the same
requests — greedy and stochastic, across seeds, and regardless of the
order concurrent submissions race into the admission queue. Stochastic
parity rides on per-request explicit seeds (``SamplingParams.seed``):
the key stream becomes ``PRNGKey(seed)``, independent of the rid the
server happened to assign.

Plus the operational wall: typed 429 backpressure (never a blocked tick
loop), slow-consumer isolation (one unread stream cannot stall the
others), and the mid-flight shutdown contract (detok thread joined,
partial text flushed, zero live slots, zero leaked pool pages).
"""
import asyncio
import contextlib
import functools
import json
import os
import random
import signal
import socket
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.client import (
    _read_head,
    _request_bytes,
    request_json,
    request_text,
    sse_generate,
)
from repro.serve.detok import PieceCodec, decode_all
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.server import SLOW_DROP, EngineServer, ServerConfig, TokenStream
from repro.serve.weights import export_serving_params

HOST = "127.0.0.1"


@functools.lru_cache(maxsize=None)
def build_serve(arch="granite-8b"):
    cfg = get_config(arch).reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    return cfg, sm, sp


def make_engine(**cfg_kw):
    _, sm, sp = build_serve()
    kw = dict(n_slots=2, max_len=64, chunk_tokens=8, page_tokens=8)
    kw.update(cfg_kw)
    return BatchedEngine(sm, sp, ServeConfig(**kw))


@contextlib.asynccontextmanager
async def serving(engine=None, server_cfg=None, **eng_kw):
    eng = engine if engine is not None else make_engine(**eng_kw)
    srv = EngineServer(eng, server_cfg or ServerConfig(host=HOST, port=0))
    port = await srv.start(aot=False)   # jit path: build_serve is warm
    try:
        yield srv, port, eng
    finally:
        await srv.close()


async def wait_stat(port, pred, timeout=15.0):
    t0 = time.perf_counter()
    while True:
        _, s = await request_json(HOST, port, "GET", "/stats")
        if pred(s):
            return s
        assert time.perf_counter() - t0 < timeout, f"stats never settled: {s}"
        await asyncio.sleep(0.01)


def reference_outputs(prompts, params):
    """The non-server ground truth: same engine config, run_until_drained."""
    eng = make_engine()
    reqs = [eng.submit(p, sp) for p, sp in zip(prompts, params)]
    eng.run_until_drained()
    return [list(r.output) for r in reqs]


class TestSSEParity:
    @pytest.mark.parametrize("seed", [0, 1])
    @pytest.mark.parametrize("temperature", [0.0, 1.0])
    def test_stream_matches_drained_engine_shuffled(self, seed, temperature):
        """6 requests raced into the server in a seed-shuffled order must
        stream exactly the tokens the batch engine emits for them in
        submission order — the wire adds nothing and loses nothing."""
        rng = np.random.default_rng(seed)
        n = 6
        prompts = [[int(t) for t in rng.integers(0, 64,
                                                 size=int(rng.integers(3, 12)))]
                   for _ in range(n)]
        maxtoks = [int(rng.integers(3, 8)) for _ in range(n)]
        seeds = [1000 * seed + i for i in range(n)]
        ref = reference_outputs(prompts, [
            SamplingParams(max_tokens=m, temperature=temperature, seed=s)
            for m, s in zip(maxtoks, seeds)])

        order = list(range(n))
        random.Random(seed).shuffle(order)

        async def go():
            async with serving() as (srv, port, eng):
                async def one(i, k):
                    await asyncio.sleep(0.01 * k)  # stagger: racy admission
                    return i, await sse_generate(HOST, port, {
                        "prompt": prompts[i], "max_tokens": maxtoks[i],
                        "temperature": temperature, "seed": seeds[i]})
                return await asyncio.gather(
                    *(one(i, k) for k, i in enumerate(order)))

        codec = PieceCodec()
        for i, (status, events, _) in asyncio.run(go()):
            assert status == 200
            toks = [e["token"] for e in events if "token" in e]
            done = events[-1]
            assert done.get("done") and done["finish_reason"] == "length"
            assert toks == ref[i], f"req {i} diverged from engine output"
            # byte-identical text: the streamed deltas concatenate to the
            # final text, which is the reference detokenization
            assert "".join(e["text"] for e in events if "token" in e) \
                == done["text"] == decode_all(codec, toks)
            assert done["n_tokens"] == len(toks) == maxtoks[i]

    def test_nonstreaming_matches_stream(self):
        prompt, m = [7, 3, 11, 2], 5
        async def go():
            async with serving() as (srv, port, eng):
                st1, ev, _ = await sse_generate(HOST, port, {
                    "prompt": prompt, "max_tokens": m})
                st2, body = await request_json(HOST, port, "POST",
                                               "/generate", {
                    "prompt": prompt, "max_tokens": m, "stream": False})
                return st1, ev, st2, body
        st1, ev, st2, body = asyncio.run(go())
        assert st1 == st2 == 200
        toks = [e["token"] for e in ev if "token" in e]
        assert body["tokens"] == toks
        assert body["text"] == ev[-1]["text"]
        assert body["finish_reason"] == ev[-1]["finish_reason"] == "length"

    def test_healthz_stats_and_errors(self):
        async def go():
            async with serving() as (srv, port, eng):
                health = await request_json(HOST, port, "GET", "/healthz")
                missing = await request_json(HOST, port, "GET", "/nope")
                bad = await request_json(HOST, port, "POST", "/generate",
                                         {"max_tokens": 2})
                await sse_generate(HOST, port,
                                   {"prompt": [1, 2], "max_tokens": 2})
                stats = await request_json(HOST, port, "GET", "/stats")
                return health, missing, bad, stats
        health, missing, bad, stats = asyncio.run(go())
        assert health == (200, {"ok": True})
        assert missing[0] == 404
        assert bad[0] == 400 and bad[1]["error"] == "bad_request"
        st = stats[1]
        assert st["streams_opened"] >= 1 and st["tokens_out"] >= 2
        assert st["open_streams"] == 0 and st["detok_backlog"] == 0
        for key in ("queue_depth", "peak_queue_depth", "live_slots",
                    "preempt_free_tick_rate", "aot_warm"):
            assert key in st


class TestBackpressure:
    def test_admission_queue_full_is_typed_429(self):
        """Slot busy + queue at capacity: the NEXT submit gets an HTTP
        429 with the typed body, immediately — the tick loop never
        blocks, and the in-flight requests still finish."""
        async def go():
            async with serving(n_slots=1, max_queued=1,
                               max_len=160) as (srv, port, eng):
                t1 = asyncio.ensure_future(sse_generate(HOST, port, {
                    "prompt": [1, 2, 3], "max_tokens": 96}))
                await wait_stat(port, lambda s: s["live_slots"] == 1)
                t2 = asyncio.ensure_future(sse_generate(HOST, port, {
                    "prompt": [4, 5, 6], "max_tokens": 8}))
                await wait_stat(port, lambda s: s["queue_depth"] == 1)
                status, body = await request_json(HOST, port, "POST",
                                                  "/generate", {
                    "prompt": [9], "max_tokens": 2, "stream": False})
                (st1, ev1, _), (st2, ev2, _) = await t1, await t2
                stats = (await request_json(HOST, port, "GET", "/stats"))[1]
                return status, body, st1, ev1, st2, ev2, stats
        status, body, st1, ev1, st2, ev2, stats = asyncio.run(go())
        assert status == 429
        assert body == {"error": "admission_queue_full", "queued": 1,
                        "capacity": 1, "retry": True}
        assert st1 == 200 and ev1[-1].get("done")
        assert st2 == 200 and ev2[-1].get("done")
        assert stats["rejected"] >= 1 and stats["http_rejects"] >= 1

    def test_interactive_overtakes_batch_flood_on_the_wire(self):
        """End-to-end pressure scheduling over real HTTP/SSE: a 1-slot
        engine is saturated by a long batch stream; an interactive
        request arriving mid-decode preempts it, finishes first, and
        neither stream's tokens differ from the FIFO reference engine —
        the scheduler moves WHEN tokens arrive, never WHICH tokens."""
        b_prompt, b_max = [1, 2, 3], 48
        i_prompt, i_max = [9, 8, 7], 4

        async def go():
            eng = make_engine(n_slots=1, max_len=160, priorities=True,
                              preempt=True)
            async with serving(engine=eng) as (srv, port, _):
                tb = asyncio.ensure_future(sse_generate(HOST, port, {
                    "prompt": b_prompt, "max_tokens": b_max,
                    "priority": "batch"}))
                await wait_stat(port, lambda s: s["live_slots"] == 1
                                and s["tokens_out"] >= 2)
                ti = asyncio.ensure_future(sse_generate(HOST, port, {
                    "prompt": i_prompt, "max_tokens": i_max,
                    "priority": "interactive"}))
                (stb, evb, tmb), (sti, evi, tmi) = await tb, await ti
                bad = await request_json(HOST, port, "POST", "/generate", {
                    "prompt": [1], "max_tokens": 2, "stream": False,
                    "priority": "urgent"})
                stats = (await request_json(HOST, port, "GET", "/stats"))[1]
                return stb, evb, tmb, sti, evi, tmi, bad, stats

        stb, evb, tmb, sti, evi, tmi, bad, stats = asyncio.run(go())
        assert stb == sti == 200
        assert evb[-1].get("done") and evi[-1].get("done")
        # the interactive stream CLOSED while the batch flood was still
        # decoding — that is the overtake, measured at the client
        assert tmi[-1] < tmb[-1], (tmi[-1], tmb[-1])
        assert stats["preempts"] >= 1 and stats["resumes"] >= 1
        assert stats["parked"] == 0 and stats["live_slots"] == 0
        assert set(stats["class_counts"]) == {"batch", "interactive"}
        # byte parity with the FIFO reference engine
        ref_b, ref_i = reference_outputs(
            [b_prompt, i_prompt],
            [SamplingParams(max_tokens=b_max),
             SamplingParams(max_tokens=i_max)])
        assert [e["token"] for e in evb if "token" in e] == ref_b
        assert [e["token"] for e in evi if "token" in e] == ref_i
        # unknown class is a typed 400, not a wedged engine
        assert bad[0] == 400 and bad[1]["error"] == "bad_prompt"

    def test_slow_consumer_cannot_stall_other_streams(self):
        """A client that stops reading its SSE socket is detected (drain
        timeout against test-scale socket buffers) and disconnected;
        concurrent fast streams finish with full output meanwhile."""
        cfg = ServerConfig(host=HOST, port=0, stream_buffer=4,
                           write_high_water=64, sndbuf=4096,
                           drain_timeout=0.3)
        async def go():
            async with serving(server_cfg=cfg, n_slots=2,
                               max_len=512) as (srv, port, eng):
                # raw non-reading client: small RCVBUF closes the TCP
                # window within a few KB of events
                s = socket.socket()
                s.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
                s.connect((HOST, port))
                # limit= caps the client transport's eager read-ahead —
                # without it asyncio buffers 64KB off the socket and the
                # TCP window never closes at test scale
                reader, writer = await asyncio.open_connection(
                    sock=s, limit=1024)
                writer.write(_request_bytes("POST", "/generate", {
                    "prompt": [1, 2, 3], "max_tokens": 480}))
                await writer.drain()
                await _read_head(reader)   # headers only, then never read
                # fast streams complete while the slow one is wedged
                fast = []
                for _ in range(3):
                    fast.append(await sse_generate(HOST, port, {
                        "prompt": [4, 5, 6], "max_tokens": 6}))
                await wait_stat(port, lambda s_: s_["slow_disconnects"] >= 1)
                stats = await wait_stat(
                    port, lambda s_: s_["live_slots"] == 0
                    and s_["open_streams"] == 0)
                writer.close()
                return fast, stats, srv.counters
        fast, stats, counters = asyncio.run(go())
        for st, ev, _ in fast:
            assert st == 200 and ev[-1].get("done")
            assert len([e for e in ev if "token" in e]) == 6
        assert counters["slow_disconnects"] >= 1
        # the wedged request was aborted and its resources freed
        assert stats["aborted"] >= 1
        assert stats["pages_in_use"] == 0

    def test_token_stream_drop_policy_buffer(self):
        """Unit wall for the bounded buffer: overflow drops token events
        and sticks the flag, but the final event ALWAYS lands."""
        async def go():
            ts = TokenStream(maxsize=2)
            for i in range(5):
                ts.push({"token": i, "text": f"t{i}", "index": i})
            ts.push({"done": True, "finish_reason": "length",
                     "text": "", "n_tokens": 5})
            got = []
            while True:
                e = await ts.next()
                got.append(e)
                if e.get("done"):
                    return ts, got
        ts, got = asyncio.run(go())
        assert ts.overflowed and ts.dropped == 3
        assert [e.get("token") for e in got] == [0, 1, None]
        assert got[-1]["done"] and ts.finished

    def test_server_config_validation(self):
        with pytest.raises(ValueError):
            ServerConfig(slow_policy="explode")
        with pytest.raises(ValueError):
            ServerConfig(stream_buffer=0)
        ServerConfig(slow_policy=SLOW_DROP)  # valid


class TestShutdown:
    def test_midflight_close_flushes_and_frees(self):
        """The regression satellite: close() mid-stream must join the
        detok thread, deliver a final 'aborted' event whose text is the
        FULL flush of every token emitted before shutdown, and leave
        zero live slots and zero pool pages (PR 5 no-leak invariant)."""
        def parse(buf, events):
            while b"\n\n" in buf:
                frame, buf = buf.split(b"\n\n", 1)
                if frame.startswith(b"data: "):
                    events.append(json.loads(frame[6:].decode()))
            return buf

        async def go():
            eng = make_engine(n_slots=2, max_len=256, prefix_cache=False)
            srv = EngineServer(eng, ServerConfig(host=HOST, port=0))
            port = await srv.start(aot=False)
            try:
                reader, writer = await asyncio.open_connection(HOST, port)
                writer.write(_request_bytes("POST", "/generate", {
                    "prompt": [1, 2, 3], "max_tokens": 200}))
                await writer.drain()
                status, _ = await _read_head(reader)
                assert status == 200
                events, buf = [], b""
                while len([e for e in events if "token" in e]) < 3:
                    chunk = await reader.read(4096)
                    assert chunk, "stream ended before 3 tokens"
                    buf = parse(buf + chunk, events)
            finally:
                await srv.close()
            # post-close: the handler task flushes the backlog's final
            # events to the still-open connection, then EOF
            while True:
                chunk = await reader.read(4096)
                if not chunk:
                    break
                buf = parse(buf + chunk, events)
            writer.close()
            return srv, eng, events

        srv, eng, events = asyncio.run(go())
        done = events[-1]
        toks = [e for e in events if "token" in e]
        assert done.get("done") and done["finish_reason"] == "aborted"
        assert len(toks) >= 3
        # every token emitted before shutdown reached the stream as text
        assert done["text"] == "".join(e["text"] for e in toks)
        assert done["n_tokens"] == len(toks)
        assert not srv.detok.alive           # backlog thread joined
        assert not srv._tick_thread.is_alive()
        st = eng.stats()
        assert st["live_slots"] == 0 and st["queue_depth"] == 0
        assert eng.pool.used_pages == 0      # no leaked pages
        eng.pool.check()

    def test_close_idempotent_and_empty(self):
        async def go():
            async with serving() as (srv, port, eng):
                await request_json(HOST, port, "GET", "/healthz")
            await srv.close()                # second close: no-op
            return srv
        srv = asyncio.run(go())
        assert not srv.detok.alive and not srv._tick_thread.is_alive()


@pytest.mark.subprocess
class TestServeCLI:
    def test_serve_boot_sse_and_clean_sigint(self, tmp_path):
        """Boot `--serve` in a subprocess, ride the real wire, SIGINT:
        readiness line, streamed tokens, warm stats, clean exit."""
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        env.update({k: v for k, v in os.environ.items()
                    if k.startswith(("JAX_", "XLA_"))})
        env.setdefault("JAX_PLATFORMS", "cpu")
        trace_log = tmp_path / "trace.jsonl"
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "granite-8b", "--reduced", "--serve", "--port", "0",
             "--slots", "2", "--max-len", "48", "--chunk-tokens", "16",
             "--page-tokens", "8", "--stats-interval", "0.5",
             "--trace-log", str(trace_log)],
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            cwd="/root/repo", env=env, text=True)
        try:
            port = None
            t0 = time.time()
            lines = []
            while time.time() - t0 < 300:
                line = proc.stdout.readline()
                if not line:
                    break
                lines.append(line)
                if line.startswith("serving on http://"):
                    port = int(line.split(":")[2].split("/")[0].split()[0])
                    break
            assert port, f"no readiness line: {''.join(lines)}"
            assert "(aot=on)" in lines[-1]   # --serve defaults AOT on

            async def go():
                st, ev, _ = await sse_generate(HOST, port, {
                    "prompt": [1, 2, 3], "max_tokens": 4})
                stats = await request_json(HOST, port, "GET", "/stats")
                metrics = await request_text(HOST, port, "GET", "/metrics")
                return st, ev, stats, metrics
            st, ev, (_, stats), (mst, mtext) = asyncio.run(go())
            assert st == 200
            assert len([e for e in ev if "token" in e]) == 4
            assert ev[-1].get("done")
            assert stats["aot_warm"] is True
            # mid-run /metrics scrape: the exposition is live and the tick
            # histogram actually observed the work we just streamed
            assert mst == 200
            for name in ("serve_requests_submitted_total 1",
                         "serve_tokens_total 4",
                         "# TYPE serve_tick_seconds histogram",
                         "serve_http_request_seconds_count"):
                assert name in mtext, f"missing from /metrics: {name!r}"
            tick_count = int([l for l in mtext.splitlines()
                              if l.startswith("serve_tick_seconds_count")
                              ][0].split()[-1])
            assert tick_count > 0
            # --stats-interval: the periodic one-line report is printing
            t0 = time.time()
            stats_line = None
            while time.time() - t0 < 30 and stats_line is None:
                line = proc.stdout.readline()
                if line.startswith("[stats]"):
                    stats_line = line
            assert stats_line and "tok/s" in stats_line, stats_line
            proc.send_signal(signal.SIGINT)
            out, _ = proc.communicate(timeout=60)
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.communicate()
        assert proc.returncode == 0
        assert "server closed" in out
        assert "Traceback" not in out and "KeyboardInterrupt" not in out
        # --trace-log flushed the ring on shutdown: lifecycle events for
        # the one request we streamed
        events = [json.loads(l)["event"]
                  for l in trace_log.read_text().splitlines()]
        assert events.count("submit") == events.count("finish") == 1
        assert "retrace" not in events

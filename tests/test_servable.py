"""ServableModel contract: the explicit model <-> engine surface.

The engine constructor checks the contract (``ensure_servable``) before
touching anything, so an unsupported model fails with a typed error that
names what's missing AND the menu of servable families — these tests pin
that behavior, the per-family probe values, the cache-family
declarations, and the launch CLI's family dispatch.
"""
import jax.numpy as jnp
import pytest

from repro.configs import build_model, get_config
from repro.nn.context import SERVE, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.servable import (
    REQUIRED_ATTRS,
    SERVABLE_FAMILIES,
    CacheFamily,
    UnservableModelError,
    ensure_servable,
)


def serve_model(arch):
    cfg = get_config(arch).reduced()
    return cfg, build_model(cfg, ModelContext(
        policy=cfg.tbn, mode=SERVE, compute_dtype=jnp.float32,
        use_pallas=False))


class TestContract:
    @pytest.mark.parametrize("arch", [
        "granite-8b", "qwen2-moe-a2.7b", "mamba2-370m",
        "recurrentgemma-2b", "seamless-m4t-large-v2",
    ])
    def test_repo_models_satisfy_contract(self, arch):
        _, m = serve_model(arch)
        assert ensure_servable(m) is m

    def test_probes_decoder_only(self):
        _, m = serve_model("granite-8b")
        assert m.has_full_attn and not m.has_recurrent_state
        assert not m.has_cross_attn

    def test_probes_encdec(self):
        _, m = serve_model("seamless-m4t-large-v2")
        assert m.has_full_attn and not m.has_recurrent_state
        assert m.has_cross_attn

    def test_cache_families_dense(self):
        _, m = serve_model("granite-8b")
        fams = m.cache_families()
        assert fams == (CacheFamily("self_attn", paged=True),)

    def test_cache_families_recurrent(self):
        _, m = serve_model("mamba2-370m")
        names = {f.name: f for f in m.cache_families()}
        assert "recurrent" in names and not names["recurrent"].paged

    def test_cache_families_encdec_cross_is_read_only(self):
        _, m = serve_model("seamless-m4t-large-v2")
        names = {f.name: f for f in m.cache_families()}
        assert names["self_attn"].paged and not names["self_attn"].read_only
        assert names["cross_attn"].paged and names["cross_attn"].read_only

    def test_unservable_lists_missing_and_menu(self):
        class NotAModel:
            pass

        with pytest.raises(UnservableModelError) as ei:
            ensure_servable(NotAModel())
        msg = str(ei.value)
        assert ei.value.missing == REQUIRED_ATTRS
        # the error is a menu, not just a rejection
        for fam in SERVABLE_FAMILIES:
            assert fam in msg
        assert "cache_families" in msg

    def test_unservable_is_a_type_error(self):
        assert issubclass(UnservableModelError, TypeError)

    def test_partial_surface_names_only_whats_missing(self):
        _, m = serve_model("granite-8b")

        class Halfway:
            # forward everything except the snapshot walkers
            def __getattr__(self, name):
                if name in ("snapshot_slot_caches", "restore_slot_caches"):
                    raise AttributeError(name)
                return getattr(m, name)

        with pytest.raises(UnservableModelError) as ei:
            ensure_servable(Halfway())
        assert set(ei.value.missing) == {
            "snapshot_slot_caches", "restore_slot_caches"
        }

    def test_engine_rejects_unservable_model(self):
        class NotAModel:
            pass

        with pytest.raises(UnservableModelError):
            BatchedEngine(NotAModel(), {}, ServeConfig(
                n_slots=1, max_len=16, chunk_tokens=4))


class TestLaunchDispatch:
    def test_help_documents_family_matrix(self, capsys):
        from repro.launch.serve import main

        with pytest.raises(SystemExit) as ei:
            main(["--help"])
        assert ei.value.code == 0
        out = capsys.readouterr().out
        assert "servable model families" in out
        for fam in SERVABLE_FAMILIES:
            assert fam in out

    def test_encdec_rejects_http_front_end(self):
        from repro.launch.serve import main

        with pytest.raises(SystemExit, match="token prompts only"):
            main(["--arch", "seamless-m4t-large-v2", "--reduced", "--serve"])

"""Parity wall for the integer-domain compute paths (kernels/tiled_xnor.py).

The exactness contract is stronger than the float kernels': the integer
accumulators must be BIT-IDENTICAL (assert_array_equal on int32) between

  * the Pallas kernels (interpret mode),
  * their pure-jnp structured twins (the non-Pallas serve path), and
  * the independent ref.py oracles (``jax.lax.population_count`` /
    dense ±1 int32 matmul — different implementations on purpose),

across decode (m in {1, 3, 8}) AND matmul-sized (m = 128) batches, with
word-padded (32 | n_in) and unaligned n_in. Dispatch-level parity pins
``ops.tiled_dense_infer(compute_path=...)``: the Pallas and structured
backends must agree exactly, and compute_path="float" must stay
byte-identical to the historical default. Hypothesis round-trip
properties for the activation quantizers live at the bottom (skipped
when hypothesis is absent, mirroring tests/test_property.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pack_bits, plan_tiling
from repro.kernels.ops import (
    FlatTileLayoutError,
    _dense_unique_local,
    tiled_dense_infer,
)
from repro.kernels.ref import (
    tiled_int8_matvec_ref,
    tiled_xnor_matvec_ref,
)
from repro.kernels.tiled_matvec import sublane_rounded
from repro.kernels.tiled_xnor import (
    COMPUTE_PATHS,
    int8_matvec_packed,
    popcount32,
    quantize_int8,
    quantize_sign,
    tiled_int8_matvec_unique,
    tiled_xnor_matvec_unique,
    xnor_matvec_words,
)

# (n_in, r): word-padded (32 | n_in) and unaligned n_in, r both dividing
# and not dividing the default blocks
INT_SHAPES = [
    (96, 24),      # word-padded, tiny
    (100, 24),     # unaligned n_in (pad bits in the last word)
    (512, 128),    # word-padded, block-sized
    (1500, 300),   # unaligned n_in, r not a block multiple
]
MS = [1, 3, 8, 128]


def _rand_case(seed, m, n_in, r):
    kx, kt = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (m, n_in))
    t = jnp.where(jax.random.bernoulli(kt, 0.5, (r, n_in)), 1.0, -1.0)
    return x, pack_bits(t)                       # (r, ceil(n_in/32))


def _pad(a, axis, mult):
    pad = (-a.shape[axis]) % mult
    if not pad:
        return a
    w = [(0, 0)] * a.ndim
    w[axis] = (0, pad)
    return jnp.pad(a, w)


# --------------------------------------------------------------------------
# kernel vs oracle: bit-identical integer accumulators
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m", MS)
@pytest.mark.parametrize("n_in,r", INT_SHAPES)
def test_xnor_kernel_matches_oracle_exactly(m, n_in, r):
    x, packed = _rand_case(m * 31 + n_in + r, m, n_in, r)
    xq, _ = quantize_sign(x, n_in)
    want = tiled_xnor_matvec_ref(xq, packed, n_in=n_in)
    assert want.dtype == jnp.int32
    # pad exactly the way the ops dispatch does
    bw = min(32, packed.shape[1])
    br = min(256, r)
    xq_p = _pad(_pad(xq, 0, sublane_rounded(m, jnp.int32)), 1, bw)
    tm_p = _pad(_pad(packed, 0, br), 1, bw)
    got = tiled_xnor_matvec_unique(
        xq_p, tm_p, n_in=n_in, block_r=br, block_w=bw, interpret=True
    )[:m, :r]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # structured twin (non-Pallas serve path): same ints, SWAR popcount
    got_words = xnor_matvec_words(xq, packed, n_in=n_in)
    np.testing.assert_array_equal(np.asarray(got_words), np.asarray(want))


@pytest.mark.parametrize("m", MS)
@pytest.mark.parametrize("n_in,r", INT_SHAPES)
def test_int8_kernel_matches_oracle_exactly(m, n_in, r):
    x, packed = _rand_case(m * 37 + n_in + 2 * r, m, n_in, r)
    q, _ = quantize_int8(x, n_in)
    want = tiled_int8_matvec_ref(q, packed, n_in=n_in)
    assert want.dtype == jnp.int32
    words = packed.shape[1]
    bk = min(1024, words * 32)
    br = min(256, r)
    q_p = jnp.pad(q, ((0, 0), (0, words * 32 - n_in)))
    q_p = _pad(_pad(q_p, 0, sublane_rounded(m, jnp.int8)), 1, bk)
    tm_p = _pad(_pad(packed, 0, br), 1, bk // 32)
    got = tiled_int8_matvec_unique(
        q_p, tm_p, r=tm_p.shape[0], block_r=br, block_k=bk, interpret=True
    )[:m, :r]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    got_packed = int8_matvec_packed(q, packed, n_in=n_in)
    np.testing.assert_array_equal(np.asarray(got_packed), np.asarray(want))


def test_popcount32_matches_lax_population_count():
    v = jax.random.randint(
        jax.random.PRNGKey(0), (64, 17), minval=jnp.iinfo(jnp.int32).min,
        maxval=jnp.iinfo(jnp.int32).max, dtype=jnp.int32
    )
    want = jax.lax.population_count(v.astype(jnp.uint32)).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(popcount32(v)), np.asarray(want))


# --------------------------------------------------------------------------
# dispatch parity: ops._dense_unique_local / tiled_dense_infer
# --------------------------------------------------------------------------
@pytest.mark.parametrize("compute_path", ["xnor", "int8"])
@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("n_in,r", [(100, 24), (512, 128)])
def test_int_dispatch_pallas_equals_structured(compute_path, m, n_in, r):
    """Both backends quantize identically and share the exact integer
    accumulator, so u agrees to the float (not allclose-level)."""
    x, packed = _rand_case(m + n_in + r, m, n_in, r)
    kw = dict(n_in=n_in, block_m=128, block_r=128, block_k=512,
              compute_path=compute_path)
    got_pl = _dense_unique_local(x, packed, use_pallas=True, **kw)
    got_ref = _dense_unique_local(x, packed, use_pallas=False, **kw)
    np.testing.assert_array_equal(np.asarray(got_pl), np.asarray(got_ref))


@pytest.mark.parametrize("compute_path", ["xnor", "int8"])
def test_tiled_dense_infer_integer_path_end_to_end(compute_path):
    """Full wrapper: quantize + integer kernel + scale + alpha broadcast
    equals the hand-computed expectation from the oracle accumulator."""
    spec = plan_tiling((256, 100), p=4, min_size=1, alpha_source="W")
    r, n_in = spec.rows_per_tile, 100
    x = jax.random.normal(jax.random.PRNGKey(5), (4, n_in))
    t = jnp.where(
        jax.random.bernoulli(jax.random.PRNGKey(6), 0.5, (r, n_in)), 1.0, -1.0
    )
    rows = pack_bits(t)
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (4,))) + 0.1
    got = tiled_dense_infer(x, rows, alpha, spec, use_pallas=True,
                            compute_path=compute_path)
    if compute_path == "xnor":
        xq, scale = quantize_sign(x, n_in)
        acc = tiled_xnor_matvec_ref(xq, rows, n_in=n_in)
    else:
        q, scale = quantize_int8(x, n_in)
        acc = tiled_int8_matvec_ref(q, rows, n_in=n_in)
    u = scale * acc.astype(jnp.float32)          # (4, r)
    want = (u[:, None, :] * alpha[None, :, None]).reshape(4, 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # the structured backend must produce the same floats
    got_ref = tiled_dense_infer(x, rows, alpha, spec, use_pallas=False,
                                compute_path=compute_path)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got_ref))


def test_float_path_unchanged_by_compute_path_arg():
    """compute_path="float" (and the default) is byte-identical to the
    historical call — the integer paths ride beside it, not through it."""
    spec = plan_tiling((256, 64), p=4, min_size=1, alpha_source="W")
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    t = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), 0.5,
                                       (spec.rows_per_tile, 64)), 1.0, -1.0)
    rows = pack_bits(t)
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (4,))) + 0.1
    base = tiled_dense_infer(x, rows, alpha, spec, use_pallas=True)
    expl = tiled_dense_infer(x, rows, alpha, spec, use_pallas=True,
                             compute_path="float")
    np.testing.assert_array_equal(np.asarray(base), np.asarray(expl))


def test_prefill_m_falls_back_to_float():
    """Above MATVEC_MAX_M the integer knob is a no-op (prefill keeps the
    MXU float path) — documented fallback, not an error."""
    from repro.kernels import MATVEC_MAX_M

    spec = plan_tiling((256, 64), p=4, min_size=1, alpha_source="W")
    m = MATVEC_MAX_M + 4
    x = jax.random.normal(jax.random.PRNGKey(3), (m, 64))
    t = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(4), 0.5,
                                       (spec.rows_per_tile, 64)), 1.0, -1.0)
    rows = pack_bits(t)
    alpha = jnp.ones((4,))
    got = tiled_dense_infer(x, rows, alpha, spec, use_pallas=False,
                            compute_path="xnor")
    want = tiled_dense_infer(x, rows, alpha, spec, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unknown_compute_path_rejected():
    spec = plan_tiling((64, 32), p=4, min_size=1, alpha_source="W")
    x = jnp.ones((2, 32))
    rows = pack_bits(jnp.ones((spec.rows_per_tile, 32)))
    with pytest.raises(ValueError, match="compute_path"):
        tiled_dense_infer(x, rows, jnp.ones((4,)), spec,
                          use_pallas=False, compute_path="fp4")
    assert "float" in COMPUTE_PATHS


# --------------------------------------------------------------------------
# satellite regressions: sublane table + flat-form layout validation
# --------------------------------------------------------------------------
def test_sublane_rounded_per_dtype_table():
    assert sublane_rounded(1, jnp.float32) == 8
    assert sublane_rounded(9, jnp.float32) == 16
    assert sublane_rounded(1, jnp.bfloat16) == 16
    assert sublane_rounded(1, jnp.int32) == 8     # 4-byte dtypes tile alike
    # the old `8 if f32 else 16` returned 16 here — int8 tiles need 32
    assert sublane_rounded(1, jnp.int8) == 32
    assert sublane_rounded(33, jnp.int8) == 64
    with pytest.raises(ValueError, match="sublane"):
        sublane_rounded(4, jnp.float64)


def test_flat_form_unaligned_n_in_raises_layout_error():
    """Flat tile + 32∤n_in on the Pallas path: a typed error naming the
    layout requirement, not a cryptic reshape failure."""
    spec = plan_tiling((64, 48), p=4, min_size=1, alpha_source="W")
    n_in = 48
    assert n_in % 32 != 0
    x = jnp.ones((2, n_in))
    flat = pack_bits(jnp.ones((spec.q,)))        # flat (ceil(q/32),) form
    alpha = jnp.ones((spec.n_alpha,))
    with pytest.raises(FlatTileLayoutError, match="row-packed"):
        tiled_dense_infer(x, flat, alpha, spec, use_pallas=True)
    # the non-Pallas flat path doesn't reshape and keeps working
    y = tiled_dense_infer(x, flat, alpha, spec, use_pallas=False)
    assert y.shape == (2, 64)


# --------------------------------------------------------------------------
# hypothesis: activation-quantization round-trip properties
# (guarded per-class so the parity wall above still runs when hypothesis
# is absent — unlike test_property.py this module mixes both kinds)
# --------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=40, deadline=None)

    finite_rows = st.tuples(
        st.integers(1, 6),                       # m
        st.integers(1, 80),                      # n_in
        st.integers(0, 2**31 - 1),               # seed
    )

    class TestQuantizeRoundTrip:
        @given(finite_rows)
        @settings(**SETTINGS)
        def test_int8_round_trip_error_bounded(self, case):
            """|x - q*scale| <= scale/2 per element (symmetric rounding),
            q stays in [-127, 127], an exact-zero row maps to q=0."""
            m, n_in, seed = case
            x = jax.random.normal(jax.random.PRNGKey(seed), (m, n_in))
            q, scale = quantize_int8(x, n_in)
            assert q.dtype == jnp.int8
            assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127
            err = np.abs(np.asarray(x) - np.asarray(q, np.float32)
                         * np.asarray(scale))
            bound = np.asarray(scale) * (0.5 + 1e-5)
            assert (err <= bound + 1e-7).all()
            qz, sz = quantize_int8(jnp.zeros((1, n_in)), n_in)
            assert not np.asarray(qz).any() and float(sz[0, 0]) == 1.0

        @given(finite_rows)
        @settings(**SETTINGS)
        def test_sign_pack_round_trip(self, case):
            """Unpacking the sign-packed words recovers sign(x) exactly;
            the packed form is invariant to positive rescaling of x."""
            from repro.core.packing import unpack_bits

            m, n_in, seed = case
            x = jax.random.normal(jax.random.PRNGKey(seed), (m, n_in))
            xq, scale = quantize_sign(x, n_in)
            signs = unpack_bits(xq, n_in, dtype=jnp.float32)
            np.testing.assert_array_equal(
                np.asarray(signs), np.where(np.asarray(x) > 0, 1.0, -1.0)
            )
            np.testing.assert_allclose(
                np.asarray(scale)[:, 0],
                np.abs(np.asarray(x)).mean(axis=1), rtol=1e-6,
            )
            xq2, _ = quantize_sign(3.5 * x, n_in)
            np.testing.assert_array_equal(np.asarray(xq), np.asarray(xq2))
else:
    def test_quantize_round_trip_requires_hypothesis():
        pytest.skip("hypothesis not installed")

"""Unit tests for the TBN core transform (Eqs. 1-9 of the paper)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    compute_alpha,
    construct_binary,
    export_tile,
    fold_inputs_reference,
    plan_tiling,
    reconstruct_from_tile,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)

jax.config.update("jax_enable_x64", False)


def spec(shape, p, **kw):
    s = plan_tiling(shape, p=p, min_size=1, **kw)
    assert s is not None
    return s


class TestPlanning:
    def test_basic_divisible(self):
        s = spec((8, 16), 4)
        assert s.p == 4 and s.q == 32 and s.aligned_rows

    def test_lambda_policy_blocks_small_layers(self):
        assert plan_tiling((8, 16), p=4, min_size=64_000) is None

    def test_p_not_dividing_n_falls_back_to_divisor(self):
        # N = 96, p=5 does not divide -> largest divisor <= 5 is 4
        s = plan_tiling((6, 16), p=5, min_size=1)
        assert s.p == 4

    def test_unaligned_detected(self):
        s = plan_tiling((6, 16), p=4, min_size=1)  # 4 does not divide 6
        assert s is not None and not s.aligned_rows

    def test_require_aligned_rejects(self):
        assert plan_tiling((6, 16), p=4, min_size=1, require_aligned=True) is None

    def test_stored_bits(self):
        s = spec((8, 16), 4, alpha_mode="tile")
        assert s.stored_bits == 32 + 32 * 4
        s = spec((8, 16), 4, alpha_mode="layer")
        assert s.stored_bits == 32 + 32
        assert s.bits_per_param == (32 + 32) / 128


class TestConstruction:
    def test_tile_replication_structure(self):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (8, 16))
        s = spec((8, 16), 4)
        b = construct_binary(w, s)
        flat = np.asarray(b).reshape(4, 32)
        for i in range(1, 4):
            np.testing.assert_array_equal(flat[0], flat[i])
        assert set(np.unique(flat)) <= {-1.0, 1.0}

    def test_tile_matches_sign_of_columnsum(self):
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
        s = spec((4, 8), 2)
        t = tile_vector(w, s)
        expected = np.where(np.asarray(w).reshape(2, 16).sum(0) > 0, 1.0, -1.0)
        np.testing.assert_array_equal(np.asarray(t), expected)

    def test_sign_zero_maps_to_minus_one(self):
        w = jnp.zeros((4, 8))
        s = spec((4, 8), 2)
        assert np.all(np.asarray(tile_vector(w, s)) == -1.0)

    def test_alpha_layer_eq7(self):
        w = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
        s = spec((4, 8), 2, alpha_mode="layer")
        a = compute_alpha(w, s)
        np.testing.assert_allclose(
            np.asarray(a), np.abs(np.asarray(w)).mean(), rtol=1e-6
        )

    def test_alpha_tile_eq9(self):
        w = jax.random.normal(jax.random.PRNGKey(3), (4, 8))
        s = spec((4, 8), 2, alpha_mode="tile")
        a = np.asarray(compute_alpha(w, s))
        wf = np.abs(np.asarray(w).reshape(2, 16))
        np.testing.assert_allclose(a, wf.mean(axis=1), rtol=1e-6)

    def test_tiled_weight_equals_reconstruct(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (16, 8))
        a_param = jax.random.normal(jax.random.PRNGKey(5), (16, 8))
        s = spec((16, 8), 4, alpha_mode="tile", alpha_source="A")
        bhat = tiled_weight(w, s, a=a_param)
        t, alpha = export_tile(w, s, a=a_param)
        np.testing.assert_allclose(
            np.asarray(bhat), np.asarray(reconstruct_from_tile(t, alpha, s)), rtol=1e-6
        )

    def test_compression_invariant_unique_values(self):
        """Property: B_hat restricted to tile i is alpha_i * t — only q
        distinct magnitudes per tile."""
        w = jax.random.normal(jax.random.PRNGKey(6), (32, 32))
        s = spec((32, 32), 8, alpha_mode="tile", alpha_source="W")
        bhat = np.asarray(tiled_weight(w, s))
        flat = bhat.reshape(8, 128)
        t = np.asarray(tile_vector(w, s))
        alpha = np.asarray(compute_alpha(w, s))
        for i in range(8):
            np.testing.assert_allclose(flat[i], alpha[i] * t, rtol=1e-6)


class TestGradients:
    def test_identity_ste_passes_grad_through(self):
        w = jax.random.normal(jax.random.PRNGKey(7), (8, 8))
        s = spec((8, 8), 4, ste="identity")
        g = jax.grad(lambda w: (construct_binary(w, s) * 2.0).sum())(w)
        np.testing.assert_allclose(np.asarray(g), 2.0 * np.ones((8, 8)), rtol=1e-6)

    def test_autodiff_ste_sums_replica_grads(self):
        w = jax.random.normal(jax.random.PRNGKey(8), (8, 8))
        s = spec((8, 8), 4, ste="autodiff")
        # dL/dB = B (for L = 0.5*sum(B^2) = const, use L = sum(B * C))
        c = jax.random.normal(jax.random.PRNGKey(9), (8, 8))
        g = jax.grad(lambda w: (construct_binary(w, s) * c).sum())(w)
        # every master element in tile-slot j receives sum_i c*[i, j]
        csum = np.asarray(c).reshape(4, 16).sum(0)
        expected = np.broadcast_to(csum, (4, 16)).reshape(8, 8)
        np.testing.assert_allclose(np.asarray(g), expected, rtol=1e-5)

    def test_alpha_grad_flows_to_A(self):
        w = jax.random.normal(jax.random.PRNGKey(10), (8, 8))
        a = jax.random.normal(jax.random.PRNGKey(11), (8, 8))
        s = spec((8, 8), 2, alpha_source="A")
        ga = jax.grad(lambda a: tiled_weight(w, s, a=a).sum())(a)
        assert np.abs(np.asarray(ga)).sum() > 0

    def test_train_step_reduces_loss_on_tiny_regression(self):
        """End-to-end sanity: TBN layer trained with SGD fits better than init."""
        key = jax.random.PRNGKey(12)
        k1, k2, k3 = jax.random.split(key, 3)
        x = jax.random.normal(k1, (64, 16))
        w_true = jax.random.normal(k2, (16, 16))
        y = x @ w_true.T
        s = spec((16, 16), 2, alpha_source="W", alpha_mode="tile")

        def loss(w):
            yhat = x @ tiled_weight(w, s).T
            return jnp.mean((yhat - y) ** 2)

        w = jax.random.normal(k3, (16, 16)) * 0.1
        l0 = loss(w)
        step = jax.jit(lambda w: w - 0.05 * jax.grad(loss)(w))
        for _ in range(150):
            w = step(w)
        assert loss(w) < l0 * 0.9


class TestStructuredFastMath:
    @pytest.mark.parametrize("alpha_mode", ["layer", "tile"])
    def test_tiled_matmul_reference_matches_dense(self, alpha_mode):
        key = jax.random.PRNGKey(13)
        kx, kw = jax.random.split(key)
        n_out, n_in, p = 24, 8, 4
        x = jax.random.normal(kx, (5, n_in))
        w = jax.random.normal(kw, (n_out, n_in))
        s = spec((n_out, n_in), p, alpha_mode=alpha_mode, alpha_source="W")
        t, alpha = export_tile(w, s)
        dense = x @ reconstruct_from_tile(t, alpha, s).T
        fast = tiled_matmul_reference(x, t, alpha, s)
        np.testing.assert_allclose(np.asarray(fast), np.asarray(dense), rtol=1e-5)

    @pytest.mark.parametrize("alpha_mode", ["layer", "tile"])
    def test_fold_inputs_reference_matches_dense(self, alpha_mode):
        key = jax.random.PRNGKey(14)
        kx, kw = jax.random.split(key)
        n_in, n_out, p = 24, 8, 4  # weight stored (n_in, n_out)
        x = jax.random.normal(kx, (5, n_in))
        w = jax.random.normal(kw, (n_in, n_out))
        s = spec((n_in, n_out), p, alpha_mode=alpha_mode, alpha_source="W")
        t, alpha = export_tile(w, s)
        dense = x @ reconstruct_from_tile(t, alpha, s)
        fast = fold_inputs_reference(x, t, alpha, s)
        np.testing.assert_allclose(
            np.asarray(fast), np.asarray(dense), rtol=1e-4, atol=1e-5
        )


class TestPacking:
    @pytest.mark.parametrize("q", [1, 31, 32, 33, 64, 1000])
    def test_roundtrip(self, q):
        from repro.core import pack_bits, unpack_bits

        t = np.sign(np.random.RandomState(q).randn(q))
        t[t == 0] = 1.0
        packed = pack_bits(jnp.asarray(t))
        assert packed.dtype == jnp.int32
        out = np.asarray(unpack_bits(packed, q))
        np.testing.assert_array_equal(out, t)

    def test_numpy_twin_matches(self):
        from repro.core import pack_bits, pack_bits_np

        t = np.sign(np.random.RandomState(0).randn(130))
        t[t == 0] = 1.0
        np.testing.assert_array_equal(
            np.asarray(pack_bits(jnp.asarray(t))), pack_bits_np(t)
        )

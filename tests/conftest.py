"""Suite-wide fixtures.

The suite compiles hundreds of distinct executables across its modules
(every engine config shape is its own pjit program). Left to accumulate
in one process, the XLA JIT eventually faults on a late fresh compile —
deterministically, on CPU, long before memory is exhausted. Clearing
JAX's compilation caches at each module boundary bounds the resident
executable set to one module's worth; modules that share an
`lru_cache`d model still reuse it within the module, and the handful of
cross-module recompiles cost seconds against a ~10-minute wall.
"""
from __future__ import annotations

import gc

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    yield
    jax.clear_caches()
    gc.collect()

"""Telemetry test wall (repro.serve.telemetry + its engine/server wiring).

The load-bearing property: telemetry is OBSERVATION ONLY. With it on
(metrics + spans + trace ring) or off, the engine emits byte-identical
tokens — greedy and stochastic — pinned here as a parity wall. On top
of that, the numeric layer is held to references: histogram counts and
quantiles against numpy, the Prometheus exposition against a format
lint (cumulative buckets, +Inf == _count, HELP/TYPE per family), span
phase attribution against the wall clock (disjoint phases sum to the
request's wall time, across preemption parks and encdec ENCODE), and
the steady-state retrace detector against both a forced retrace (must
fire, warn once) and a clean post-warmup run (must stay silent).
"""
import asyncio
import functools
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.weights import export_serving_params
from repro.serve.telemetry import (
    DECODE,
    DURATION_BUCKETS,
    ENCODE,
    PARKED,
    PREFILL,
    QUEUE,
    TICK_PHASES,
    EngineTelemetry,
    Histogram,
    MetricsRegistry,
    RequestSpan,
    TraceRing,
    log_buckets,
)

KEY = jax.random.PRNGKey(0)


def _export(arch):
    cfg = get_config(arch).reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), KEY)
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    return cfg, sm, sp


@functools.lru_cache(maxsize=None)
def build_serve(arch="granite-8b"):
    return _export(arch)


def make_engine(**cfg_kw):
    _, sm, sp = build_serve()
    kw = dict(n_slots=2, max_len=64, chunk_tokens=8, page_tokens=8)
    kw.update(cfg_kw)
    return BatchedEngine(sm, sp, ServeConfig(**kw))


def drain(eng, reqs):
    i = 0
    while eng.has_work:
        assert i < 2000, "engine wedged"
        eng.step()
        i += 1
    return [list(r.output) for r in reqs]


# ---------------------------------------------------------------------
# histogram / registry numerics


class TestHistogram:
    def test_counts_and_sum_match_numpy(self):
        rng = np.random.default_rng(0)
        vals = rng.lognormal(mean=-6, sigma=2, size=2000)
        h = Histogram(edges=DURATION_BUCKETS)
        for v in vals:
            h.observe(float(v))
        assert h.count == len(vals)
        assert h.sum == pytest.approx(float(np.sum(vals)))
        # per-bucket counts == numpy histogram over the same edges
        # (bucket i holds v <= edges[i], first bucket [0, edges[0]])
        edges = np.array((0.0,) + DURATION_BUCKETS + (np.inf,))
        ref, _ = np.histogram(vals, bins=edges)
        # np.histogram is right-exclusive, ours is right-INclusive; the
        # lognormal draw never lands exactly on an edge, so they agree
        assert h.counts == ref.tolist()

    @pytest.mark.parametrize("q", [0.1, 0.5, 0.9, 0.99])
    def test_quantile_within_one_bucket_of_numpy(self, q):
        rng = np.random.default_rng(1)
        vals = rng.lognormal(mean=-4, sigma=1.5, size=5000)
        h = Histogram(edges=DURATION_BUCKETS)
        for v in vals:
            h.observe(float(v))
        est, ref = h.quantile(q), float(np.quantile(vals, q))
        # bucket-interpolated estimate is accurate to one bucket width;
        # edges grow by 10^(1/6) per bucket
        growth = 10 ** (1 / 6)
        assert ref / growth <= est <= ref * growth, (q, est, ref)

    def test_empty_and_overflow(self):
        h = Histogram(edges=(1.0, 10.0))
        assert h.quantile(0.5) is None
        h.observe(5000.0)  # beyond the last edge -> +Inf bucket
        assert h.counts == [0, 0, 1]
        assert h.quantile(0.5) == 10.0  # clamped to last finite edge

    def test_log_buckets_shape(self):
        edges = log_buckets(1e-3, 1.0, per_decade=3)
        assert edges[0] == 1e-3
        assert list(edges) == sorted(set(edges))
        assert len(edges) == 10  # 3 decades * 3 + endpoint
        with pytest.raises(ValueError):
            log_buckets(0, 1.0)


class TestRegistry:
    def test_counter_gauge_labels_and_values(self):
        r = MetricsRegistry()
        c = r.counter("t_total", "help", labels=("kind",))
        c.labels(kind="a").inc()
        c.labels(kind="a").inc(2)
        c.labels(kind="b").inc()
        g = r.gauge("t_gauge", fn=lambda: 42)
        assert r.value_of("t_total", kind="a") == 3
        assert r.value_of("t_total", kind="b") == 1
        assert r.value_of("t_total", kind="zzz") is None
        assert g.get() == 42

    def test_reregistration_idempotent_and_conflict(self):
        r = MetricsRegistry()
        a = r.counter("x_total", labels=("k",))
        assert r.counter("x_total", labels=("k",)) is a
        with pytest.raises(ValueError):
            r.gauge("x_total")
        with pytest.raises(ValueError):
            r.counter("x_total", labels=("other",))
        with pytest.raises(ValueError):
            r.counter("bad name")

    def test_exposition_format_lint(self):
        """render() must be parseable Prometheus text: TYPE per family,
        cumulative non-decreasing buckets, +Inf bucket == _count."""
        r = MetricsRegistry()
        r.counter("lint_total", "a counter").inc(3)
        r.gauge("lint_gauge", "a gauge").set(1.5)
        h = r.histogram("lint_seconds", "a histogram", labels=("phase",))
        for i in range(50):
            h.labels(phase="p").observe(10 ** ((i % 9) - 5))
        text = r.render()
        assert text.endswith("\n")
        types, samples = {}, {}
        for line in text.splitlines():
            if line.startswith("# TYPE "):
                _, _, name, t = line.split()
                types[name] = t
            elif not line.startswith("#"):
                key, _, val = line.rpartition(" ")
                float(val)  # every sample value parses
                samples[key] = float(val)
        assert types == {"lint_total": "counter", "lint_gauge": "gauge",
                         "lint_seconds": "histogram"}
        assert samples["lint_total"] == 3
        assert samples["lint_gauge"] == 1.5
        buckets = [(k, v) for k, v in samples.items()
                   if k.startswith("lint_seconds_bucket")]
        cums = [v for _, v in buckets]
        assert cums == sorted(cums), "buckets must be cumulative"
        assert 'le="+Inf"' in buckets[-1][0]
        assert buckets[-1][1] == samples['lint_seconds_count{phase="p"}'] == 50

    def test_label_escaping(self):
        r = MetricsRegistry()
        r.counter("esc_total", labels=("k",)).labels(k='a"b\nc\\d').inc()
        line = [l for l in r.render().splitlines()
                if l.startswith("esc_total{")][0]
        assert line == 'esc_total{k="a\\"b\\nc\\\\d"} 1'


# ---------------------------------------------------------------------
# spans + trace ring (pure, no engine)


class TestSpanAndRing:
    def test_phases_disjoint_and_cover_wall(self):
        s = RequestSpan(rid=1, now=100.0)
        s.mark_admit(101.0, PREFILL)     # 1s queued
        s.to_phase(DECODE, 101.5)        # 0.5s prefill
        s.to_phase(PARKED, 102.0)        # 0.5s decode
        s.to_phase(DECODE, 103.0)        # 1s parked
        s.finish(103.25, "length")       # 0.25s decode
        assert s.phases == {QUEUE: 1.0, PREFILL: 0.5,
                            DECODE: 0.75, PARKED: 1.0}
        assert sum(s.phases.values()) == pytest.approx(s.wall) == 3.25

    def test_token_marks_first(self):
        s = RequestSpan(rid=0, now=0.0)
        assert s.token(1.0) is True
        assert s.token(2.0) is False
        assert (s.first_token_t, s.last_token_t) == (1.0, 2.0)

    def test_ring_drops_oldest_and_counts(self):
        ring = TraceRing(capacity=3)
        for i in range(5):
            ring.emit("e", i=i)
        assert len(ring) == 3 and ring.dropped == 2
        assert [r["i"] for r in ring.drain()] == [2, 3, 4]
        assert len(ring) == 0

    def test_ring_jsonl_sink(self, tmp_path):
        ring = TraceRing(capacity=8)
        ring.emit("submit", rid=1)
        ring.emit("finish", rid=1, reason="length")
        path = tmp_path / "trace.jsonl"
        assert ring.write_jsonl(path) == 2
        recs = [json.loads(l) for l in path.read_text().splitlines()]
        assert [r["event"] for r in recs] == ["submit", "finish"]
        assert all("ts" in r for r in recs)


# ---------------------------------------------------------------------
# engine wiring


class TestEngineTelemetry:
    def test_token_parity_on_vs_off(self):
        """The wall: byte-identical stochastic tokens with telemetry
        (metrics + spans + ring) on vs off, prefix cache exercised."""
        rng = np.random.default_rng(2)
        prompts = [[int(t) for t in rng.integers(0, 64, size=n)]
                   for n in (5, 11, 7, 9)]
        params = [SamplingParams(max_tokens=6, temperature=0.9, top_k=8,
                                 seed=50 + i) for i in range(len(prompts))]
        outs = {}
        for on in (False, True):
            eng = make_engine(telemetry=on, prefix_cache=True,
                              trace_events=64 if on else 0)
            outs[on] = drain(eng, [eng.submit(p, sp)
                                   for p, sp in zip(prompts, params)])
        assert outs[True] == outs[False]

    def test_lifecycle_metrics_and_spans(self):
        eng = make_engine(trace_events=64)
        reqs = [eng.submit([1, 2, 3, 4, 5], SamplingParams(max_tokens=4))
                for _ in range(3)]
        drain(eng, reqs)
        tel = eng.tel
        assert tel.submitted.get() == 3
        assert tel.finished.labels(reason="length").get() == 3
        assert tel.tokens.get() == 12
        assert tel.ttft._solo().count == 3   # one first token per request
        assert tel.itl._solo().count == 9    # 3 tokens after the first, each
        assert tel.tick._solo().count > 0
        observed = {p for p, h in tel.tick_phase.items() if h.count}
        assert {"admission", "decode_device", "decode_host"} <= observed
        assert "encode" not in observed      # decoder-only: never charged
        # spans: disjoint phases cover [submit, finish] for every request
        for r in reqs:
            s = r.span
            assert s.finish_reason == "length"
            assert set(s.phases) <= {QUEUE, PREFILL, DECODE}
            assert sum(s.phases.values()) <= s.wall + 1e-6
            assert sum(s.phases.values()) == pytest.approx(s.wall, rel=1e-3)
        events = [e["event"] for e in eng.tel.ring.drain()]
        assert events.count("submit") == events.count("finish") == 3
        # stats() surfaces the quantile summary
        st = eng.stats()
        assert st["latency"]["ttft_ms"]["count"] == 3
        assert st["retraces"] == 0

    def test_span_phases_across_preemption(self):
        """A preempted request's span charges its parked time to PARKED
        and still covers its wall."""
        eng = make_engine(n_slots=1, priorities=True, preempt=True,
                          max_queued=8)
        lo = eng.submit([1, 2, 3], SamplingParams(
            max_tokens=8, priority="batch"))
        for _ in range(3):
            eng.step()  # batch request admitted and decoding
        hi = eng.submit([4, 5], SamplingParams(
            max_tokens=2, priority="interactive"))
        drain(eng, [lo, hi])
        assert eng.tel.preempts.get() >= 1
        assert eng.tel.resumes.get() >= 1
        s = lo.span
        assert s.phases.get(PARKED, 0.0) > 0.0
        assert sum(s.phases.values()) == pytest.approx(s.wall, rel=1e-3)
        assert list(lo.output) and list(hi.output)

    def test_span_encode_phase_encdec(self):
        """Encoder-decoder admission charges span time to ENCODE and the
        tick breakdown records the encode phase."""
        cfg, sm, sp = build_serve("seamless-m4t-large-v2")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=64, chunk_tokens=8, page_tokens=8,
            enc_tokens=16))
        frames = np.random.default_rng(3).standard_normal(
            (9, cfg.d_model)).astype(np.float32)
        req = eng.submit([3, 1, 4], SamplingParams(max_tokens=3),
                         frames=frames)
        drain(eng, [req])
        assert eng.tel.encode_ticks.get() == 1
        assert eng.tel.tick_phase["encode"].count == 1
        s = req.span
        assert s.phases.get(ENCODE, 0.0) > 0.0
        assert sum(s.phases.values()) == pytest.approx(s.wall, rel=1e-3)

    def test_pool_and_queue_gauges(self):
        eng = make_engine(prefix_cache=True)
        r = eng.tel.registry
        assert r.value_of("serve_pool_pages", family="self_attn") == \
            eng.pool.n_pages
        assert r.value_of("serve_pool_utilization", family="self_attn") == 0.0
        reqs = [eng.submit([1, 2, 3, 4], SamplingParams(max_tokens=2))
                for _ in range(2)]
        assert r.value_of("serve_queue_depth") == 2
        eng.step()
        assert r.value_of("serve_queue_depth") == 0
        assert r.value_of("serve_live_slots") == 2
        assert r.value_of("serve_pool_utilization", family="self_attn") > 0.0
        drain(eng, reqs)
        lookups = (r.value_of("serve_prefix_lookups_total", result="hit")
                   + r.value_of("serve_prefix_lookups_total", result="miss"))
        assert lookups == 2  # one trie lookup per admission

    def test_telemetry_off_is_off(self):
        eng = make_engine(telemetry=False)
        assert eng.tel is None
        req = eng.submit([1, 2, 3], SamplingParams(max_tokens=2))
        drain(eng, [req])
        assert req.span is None
        assert "latency" not in eng.stats()

    def test_trace_events_requires_telemetry(self):
        with pytest.raises(ValueError):
            make_engine(telemetry=False, trace_events=16)


class TestRetraceDetector:
    """Needs a FRESH model per engine: the lazy jitted tick callables
    cache on the model object, so the lru-cached suite model would
    already hold traces for these shapes and mask the forced retrace."""

    def _fresh_engine(self):
        _, sm, sp = _export("granite-8b")
        return BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=64, chunk_tokens=8, page_tokens=8))

    def test_silent_after_warmup_and_fires_on_forced_retrace(self):
        eng = self._fresh_engine()
        eng.warmup()
        reqs = [eng.submit([1, 2, 3], SamplingParams(max_tokens=3))
                for _ in range(2)]
        with warnings.catch_warnings():
            # a clean post-warmup run must never trace: any retrace
            # warning here is the regression the detector exists for
            warnings.simplefilter("error")
            drain(eng, reqs)
        assert eng.tel.retraces.get() == 0

        # force a retrace: drop the AOT decode executable so the tick
        # falls back to the lazy jit, AND clear jax's tracing caches
        # (warmup's .lower() seeded them, so the fallback alone would
        # reuse the cached jaxpr without re-running the Python body) —
        # the next decode tick genuinely re-traces
        eng._aot.pop("decode_tick")
        jax.clear_caches()
        req = eng.submit([4, 5], SamplingParams(max_tokens=2))
        with pytest.warns(RuntimeWarning, match="retrace"):
            drain(eng, [req])
        n = eng.tel.retraces.get()
        assert n >= 1
        assert eng.stats()["retraces"] == n

        # warn-once: further retraced ticks count but stay quiet
        eng._aot.pop("extend_tick")
        req = eng.submit([6, 7, 8, 9], SamplingParams(max_tokens=2))
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            drain(eng, [req])
        assert eng.tel.retraces.get() > n


# ---------------------------------------------------------------------
# server exposition (in-process)


class TestMetricsEndpoint:
    def test_metrics_endpoint_and_http_histogram(self):
        from repro.serve.client import request_json, request_text, sse_generate
        from repro.serve.server import EngineServer, ServerConfig

        async def go():
            eng = make_engine()
            srv = EngineServer(eng, ServerConfig(host="127.0.0.1", port=0))
            port = await srv.start(aot=False)
            try:
                await sse_generate("127.0.0.1", port, {
                    "prompt": [1, 2, 3], "max_tokens": 3})
                status, text = await request_text(
                    "127.0.0.1", port, "GET", "/metrics")
                _, stats = await request_json(
                    "127.0.0.1", port, "GET", "/stats")
            finally:
                await srv.close()
            return status, text, stats

        status, text, stats = asyncio.run(go())
        assert status == 200
        for name in ("serve_requests_submitted_total 1",
                     "serve_tokens_total 3",
                     "# TYPE serve_tick_seconds histogram",
                     "# TYPE serve_http_request_seconds histogram",
                     'serve_http_request_seconds_count{route="/generate"} 1',
                     "serve_streams_opened_total 1"):
            assert name in text, f"missing from /metrics: {name!r}"
        for phase in TICK_PHASES:
            assert f'serve_tick_phase_seconds_count{{phase="{phase}"}}' \
                in text
        # enriched /stats carries the same quantile summary + http route
        assert stats["latency"]["ttft_ms"]["count"] == 1
        assert stats["latency"]["http_ms"]["/generate"]["count"] == 1

    def test_metrics_404_when_disabled(self):
        from repro.serve.client import request_text
        from repro.serve.server import EngineServer, ServerConfig

        async def go():
            eng = make_engine(telemetry=False)
            srv = EngineServer(eng, ServerConfig(host="127.0.0.1", port=0))
            port = await srv.start(aot=False)
            try:
                return await request_text("127.0.0.1", port, "GET",
                                          "/metrics")
            finally:
                await srv.close()

        status, body = asyncio.run(go())
        assert status == 404
        assert json.loads(body)["error"] == "telemetry_disabled"


class TestLoadgenScrapeHelpers:
    def test_parse_and_check_metrics(self):
        from benchmarks.loadgen import (
            REQUIRED_METRICS,
            check_metrics,
            parse_metrics,
            server_quantiles,
        )

        tel = EngineTelemetry()
        r = tel.registry
        # fill in the front-end families check_metrics requires
        r.histogram("serve_http_request_seconds", labels=("route",))
        r.counter("serve_streams_opened_total")
        r.gauge("serve_queue_depth", fn=lambda: 0)
        r.gauge("serve_live_slots", fn=lambda: 0)
        before = parse_metrics(r.render())
        tel.submitted.inc(4)
        tel.tokens.inc(40)
        for i in range(10):
            tel.tick.observe(0.002 * (i + 1))
            tel.ttft.observe(0.05)
            tel.itl.observe(0.002)
        after = parse_metrics(r.render())
        assert set(REQUIRED_METRICS) <= after["families"]
        deltas = check_metrics(before, after)
        assert deltas["serve_tokens_total"] == 40
        assert deltas["serve_tick_seconds_count"] == 10
        q = server_quantiles(after)
        assert q["server_ttft_p50_ms"] == pytest.approx(50, rel=0.5)
        assert q["server_tick_p50_ms"] == pytest.approx(10, rel=0.6)
        # regression must trip the monotonicity check
        with pytest.raises(AssertionError):
            check_metrics(after, before)

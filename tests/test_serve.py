"""Serving: weights export (train -> packed tiles), batched engine
correctness vs single-request decode, int8 KV cache parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import build_model, get_config
from repro.core.packing import unpack_bits
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams, sample_logits
from repro.serve.weights import export_serving_params, serving_bytes

KEY = jax.random.PRNGKey(0)


def build_pair(arch="granite-8b", **cfg_over):
    cfg = get_config(arch).reduced()
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    t_model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                            compute_dtype=jnp.float32))
    s_model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                            compute_dtype=jnp.float32,
                                            use_pallas=False))
    return cfg, t_model, s_model


class TestWeightsExport:
    def test_export_matches_train_forward(self):
        """Serve-form (packed tile) logits == train-forward logits: the
        shipped representation computes the identical function."""
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        batch = {"tokens": toks}
        pos = jnp.broadcast_to(jnp.arange(16), (2, 16))

        xt = tm._embed_inputs(tp, batch)
        ht, _ = tm.backbone(tp, xt, positions=pos)
        lt = tm.logits(tp, ht)

        xs = sm._embed_inputs(sp, batch)
        hs, _ = sm.backbone(sp, xs, positions=pos)
        ls = sm.logits(sp, hs)
        np.testing.assert_allclose(
            np.asarray(lt, np.float32), np.asarray(ls, np.float32),
            rtol=2e-2, atol=2e-2,
        )

    def test_export_is_smaller(self):
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        assert serving_bytes(sp) < serving_bytes(tp) / 4

    def test_moe_expert_bank_export(self):
        cfg, tm, sm = build_pair("qwen2-moe-a2.7b")
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        # spot-check a tiled expert bank leaf: per-expert packed tiles
        leaves = {
            "/".join(str(getattr(p, "key", p)) for p in path): v
            for path, v in jax.tree_util.tree_leaves_with_path(sp)
        }
        tile_keys = [k for k in leaves if k.endswith("/tile")]
        assert tile_keys, "no packed tiles in MoE serve params"
        assert all(leaves[k].dtype == jnp.int32 for k in tile_keys)

    def test_packed_tile_bits_roundtrip(self):
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        # find a Dense with a tile and verify sign structure matches W
        from repro.core.tiling import tile_vector

        w = tp["seg0"]["mixer"]["wq"]["w"][0]      # layer 0 slice
        spec = cfg.tbn.spec_for(tuple(w.shape))
        t_ref = tile_vector(w, spec)
        # shipped form is row-packed: (r, ceil(n_in/32)) — one word-padded
        # packed row per unique weight row (shardable over the model axis)
        packed = sp["seg0"]["mixer"]["wq"]["tile"][0]
        assert packed.shape == (
            spec.rows_per_tile, (w.shape[1] + 31) // 32
        ), packed.shape
        t_got = unpack_bits(packed, w.shape[1]).reshape(-1)
        np.testing.assert_array_equal(np.asarray(t_ref), np.asarray(t_got))


class TestEngine:
    def _engine(self, arch="granite-8b", n_slots=3, **cfg_over):
        cfg, tm, sm = build_pair(arch, **cfg_over)
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        eng = BatchedEngine(
            sm, sp,
            ServeConfig(n_slots=n_slots, max_len=64, chunk_tokens=8),
        )
        return cfg, sm, sp, eng

    def test_single_request_greedy(self):
        cfg, sm, sp, eng = self._engine()
        req = eng.submit([1, 2, 3], SamplingParams(max_tokens=5))
        eng.run_until_drained()
        assert req.done and len(req.output) == 5
        assert all(0 <= t < cfg.vocab for t in req.output)

    def test_batched_equals_solo(self):
        """Tokens produced with 3 concurrent requests == one at a time."""
        _, _, _, eng1 = self._engine(n_slots=3)
        prompts = [[1, 2, 3], [4, 5], [6, 7, 8, 9]]
        reqs = [eng1.submit(p, SamplingParams(max_tokens=4)) for p in prompts]
        eng1.run_until_drained()

        _, _, _, eng2 = self._engine(n_slots=1)
        solo = []
        for p in prompts:
            r = eng2.submit(p, SamplingParams(max_tokens=4))
            eng2.run_until_drained()
            solo.append(r.output)
        for r, s in zip(reqs, solo):
            assert r.output == s

    def test_slot_reuse_drains_queue(self):
        _, _, _, eng = self._engine(n_slots=2)
        reqs = [eng.submit([i + 1], SamplingParams(max_tokens=3))
                for i in range(5)]
        eng.run_until_drained()
        assert all(r.done for r in reqs)

    def test_eos_stops_early(self):
        cfg, sm, sp, eng = self._engine()
        # greedy decode to find the first emitted token, then use it as EOS
        probe = eng.submit([1, 2], SamplingParams(max_tokens=2))
        eng.run_until_drained()
        eos = probe.output[0]
        _, _, _, eng2 = self._engine()
        r = eng2.submit([1, 2], SamplingParams(max_tokens=32, eos_id=eos))
        eng2.run_until_drained()
        assert r.output[-1] == eos and len(r.output) <= 32
        assert r.finish_reason == "eos"

    def test_prompt_longer_than_max_len_rejected(self):
        """An oversized (or empty) prompt fails fast at submit() and
        neither consumes a slot nor wedges the tick loop for concurrent
        requests."""
        _, _, _, eng = self._engine(n_slots=2)  # max_len 64
        ok = eng.submit([1, 2, 3], SamplingParams(max_tokens=3))
        with pytest.raises(ValueError, match="exceeds max_len"):
            eng.submit(list(range(65)), SamplingParams(max_tokens=3))
        with pytest.raises(ValueError, match="non-empty"):
            eng.submit([], SamplingParams(max_tokens=3))
        eng.run_until_drained()
        assert ok.done and len(ok.output) == 3
        assert sorted(eng._free) == [0, 1]      # no slot leaked

    def test_slot_exhaustion_queues_and_drains(self):
        """More requests than slots: the overflow waits in the queue, live
        occupancy never exceeds n_slots, and every request completes."""
        _, _, _, eng = self._engine(n_slots=2)
        reqs = [eng.submit([i + 1, i + 2], SamplingParams(max_tokens=3))
                for i in range(7)]
        peak = 0
        for _ in range(200):
            if eng._queue.empty() and not eng._live:
                break
            eng.step()
            peak = max(peak, len(eng._live))
            # FIFO admission: started requests (first token emitted at
            # admission) are always a prefix of submission order
            started = [len(r.output) > 0 for r in reqs]
            assert started == sorted(started, reverse=True), started
        assert all(r.done for r in reqs)
        assert peak <= 2
        assert all(r.finish_reason == "length" for r in reqs)

    def test_decode_retires_at_cache_capacity(self):
        """A sequence reaching max_len retires with finish_reason="length"
        instead of decoding on against a cache whose newest K/V rows are
        silently dropped: prompt 60 + cache 64 leaves exactly 5 tokens
        (the extend token + 4 decodes writing rows 60..63)."""
        _, _, _, eng = self._engine()                     # max_len 64
        r = eng.submit(list(range(1, 61)), SamplingParams(max_tokens=20))
        eng.run_until_drained()
        assert r.done and r.finish_reason == "length"
        assert len(r.output) == 64 - 60 + 1

    def test_eos_vs_max_tokens_retirement_ordering(self):
        """When the stop token lands exactly on the max_tokens boundary the
        EOS check wins — finish_reason must say "eos", not "length"."""
        _, _, _, probe_eng = self._engine()
        probe = probe_eng.submit([1, 2], SamplingParams(max_tokens=1))
        probe_eng.run_until_drained()
        # max_tokens=1 retires on the tick its final prefill chunk lands
        # (the first token comes from the extend logits) — one tick total,
        # no decode step ever runs for it
        assert probe.done and len(probe.output) == 1
        assert probe.finish_reason == "length"
        assert probe_eng.steps == 1 and probe.token_steps == [0]
        eos = probe.output[0]

        _, _, _, eng = self._engine()
        both = eng.submit([1, 2], SamplingParams(max_tokens=1, eos_id=eos))
        eng.run_until_drained()
        assert both.done and both.output == [eos]
        assert both.finish_reason == "eos"      # EOS checked before length

        _, _, _, eng2 = self._engine()
        never = eng2.submit([1, 2], SamplingParams(max_tokens=4, eos_id=-1))
        eng2.run_until_drained()
        assert never.finish_reason == "length" and len(never.output) == 4


class TestPerSlotSampling:
    """Per-request sampling params must hold for EVERY token (the old tick
    sampled decode tokens with the engine defaults) and explicit falsy
    params (temperature=0.0 / top_k=0) must win over engine defaults."""

    def _engine(self, n_slots=2, **serve_over):
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        scfg = ServeConfig(n_slots=n_slots, max_len=64,
                           chunk_tokens=8, **serve_over)
        return cfg, sm, sp, BatchedEngine(sm, sp, scfg)

    def _replay_prefill(self, sm, sp, prompt):
        """Monolithic raw-prompt prefill mirroring the chunked engine's
        context (no padding tokens enter the caches) for a replay."""
        toks = jnp.asarray([prompt], jnp.int32)
        return sm.prefill(sp, {"tokens": toks}, 64)

    def test_greedy_request_deterministic_on_sampling_engine(self):
        """SamplingParams(temperature=0.0) on a stochastic-default engine:
        explicit greedy must win over the 0.9 default (is-None sentinels,
        not or-on-falsy) for the whole sequence, across engine seeds."""
        outs = []
        for seed in (0, 1):
            _, _, _, eng = self._engine(temperature=0.9, seed=seed)
            r = eng.submit([1, 2, 3],
                           SamplingParams(temperature=0.0, max_tokens=6))
            eng.run_until_drained()
            outs.append(r.output)
        assert outs[0] == outs[1]
        # and equals the output of a greedy-default engine (params default
        # to None -> inherit the engine's 0.0)
        _, _, _, eng = self._engine(temperature=0.0, seed=7)
        r = eng.submit([1, 2, 3], SamplingParams(max_tokens=6))
        eng.run_until_drained()
        assert r.output == outs[0]

    def test_sampling_request_stochastic_beyond_first_token(self):
        """A temperature request on a greedy-default engine: decode tokens
        must come from the request's sampler, not the engine default — the
        output must diverge from the greedy continuation of its own first
        token (which is exactly what the old per-tick default produced)."""
        _, sm, sp, eng = self._engine(temperature=0.0)
        req = eng.submit([1, 2, 3],
                         SamplingParams(temperature=1.0, max_tokens=10))
        eng.run_until_drained()
        assert len(req.output) == 10
        logits, caches, lengths = self._replay_prefill(sm, sp, [1, 2, 3])
        cur = req.output[0]
        decode = jax.jit(sm.decode_step)
        greedy = []
        for _ in range(9):
            logits, caches, lengths = decode(
                sp, jnp.array([[cur]], jnp.int32), caches, lengths)
            cur = int(jnp.argmax(logits[0]))
            greedy.append(cur)
        assert req.output[1:] != greedy

    def test_topk_request_restricts_every_decode_token(self):
        """top_k=2 on an unrestricted sampling engine: every decoded token
        (not just the prefill one) must be in the top-2 of that step's
        logits, verified by replaying the engine's exact cache states."""
        _, sm, sp, eng = self._engine(temperature=1.0)  # default: full vocab
        req = eng.submit([4, 5], SamplingParams(temperature=1.0, top_k=2,
                                                max_tokens=8))
        eng.run_until_drained()
        logits, caches, lengths = self._replay_prefill(sm, sp, [4, 5])
        top2 = np.argsort(-np.asarray(logits[0]))[:2]
        assert req.output[0] in top2
        decode = jax.jit(sm.decode_step)
        cur = req.output[0]
        for tok in req.output[1:]:
            logits, caches, lengths = decode(
                sp, jnp.array([[cur]], jnp.int32), caches, lengths)
            top2 = np.argsort(-np.asarray(logits[0]))[:2]
            assert tok in top2, (tok, top2)
            cur = tok

    def test_mixed_slots_greedy_unperturbed_by_stochastic_neighbor(self):
        """A greedy request batched next to a stochastic one produces the
        same tokens as when it runs alone (greedy rows ignore the key)."""
        _, _, _, eng = self._engine(n_slots=2, temperature=0.0, seed=3)
        solo = eng.submit([1, 2, 3], SamplingParams(max_tokens=5))
        eng.run_until_drained()

        _, _, _, eng2 = self._engine(n_slots=2, temperature=0.0, seed=3)
        greedy = eng2.submit([1, 2, 3], SamplingParams(max_tokens=5))
        eng2.submit([6, 7], SamplingParams(temperature=1.0, max_tokens=5))
        eng2.run_until_drained()
        assert greedy.output == solo.output


    def test_slot_sampling_params_reset_on_retire(self):
        """Retiring a stochastic request must clear its slot's sampling
        arrays, or the dead slot would keep the batch sampler's all-greedy
        fast path disabled for every later tick."""
        _, _, _, eng = self._engine(n_slots=2, temperature=0.0)
        r = eng.submit([1, 2], SamplingParams(temperature=1.0, top_k=2,
                                              max_tokens=3))
        eng.run_until_drained()
        assert r.done
        assert float(jnp.sum(jnp.abs(eng.temps))) == 0.0
        assert int(jnp.sum(jnp.abs(eng.topks))) == 0
        assert all(int(e) == -1 for e in eng._eos_ids)


class TestDrainDiagnostics:
    def test_drain_failure_reports_queue_and_slot_state(self):
        """A wedged (or merely under-budgeted) drain must say WHERE the
        engine stopped: queued count, live count, and each live slot's
        phase@offset — not a bare "engine did not drain"."""
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=4))
        eng.submit([1, 2, 3, 4, 5, 6], SamplingParams(max_tokens=50))
        eng.submit([7, 8], SamplingParams(max_tokens=2))  # stays queued
        with pytest.raises(RuntimeError) as ei:
            eng.run_until_drained(max_steps=3)
        msg = str(ei.value)
        assert "after 3 steps" in msg
        assert "1 queued" in msg and "1 live" in msg
        # per-slot phase/offset: the 6-token prompt finished its chunked
        # prefill (6/6) and is mid-decode
        assert "slot 0" in msg and "decode@6/6" in msg
        assert "/50 tok" in msg

    def test_drain_failure_reports_prefill_offset(self):
        """A slot stuck mid-prefill reports prefill@consumed/total."""
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=4))
        eng.submit(list(np.arange(1, 21)), SamplingParams(max_tokens=4))
        with pytest.raises(RuntimeError, match=r"prefill@8/20"):
            eng.run_until_drained(max_steps=2)


class TestSchedulerStats:
    """Shape + semantics of the scheduler counters in ``stats()`` — the
    HTTP /stats surface the CLI and benchmarks print. ``preempt_free_ticks``
    used to be a stub that equalled ``work_ticks`` unconditionally; it is
    real now and these tests keep it that way."""

    def _engine(self, **cfg_over):
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        base = dict(n_slots=2, max_len=64, chunk_tokens=8, page_tokens=4)
        base.update(cfg_over)
        return BatchedEngine(sm, sp, ServeConfig(**base))

    def test_stats_shape_includes_scheduler_counters(self):
        eng = self._engine()
        eng.submit([1, 2, 3], SamplingParams(max_tokens=3))
        eng.run_until_drained()
        s = eng.stats()
        for key in ("preempts", "resumes", "preempted_tokens", "parked",
                    "preempt_free_ticks", "preempt_free_tick_rate",
                    "class_ttft_ticks", "class_counts"):
            assert key in s, key
        assert s["preempts"] == 0 and s["resumes"] == 0
        assert s["preempted_tokens"] == 0 and s["parked"] == 0
        # an undisturbed run: every work tick is preempt-free
        assert s["work_ticks"] > 0
        assert s["preempt_free_ticks"] == s["work_ticks"]
        assert s["preempt_free_tick_rate"] == 1.0
        assert s["class_counts"] == {"batch": 1}
        assert s["class_ttft_ticks"].keys() == {"batch"}

    def test_preempt_free_ticks_counts_real_preempts(self):
        """Forced preemption must show up: preempted ticks are not
        preempt-free, the preempt/resume counters move, and the parked
        token cost is accounted."""
        eng = self._engine()
        eng.submit([1, 2, 3], SamplingParams(max_tokens=6))
        eng.submit([4, 5], SamplingParams(max_tokens=6))
        tick = 0
        while eng.has_work:
            if tick % 3 == 2:
                for slot in list(eng._live):
                    eng.preempt_slot(slot)
            eng.step()
            tick += 1
        s = eng.stats()
        assert s["preempts"] > 0 and s["resumes"] == s["preempts"]
        assert s["preempted_tokens"] > 0
        assert s["preempt_free_ticks"] < s["work_ticks"]
        assert 0.0 <= s["preempt_free_tick_rate"] < 1.0
        assert s["parked"] == 0

    def test_per_class_ttft_buckets_by_request_class(self):
        eng = self._engine(priorities=True)
        eng.submit([1, 2, 3], SamplingParams(max_tokens=2,
                                             priority="interactive"))
        eng.submit([4, 5], SamplingParams(max_tokens=2, priority="batch"))
        eng.run_until_drained()
        s = eng.stats()
        assert s["class_counts"] == {"batch": 1, "interactive": 1}
        assert set(s["class_ttft_ticks"]) == {"batch", "interactive"}
        assert all(v >= 0 for v in s["class_ttft_ticks"].values())


class TestServeConfigValidation:
    def test_oversized_chunk_rejected_at_construction(self):
        """A chunk wider than the cache capacity could scatter past the
        decode cache — rejected before any engine exists."""
        with pytest.raises(ValueError, match="exceeds max_len"):
            ServeConfig(max_len=32, chunk_tokens=128)

    def test_nonpositive_chunk_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            ServeConfig(max_len=256, chunk_tokens=0)
        with pytest.raises(ValueError, match="positive"):
            ServeConfig(max_len=256, chunk_tokens=-4)

    def test_zero_slots_rejected(self):
        """n_slots=0 used to wedge the scheduler silently (every submit
        queues forever, run_until_drained spins to max_steps)."""
        with pytest.raises(ValueError, match="n_slots"):
            ServeConfig(n_slots=0)
        with pytest.raises(ValueError, match="n_slots"):
            ServeConfig(n_slots=-1)

    def test_zero_max_len_rejected(self):
        with pytest.raises(ValueError, match="max_len"):
            ServeConfig(max_len=0, chunk_tokens=1)

    def test_page_tokens_must_be_positive_and_divide_max_len(self):
        with pytest.raises(ValueError, match="page_tokens"):
            ServeConfig(max_len=64, chunk_tokens=8, page_tokens=0)
        with pytest.raises(ValueError, match="divide max_len"):
            ServeConfig(max_len=64, chunk_tokens=8, page_tokens=24)
        ServeConfig(max_len=64, chunk_tokens=8, page_tokens=16)  # ok

    def test_pool_below_one_slot_rejected(self):
        """A pool smaller than one slot's page count could never complete
        a full-length sequence."""
        with pytest.raises(ValueError, match="pool_pages"):
            ServeConfig(max_len=64, chunk_tokens=8, page_tokens=16,
                        pool_pages=3)

    def test_prefix_nodes_floor(self):
        with pytest.raises(ValueError, match="prefix_nodes"):
            ServeConfig(prefix_nodes=0)

    def test_preempt_requires_priorities(self):
        """FIFO admission would hand a preempted slot straight back to the
        class that was just evicted — rejected at construction."""
        with pytest.raises(ValueError, match="requires priorities"):
            ServeConfig(preempt=True)
        ServeConfig(preempt=True, priorities=True)  # ok

    def test_unknown_default_priority_rejected(self):
        with pytest.raises(ValueError, match="default_priority"):
            ServeConfig(default_priority="urgent")

    def test_starvation_limit_floor(self):
        with pytest.raises(ValueError, match="starvation_limit"):
            ServeConfig(priorities=True, starvation_limit=0)

    def test_negative_max_preempts_rejected(self):
        with pytest.raises(ValueError, match="max_preempts"):
            ServeConfig(priorities=True, preempt=True, max_preempts=-1)


class TestInt8KV:
    def test_decode_parity_bf16_vs_int8(self):
        """Greedy decode path with int8 KV matches bf16 KV closely."""
        outs = {}
        for kvd in ("bf16", "int8"):
            cfg, tm, sm = build_pair("granite-8b", kv_dtype=kvd)
            tp = mod.init_params(tm.specs(), KEY)
            sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
            toks = jnp.array([[1, 2, 3, 4]], jnp.int32)
            logits, caches, lengths = sm.prefill(sp, {"tokens": toks}, 16)
            seq = []
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            for _ in range(4):
                logits, caches, lengths = sm.decode_step(sp, tok, caches, lengths)
                tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                seq.append(int(tok[0, 0]))
            outs[kvd] = seq
        assert outs["bf16"] == outs["int8"]

    def test_quant_roundtrip_exact_for_updates(self):
        from repro.nn.attention import dequantize_kv, quantize_kv

        x = jax.random.normal(KEY, (2, 8, 4, 16), jnp.float32)
        q, s = quantize_kv(x)
        # requantizing the dequantized cache reproduces the codes exactly
        q2, s2 = quantize_kv(dequantize_kv(q, s, jnp.float32))
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-6)


class TestSampling:
    def test_topk_at_least_vocab_is_no_restriction(self):
        """k >= V must behave like no top-k (and not crash lax.top_k),
        in both the scalar and the batch sampler."""
        logits = jnp.array([[0.5, 2.0, -1.0, 0.1]])
        want = sample_logits(logits, KEY, temperature=1.0, top_k=None)
        got = sample_logits(logits, KEY, temperature=1.0, top_k=100)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        from repro.serve.sampling import sample_logits_batch

        got_b = sample_logits_batch(
            logits, KEY[None], temperature=jnp.array([1.0]),
            top_k=jnp.array([100], jnp.int32))
        want_b = sample_logits_batch(
            logits, KEY[None], temperature=jnp.array([1.0]),
            top_k=jnp.array([0], jnp.int32))
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))

    def test_batch_sampler_rejects_shared_key(self):
        """A single shared key is ambiguous under per-request key streams:
        the batch sampler demands one key per row."""
        from repro.serve.sampling import sample_logits_batch

        logits = jnp.zeros((2, 4))
        with pytest.raises(ValueError, match="one PRNG key per row"):
            sample_logits_batch(
                logits, KEY, temperature=jnp.zeros((2,)),
                top_k=jnp.zeros((2,), jnp.int32))

    def test_oversized_topk_request_serves_without_wedging(self):
        """A stochastic request with top_k >= vocab must not crash
        mid-admission (it previously wedged the engine with a leaked
        slot); it serves as unrestricted sampling."""
        cfg, tm, sm = build_pair()
        tp = mod.init_params(tm.specs(), KEY)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=64, chunk_tokens=8))
        r = eng.submit([1, 2], SamplingParams(
            temperature=1.0, top_k=cfg.vocab + 100, max_tokens=3))
        eng.run_until_drained()
        assert r.done and len(r.output) == 3
        assert sorted(eng._free) == [0, 1]

    def test_greedy_is_argmax(self):
        logits = jnp.array([[0.1, 2.0, -1.0], [3.0, 0.0, 0.0]])
        out = sample_logits(logits, KEY, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(out), [1, 0])

    def test_topk_restricts_support(self):
        logits = jnp.array([[10.0, 9.0, -50.0, -50.0]])
        for seed in range(16):
            t = sample_logits(logits, jax.random.PRNGKey(seed),
                              temperature=1.0, top_k=2)
            assert int(t[0]) in (0, 1)

"""MoE serving parity wall: engine decode == monolithic reference.

The train-path dispatch pads each expert to a capacity that depends on
the TOTAL token count (``ceil(1.25 * k * tl / e)``), so the same token
can be dropped under one chunking and kept under another — useless as a
serving path. SERVE mode swaps in a drop-free fixed-shape dispatch
(capacity = tl * k, token-major positions, gate-rank-ordered combine;
``nn/moe.py``), which makes every routed token's math independent of its
batch neighbors and chunk boundaries. These tests pin the consequence:
engine tokens are byte-identical to the monolithic prefill+decode
reference across chunk sizes, greedy and seeded-stochastic, with and
without forced preemption — and the expert tiles ship as per-expert
``(E, r, words)`` packed rows that round-trip bit-exactly.
"""
import jax
import numpy as np
import pytest

from repro.core.packing import unpack_bits
from repro.core.tiling import tile_vector
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from test_chunked_prefill import (
    CHUNKS,
    PROMPT,
    build_serve,
    monolithic_reference,
)

MOE_ARCHS = ["qwen2-moe-a2.7b", "moonshot-v1-16b-a3b"]
PROMPTS = [PROMPT, [8, 6, 1, 12, 0], [5, 5, 2, 8]]


def engine_run(sm, sp, prompts, *, chunk_tokens=8, max_tokens=6,
               temperature=0.0, top_k=0, preempt_every=0, **cfg_over):
    base = dict(n_slots=2, max_len=64, chunk_tokens=chunk_tokens,
                page_tokens=8, seed=0)
    base.update(cfg_over)
    eng = BatchedEngine(sm, sp, ServeConfig(**base))
    reqs = [eng.submit(np.asarray(p, np.int32), SamplingParams(
        max_tokens=max_tokens, temperature=temperature, top_k=top_k))
        for p in prompts]
    i = 0
    while eng.has_work:
        assert i < 800, "engine wedged"
        if preempt_every and i % preempt_every == preempt_every - 1:
            for slot in list(eng._live):
                assert eng.preempt_slot(slot)
        eng.step()
        i += 1
    return eng, [r.output for r in reqs]


class TestMoEParityWall:
    @pytest.mark.parametrize("chunk", CHUNKS)
    def test_greedy_parity_across_chunk_sizes(self, chunk):
        cfg, sm, sp = build_serve("qwen2-moe-a2.7b")
        refs = [monolithic_reference(sm, sp, p, 6, rid=i)
                for i, p in enumerate(PROMPTS)]
        _, out = engine_run(sm, sp, PROMPTS, chunk_tokens=chunk)
        assert out == refs

    def test_seeded_stochastic_parity(self):
        cfg, sm, sp = build_serve("qwen2-moe-a2.7b")
        kw = dict(temperature=0.9, top_k=12)
        refs = [monolithic_reference(sm, sp, p, 6, rid=i, **kw)
                for i, p in enumerate(PROMPTS)]
        _, out = engine_run(sm, sp, PROMPTS, **kw)
        assert out == refs

    @pytest.mark.parametrize("kw", [
        dict(), dict(temperature=0.9, top_k=12),
    ], ids=["greedy", "stochastic"])
    def test_preempt_resume_parity(self, kw):
        """Forced preemption every 3rd tick changes nothing: the routed
        expert math sees the same tokens at the same positions after a
        page-table rewrite + resume."""
        cfg, sm, sp = build_serve("qwen2-moe-a2.7b")
        eng, base = engine_run(sm, sp, PROMPTS, **kw)
        chaos, out = engine_run(sm, sp, PROMPTS, preempt_every=3, **kw)
        assert out == base
        st = chaos.stats()
        assert st["preempts"] > 0 and st["resumes"] == st["preempts"]

    def test_moonshot_engine_smoke(self):
        """Second MoE config (shared experts + different k/E) drains and
        matches the reference at one chunk size."""
        cfg, sm, sp = build_serve("moonshot-v1-16b-a3b")
        refs = [monolithic_reference(sm, sp, p, 4, rid=i)
                for i, p in enumerate(PROMPTS[:2])]
        _, out = engine_run(sm, sp, PROMPTS[:2], max_tokens=4)
        assert out == refs


class TestMoEExportRoundTrip:
    def test_expert_bank_tiles_are_E_r_words(self):
        """Expert bank ships one packed (r, words) row block PER EXPERT —
        per scanned layer the leaf is (L, E, r, words) int32."""
        cfg, sm, sp = build_serve("qwen2-moe-a2.7b")
        tile = sp["seg0"]["ffn"]["up"]["tile"]
        assert tile.shape[1] == cfg.moe.n_experts
        assert tile.ndim == 4 and tile.dtype == np.int32

    def test_expert_tiles_roundtrip_bit_exact(self):
        """Unpacking each expert's shipped rows reproduces tile_vector of
        that expert's master weights exactly — compression is lossless on
        the sign structure."""
        import jax.numpy as jnp

        from repro.configs import build_model, get_config
        from repro.nn import module as mod
        from repro.nn.context import TRAIN, ModelContext

        cfg, sm, sp = build_serve("qwen2-moe-a2.7b")
        tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                           compute_dtype=jnp.float32))
        tp = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
        w_bank = tp["seg0"]["ffn"]["up"]["w"]        # (L, E, d_ff, d)
        packed = sp["seg0"]["ffn"]["up"]["tile"]     # (L, E, r, words)
        spec = cfg.tbn.spec_for(tuple(w_bank.shape[2:]))
        for layer in range(w_bank.shape[0]):
            for e in range(w_bank.shape[1]):
                t_ref = tile_vector(w_bank[layer, e], spec)
                t_got = unpack_bits(
                    packed[layer, e], w_bank.shape[-1]).reshape(-1)
                np.testing.assert_array_equal(
                    np.asarray(t_ref), np.asarray(t_got),
                    err_msg=f"layer {layer} expert {e}")

"""Prefix-cache parity wall: with the radix-trie prefix cache ENABLED,
emitted tokens are byte-identical to the cache-disabled engine across
cold-miss, warm-hit, partial-hit, and post-eviction admissions — greedy
and seeded stochastic, on all three decode-cache families plus int8 KV.

Why parity holds by construction: a trie hit maps PAGE-ALIGNED prefix
state that an identical token stream produced — attention pages hold the
K/V rows positions 0..boundary-1 would have gotten (K/V at position p
depends only on tokens <= p), recurrent snapshots hold the carry at
exactly ``boundary`` tokens (chunk scheduling never crosses a page
boundary on stateful models, so the snapshot is taken at the boundary,
not near it) — and sampling keys only on (seed, rid, t), never on how
the cache content was obtained.

Plus pool accounting: pages never leak across admissions, trie eviction
reclaims them under pressure, and ``BatchedEngine.stats()`` reports the
hits the scheduler actually served.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import pytest

from repro.configs import build_model, get_config
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.weights import export_serving_params

KEY = jax.random.PRNGKey(0)

FAMILY_ARCHS = [
    "granite-8b",          # full attention -> paged pool pages
    "recurrentgemma-2b",   # windowed ring + RG-LRU -> boundary snapshots
    "mamba2-370m",         # SSM (h, conv) -> boundary snapshots
]

# page_tokens=4 below: the 14-token prompt publishes 3 complete pages and
# a warm re-admission may match at most (14-1)//4 = 3 of them
PROMPT = [3, 9, 4, 11, 7, 2, 5, 1, 8, 6, 10, 12, 0, 13]
PARTIAL = PROMPT[:4] + [12, 3, 9, 1, 7]      # shares exactly page 0
OTHER = [5, 5, 2, 8, 1, 9, 4, 4, 6, 2]       # diverges at token 0


@functools.lru_cache(maxsize=None)
def build_serve(arch, **cfg_over):
    cfg = get_config(arch).reduced()
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), KEY)
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    return cfg, sm, sp


def drain_sequence(sm, sp, prompts, *, prefix_cache, seed=0,
                   temperature=0.0, top_k=0, max_tokens=5, **cfg_over):
    """Submit+drain each prompt in order on ONE engine (so later prompts
    see what earlier ones published) and return (engine, token lists).
    Request ids follow submission order, so the same sequence on a
    cache-off engine samples with identical per-request key streams."""
    eng = BatchedEngine(sm, sp, ServeConfig(
        n_slots=2, max_len=64, chunk_tokens=8, page_tokens=4,
        prefix_cache=prefix_cache, seed=seed, **cfg_over))
    outs = []
    for p in prompts:
        r = eng.submit(p, SamplingParams(
            temperature=temperature, top_k=top_k, max_tokens=max_tokens))
        eng.run_until_drained()
        outs.append(r.output)
    return eng, outs


class TestPrefixParityWall:
    """Token parity ON vs OFF over the full admission matrix: request 0 is
    the cold miss (and the publisher), request 1 the warm hit, request 2
    the partial hit, request 3 an unrelated miss."""

    SEQUENCE = [PROMPT, PROMPT, PARTIAL, OTHER]

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_greedy_cold_warm_partial_parity(self, arch):
        cfg, sm, sp = build_serve(arch)
        on_eng, on = drain_sequence(sm, sp, self.SEQUENCE, prefix_cache=True)
        _, off = drain_sequence(sm, sp, self.SEQUENCE, prefix_cache=False)
        assert on == off, (arch, on, off)
        st = on_eng.stats()
        assert st["prefix_hits"] == 2                     # warm + partial
        # warm hit maps 3 pages (12 tokens), partial hit page 0 (4 tokens)
        assert st["prefill_tokens_skipped"] == 16
        assert on_eng.trie is not None and len(on_eng.trie) > 0

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_seeded_stochastic_parity(self, arch):
        """Sampling keys on (seed, rid, t) only — a hit must replay the
        exact stochastic stream the cold path would have produced."""
        cfg, sm, sp = build_serve(arch)
        _, on = drain_sequence(sm, sp, self.SEQUENCE, prefix_cache=True,
                               seed=3, temperature=1.0, top_k=5,
                               max_tokens=7)
        _, off = drain_sequence(sm, sp, self.SEQUENCE, prefix_cache=False,
                                seed=3, temperature=1.0, top_k=5,
                                max_tokens=7)
        assert on == off, (arch, on, off)

    def test_int8_kv_parity(self):
        """Quantized family: codes AND scales page together, so a shared
        prefix replays bit-identical int8 codes."""
        cfg, sm, sp = build_serve("granite-8b", kv_dtype="int8")
        on_eng, on = drain_sequence(sm, sp, self.SEQUENCE, prefix_cache=True)
        _, off = drain_sequence(sm, sp, self.SEQUENCE, prefix_cache=False)
        assert on == off, (on, off)
        assert on_eng.stats()["prefix_hits"] == 2

    @pytest.mark.parametrize("arch", FAMILY_ARCHS)
    def test_post_eviction_parity(self, arch):
        """After the trie is forcibly drained, a re-admission is a cold
        miss again — and still emits the same tokens."""
        cfg, sm, sp = build_serve(arch)
        eng, outs = drain_sequence(sm, sp, [PROMPT, PROMPT],
                                   prefix_cache=True)
        assert eng.stats()["prefix_hits"] == 1
        eng.trie.clear()                                  # evict everything
        assert len(eng.trie) == 0
        r = eng.submit(PROMPT, SamplingParams(max_tokens=5))
        eng.run_until_drained()
        assert r.prefix_hit_tokens == 0                   # cold again
        assert r.output == outs[0], (arch, r.output, outs[0])
        if eng.pool is not None:
            eng.pool.check()

    @pytest.mark.parametrize("arch", ["mamba2-370m"])
    def test_stateful_warm_hit_prefills_at_full_chunk_width(self, arch):
        """Boundary capping only pauses at boundaries the trie is
        MISSING: a cold stateful prefill steps one page per tick (each
        boundary snapshotted), but a warm full-hit repeat has nothing to
        snapshot and lands its whole tail in one chunk."""
        cfg, sm, sp = build_serve(arch)
        prompt = [int(x) % cfg.vocab for x in range(40)]   # 10 pages of 4
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=16, page_tokens=4,
            prefix_cache=True))
        a = eng.submit(prompt, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert a.token_steps[0] - a.admit_step + 1 == 10   # page-capped
        b = eng.submit(prompt, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert b.prefix_hit_tokens == 36                   # (40-1)//4 pages
        assert b.token_steps[0] - b.admit_step + 1 == 1    # uncapped tail
        assert b.output == a.output

    def test_snapshot_backfill_on_republish(self):
        """A node republished without a snapshot (possible after an
        eviction raced a live slot) must regain one on the next publish
        that carries it — otherwise stateful match depth is capped at
        that boundary forever."""
        from repro.serve.prefix import PrefixTrie

        trie = PrefixTrie(2, pool=None, max_nodes=8)
        seq = [1, 2, 3, 4]
        trie.insert(seq, None, {}, now=0)        # snapshotless republish
        assert trie.match(seq + [9], require_snapshot=True) == []
        trie.insert(seq, None, {2: "snapA", 4: "snapB"}, now=1)
        path = trie.match(seq + [9], require_snapshot=True)
        assert len(path) == 2 and path[-1].snapshot == "snapB"

    def test_warm_hit_skips_prefill_work(self):
        """The point of the cache: a warm admission runs measurably fewer
        prefill ticks. 14-token prompt, chunk 8: cold = 2 extend ticks;
        warm maps 12 tokens and finishes prefill in 1."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=8, page_tokens=4,
            prefix_cache=True))
        a = eng.submit(PROMPT, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        cold_ttft_ticks = a.token_steps[0] - a.admit_step
        b = eng.submit(PROMPT, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        warm_ttft_ticks = b.token_steps[0] - b.admit_step
        assert b.prefix_hit_tokens == 12
        assert warm_ttft_ticks < cold_ttft_ticks
        assert b.output == a.output


class TestPoolAccounting:
    def test_no_leaked_pages_after_drain(self):
        """After every request retires, the only page references left are
        the trie's pins — releasing those returns the pool to fully
        free."""
        cfg, sm, sp = build_serve("granite-8b")
        eng, _ = drain_sequence(sm, sp, [PROMPT, PROMPT, PARTIAL, OTHER],
                                prefix_cache=True)
        eng.pool.check()
        assert eng.pool.used_pages == len(eng.trie.held_pages())
        eng.trie.clear()
        eng.pool.check()
        assert eng.pool.used_pages == 0
        assert eng.pool.free_pages == eng.pool.n_pages

    def test_no_leaked_pages_without_prefix_cache(self):
        cfg, sm, sp = build_serve("granite-8b")
        eng, _ = drain_sequence(sm, sp, [PROMPT, OTHER], prefix_cache=False)
        eng.pool.check()
        assert eng.pool.used_pages == 0

    def test_trie_eviction_reclaims_pages_under_pressure(self):
        """A pool sized for one slot: the second prompt's pages can only
        come from evicting the first prompt's published nodes — the
        engine must do that transparently and still drain."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=8, page_tokens=4,
            pool_pages=16, prefix_cache=True))      # == one slot's worth
        a = eng.submit([int(x) % cfg.vocab for x in range(1, 61)],
                       SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert len(eng.trie) == 15                  # 15 published pages
        b = eng.submit([int(x) % cfg.vocab for x in range(70, 130)],
                       SamplingParams(max_tokens=2))
        eng.run_until_drained()
        assert a.done and b.done
        assert eng.trie.evictions > 0
        eng.pool.check()

    def test_pool_exhaustion_without_trie_raises(self):
        """No prefix cache -> nothing to evict: concurrent prompts that
        genuinely overcommit the pool fail loudly, naming the fix."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=64, chunk_tokens=8, page_tokens=16,
            pool_pages=4, prefix_cache=False))      # one slot's worth
        # long decode keeps the first slot's 3 pages pinned while the
        # second prefills — a genuine concurrent overcommit
        eng.submit(list(range(1, 41)), SamplingParams(max_tokens=20))
        eng.submit(list(range(1, 41)), SamplingParams(max_tokens=20))
        with pytest.raises(RuntimeError, match="pool exhausted"):
            eng.run_until_drained()

    def test_shared_pages_survive_trie_eviction_while_slot_lives(self):
        """Evicting a node whose pages a live slot still maps must not
        free those pages out from under the slot — the refcount keeps
        them until retirement."""
        cfg, sm, sp = build_serve("granite-8b")
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=1, max_len=64, chunk_tokens=8, page_tokens=4,
            prefix_cache=True))
        a = eng.submit(PROMPT, SamplingParams(max_tokens=2))
        eng.run_until_drained()
        b = eng.submit(PROMPT, SamplingParams(max_tokens=8))
        eng.step()                                 # b live, pages mapped
        held = int(eng._n_mapped[0])
        assert held >= 3                           # the warm-hit mapping
        eng.trie.clear()                           # drop every trie pin
        for i in range(held):
            pid = int(eng._ptab[0, i])
            assert eng.pool.refcounts[pid] >= 1    # slot's ref survives
        eng.run_until_drained()
        assert b.output[:2] == a.output            # same greedy stream
        eng.pool.check()


class TestStats:
    def test_stats_shape_and_ranges(self):
        cfg, sm, sp = build_serve("granite-8b")
        eng, _ = drain_sequence(sm, sp, [PROMPT, PROMPT], prefix_cache=True)
        st = eng.stats()
        assert st["admitted"] == 2
        assert st["hit_rate"] == 0.5
        assert st["prefill_tokens_skipped"] == 12
        assert st["prompt_tokens"] == 2 * len(PROMPT)
        assert 0.0 <= st["page_utilization"] <= 1.0
        assert st["pages_in_use"] <= st["pool_pages"]
        assert st["evictions"] == 0

    def test_stats_without_prefix_cache(self):
        cfg, sm, sp = build_serve("granite-8b")
        eng, _ = drain_sequence(sm, sp, [PROMPT], prefix_cache=False)
        st = eng.stats()
        assert st["prefix_hits"] == 0 and st["hit_rate"] == 0.0
        assert st["trie_nodes"] == 0 and st["evictions"] == 0

"""Per-assigned-architecture smoke tests (reduced configs, CPU).

For each of the 10 assigned archs: instantiate a reduced same-family config,
run one forward + one SGD train step, assert output shapes and no NaNs.
Decode parity (prefill + stepwise decode == full forward) is checked for one
arch per cache family (full attn / window+rec / ssm / enc-dec).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, build_model, get_config
from repro.nn.context import ModelContext

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=16):
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(KEY, (b, s, cfg.d_model)),
            "tokens": jax.random.randint(KEY, (b, s // 2), 0, cfg.vocab),
        }
    if cfg.modality == "vlm":
        return {
            "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
            "image_mask": jnp.zeros((b, s), bool).at[:, :4].set(True),
            "image_embeds": jax.random.normal(KEY, (b, s, cfg.d_model)),
        }
    return {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)

    loss, metrics = model.train_forward(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one SGD step end-to-end (exercises STE/custom-vjp through scan+remat)
    grads = jax.grad(lambda p: model.train_forward(p, batch)[0])(params)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    loss2, _ = model.train_forward(new_params, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_tbn_actually_tiles(arch):
    """The TBN policy must tile at least one layer in every arch."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    report = model.ctx.ledger.report()
    tiled = [r for r in report.layers if r.spec is not None]
    assert tiled, f"{arch}: no layer tiled under reduced policy"
    assert report.bits_per_param() < 1.0, f"{arch}: not sub-bit"


@pytest.mark.parametrize(
    "arch", ["granite-8b", "recurrentgemma-2b", "mamba2-370m", "seamless-m4t-large-v2"]
)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    ctx = ModelContext(policy=cfg.tbn, compute_dtype=jnp.float32)
    model = build_model(cfg, ctx)
    params = model.init(KEY)
    b, s, extra = 2, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s + extra), 0, cfg.vocab)

    if cfg.family == "encdec":
        frames = jax.random.normal(KEY, (b, s, cfg.d_model))
        logits_d, caches, lengths = model.prefill(
            params, {"frames": frames, "tokens": toks[:, :s]}, max_len=s + extra
        )
        for t in range(extra):
            logits_d, caches, lengths = model.decode_step(
                params, toks[:, s + t : s + t + 1], caches, lengths
            )
        memory = model.encode(params, frames)
        h = model.decode(params, toks, memory)
        full = model.head(params["head"], model.dec_norm(params["dec_norm"], h[:, -1:]))[:, 0]
    else:
        logits_d, caches, lengths = model.prefill(
            params, {"tokens": toks[:, :s]}, max_len=s + extra
        )
        for t in range(extra):
            logits_d, caches, lengths = model.decode_step(
                params, toks[:, s + t : s + t + 1], caches, lengths
            )
        pos = jnp.broadcast_to(jnp.arange(s + extra), (b, s + extra))
        x = model._embed_inputs(params, {"tokens": toks})
        hfull, _ = model.backbone(params, x, positions=pos)
        full = model.logits(params, hfull[:, -1:])[:, 0]

    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(full), rtol=2e-3, atol=2e-3
    )


def test_full_configs_have_exact_assigned_dims():
    """The full (non-reduced) configs carry the assignment's exact numbers."""
    expect = {
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_ff, cfg.vocab) == (
            L, d, h, kv, ff, v,
        ), arch
    # MoE extras
    m = get_config("moonshot-v1-16b-a3b").moe
    assert (m.n_experts, m.top_k) == (64, 6)
    q = get_config("qwen2-moe-a2.7b").moe
    assert (q.n_experts, q.top_k, q.n_shared) == (60, 4, 4)
    assert get_config("mamba2-370m").ssm.d_state == 128
    assert get_config("recurrentgemma-2b").window == 2048

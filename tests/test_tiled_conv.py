"""Tiled conv inference path: kernel parity, serve routing, and the
no-dense-weight guarantee.

The acceptance oracle is ``jax.lax.conv_general_dilated`` on the fully
reconstructed dense weight (kernels.ref.tiled_conv_ref); both the Pallas
interpret path and the structured tile-bank fallback must match it to
<= 1e-4 in f32 across strides / paddings / kernel sizes / channel counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    export_tile,
    pack_conv_tile,
    plan_conv_tiling,
    plan_tiling,
    unpack_conv_tile,
)
from repro.kernels import resolve_conv_padding, tiled_conv_infer
from repro.kernels.ref import tiled_conv_dense_weight, tiled_conv_ref

KEY = jax.random.PRNGKey(0)


def make_case(c_out, c_in, kh, kw, p, alpha_mode="tile", alpha_source="W"):
    spec = plan_tiling(
        (c_out, c_in, kh, kw), p=p, min_size=0,
        alpha_mode=alpha_mode, alpha_source=alpha_source,
    )
    assert spec is not None and spec.aligned_rows
    w = jax.random.normal(jax.random.fold_in(KEY, c_out * kh + c_in),
                          (c_out, c_in, kh, kw))
    t, alpha = export_tile(w, spec)
    packed = pack_conv_tile(t, c_out // spec.p, c_in, kh, kw)
    return spec, packed, alpha


# --------------------------------------------------------------------------
# acceptance sweep: {stride 1,2} x {SAME,VALID} x {1x1, 3x3} x channels
# --------------------------------------------------------------------------
@pytest.mark.parametrize("stride", [(1, 1), (2, 2)])
@pytest.mark.parametrize("padding", ["SAME", "VALID"])
@pytest.mark.parametrize("kernel", [(1, 1), (3, 3)])
@pytest.mark.parametrize("c_in,c_out,p", [(32, 64, 4), (16, 24, 2), (3, 8, 2)])
def test_tiled_conv_infer_matches_dense_reference(
    stride, padding, kernel, c_in, c_out, p
):
    kh, kw = kernel
    spec, packed, alpha = make_case(c_out, c_in, kh, kw, p)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 9, c_in))
    want = tiled_conv_ref(x, packed, alpha, spec, stride=stride, padding=padding)
    for use_pallas in (False, True):
        got = tiled_conv_infer(
            x, packed, alpha, spec, stride=stride, padding=padding,
            use_pallas=use_pallas,
        )
        assert got.shape == want.shape
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=f"use_pallas={use_pallas}",
        )


@pytest.mark.parametrize("alpha_mode", ["layer", "tile"])
@pytest.mark.parametrize("kernel,stride", [((5, 3), (1, 2)), ((3, 3), (2, 1))])
def test_tiled_conv_infer_asymmetric_and_alpha_modes(alpha_mode, kernel, stride):
    kh, kw = kernel
    spec, packed, alpha = make_case(24, 8, kh, kw, 3, alpha_mode=alpha_mode)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 11, 8))
    want = tiled_conv_ref(x, packed, alpha, spec, stride=stride, padding="VALID")
    for use_pallas in (False, True):
        got = tiled_conv_infer(
            x, packed, alpha, spec, stride=stride, padding="VALID",
            use_pallas=use_pallas,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_explicit_padding_pairs():
    spec, packed, alpha = make_case(16, 8, 3, 3, 2)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 7, 7, 8))
    pads = [(2, 1), (0, 2)]
    want = tiled_conv_ref(x, packed, alpha, spec, stride=(1, 1), padding=pads)
    for use_pallas in (False, True):
        got = tiled_conv_infer(
            x, packed, alpha, spec, stride=(1, 1), padding=pads,
            use_pallas=use_pallas,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_same_lower_and_unsupported_padding_strings():
    spec, packed, alpha = make_case(16, 8, 3, 3, 2)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 6, 6, 8))
    want = tiled_conv_ref(x, packed, alpha, spec, stride=(2, 2),
                          padding="SAME_LOWER")
    for use_pallas in (False, True):
        got = tiled_conv_infer(x, packed, alpha, spec, stride=(2, 2),
                               padding="SAME_LOWER", use_pallas=use_pallas)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )
    with pytest.raises(ValueError, match="unsupported padding"):
        tiled_conv_infer(x, packed, alpha, spec, padding="WRAP")


def test_resolve_conv_padding_matches_xla():
    """Output dims from the resolver == conv_general_dilated's for every
    combination the sweep exercises."""
    x = jnp.zeros((1, 13, 9, 4))
    w = jnp.zeros((8, 4, 3, 3))
    for stride in [(1, 1), (2, 2), (3, 1)]:
        for padding in ["SAME", "VALID", [(1, 2), (0, 1)]]:
            y = jax.lax.conv_general_dilated(
                x, w, stride, padding if not isinstance(padding, str) else padding,
                dimension_numbers=("NHWC", "OIHW", "NHWC"))
            (oh, ow), _ = resolve_conv_padding((13, 9), (3, 3), stride, padding)
            assert (y.shape[1], y.shape[2]) == (oh, ow), (stride, padding)


# --------------------------------------------------------------------------
# conv-layout packing round trip
# --------------------------------------------------------------------------
@pytest.mark.parametrize("c_in", [1, 3, 32, 48])
def test_pack_conv_tile_roundtrip(c_in):
    r, kh, kw = 6, 3, 3
    q = r * c_in * kh * kw
    t = jnp.where(jax.random.bernoulli(KEY, 0.5, (q,)), 1.0, -1.0)
    packed = pack_conv_tile(t, r, c_in, kh, kw)
    assert packed.shape == (kh * kw, r, (c_in + 31) // 32)
    bank = unpack_conv_tile(packed, r, c_in, kh, kw)
    np.testing.assert_array_equal(
        np.asarray(bank), np.asarray(t.reshape(r, c_in, kh, kw))
    )


# --------------------------------------------------------------------------
# Conv2D layer routing (serve mode)
# --------------------------------------------------------------------------
def _conv_pair(policy, **kw):
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.nn.linear import Conv2D

    tctx = ModelContext(policy=policy, mode=TRAIN, compute_dtype=jnp.float32)
    sctx = ModelContext(policy=policy, mode=SERVE, compute_dtype=jnp.float32,
                        use_pallas=False)
    return (Conv2D(ctx=tctx, **kw), Conv2D(ctx=sctx, **kw))


def test_conv2d_serve_routes_through_packed_tile():
    """SERVE Conv2D under the packed policy declares only (tile_conv, alpha)
    — no dense weight in the shipped params — and matches TRAIN output."""
    from repro.core.policy import tbn_policy
    from repro.nn import module as mod
    from repro.serve.weights import export_serving_params

    pol = tbn_policy(p=4, min_size=0, alpha_source="A")
    tc, sc = _conv_pair(pol, c_in=8, c_out=16, kernel=(3, 3), stride=(2, 2))
    sspec = sc.specs()
    assert set(sspec) == {"tile_conv", "alpha"}
    assert sspec["tile_conv"].dtype == jnp.int32
    tp = mod.init_params({"c": tc.specs()}, KEY)
    sp = export_serving_params({"c": tc.specs()}, {"c": sspec}, tp, pol)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 8, 8))
    np.testing.assert_allclose(
        np.asarray(tc(tp["c"], x)), np.asarray(sc(sp["c"], x)),
        rtol=1e-4, atol=1e-4,
    )


def test_conv2d_serve_never_materializes_dense_weight():
    """Jaxpr audit: no intermediate on the serve path has the dense weight's
    element count — the largest weight-derived tensor is the p-fold smaller
    tile bank."""
    from repro.core.policy import tbn_policy
    from repro.nn import module as mod
    from repro.serve.weights import export_serving_params

    pol = tbn_policy(p=4, min_size=0, alpha_source="W")
    kw = dict(c_in=32, c_out=64, kernel=(3, 3))
    tc, sc = _conv_pair(pol, **kw)
    tp = mod.init_params({"c": tc.specs()}, KEY)
    sp = export_serving_params({"c": tc.specs()}, {"c": sc.specs()}, tp, pol)
    x = jnp.zeros((1, 8, 8, 32))
    n_dense = 64 * 32 * 3 * 3
    jaxpr = jax.make_jaxpr(lambda p, x: sc(p, x))(sp["c"], x)
    for eqn in jaxpr.jaxpr.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                size = int(np.prod(v.aval.shape)) if v.aval.shape else 1
                # activations can be big; catch weight-shaped tensors only
                assert v.aval.shape != (64, 32, 3, 3) and size != n_dense, (
                    f"dense-weight-sized intermediate {v.aval.shape} in "
                    f"{eqn.primitive}"
                )


def test_conv2d_serve_bwnn_parity():
    from repro.core.policy import bwnn_policy
    from repro.nn import module as mod
    from repro.serve.weights import export_serving_params

    pol = bwnn_policy()
    tc, sc = _conv_pair(pol, c_in=4, c_out=8, kernel=(3, 3), use_bias=True)
    sspec = sc.specs()
    assert "wbits" in sspec and "w" not in sspec
    tp = mod.init_params({"c": tc.specs()}, KEY)
    sp = export_serving_params({"c": tc.specs()}, {"c": sspec}, tp, pol)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, 6, 4))
    np.testing.assert_allclose(
        np.asarray(tc(tp["c"], x)), np.asarray(sc(sp["c"], x)),
        rtol=1e-5, atol=1e-5,
    )


def test_conv2d_serve_unaligned_falls_back_to_flat_tile():
    """p | N but p does not divide c_out: serve ships the flat tile and the
    (documented) dense-reconstruction fallback still matches TRAIN."""
    from repro.core.policy import tbn_policy
    from repro.nn import module as mod
    from repro.serve.weights import export_serving_params

    pol = tbn_policy(p=3, min_size=0, alpha_source="W", require_aligned=False)
    tc, sc = _conv_pair(pol, c_in=6, c_out=8, kernel=(3, 3))
    assert tc.spec is not None and not tc.spec.aligned_rows
    sspec = sc.specs()
    assert "tile" in sspec and "tile_conv" not in sspec
    tp = mod.init_params({"c": tc.specs()}, KEY)
    sp = export_serving_params({"c": tc.specs()}, {"c": sspec}, tp, pol)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 5, 5, 6))
    np.testing.assert_allclose(
        np.asarray(tc(tp["c"], x)), np.asarray(sc(sp["c"], x)),
        rtol=1e-4, atol=1e-4,
    )


def test_conv_plan_arithmetic():
    spec = plan_tiling((64, 32, 3, 3), p=4, min_size=0)
    plan = plan_conv_tiling(spec)
    assert plan.r == 16 and plan.kk == 32 * 9 and plan.positions == 9
    assert plan.packed_shape() == (9, 16, 1)
    assert plan.r * plan.kk == spec.q
    # dense reconstruction helper agrees with the replication structure
    t = jnp.where(jax.random.bernoulli(KEY, 0.5, (spec.q,)), 1.0, -1.0)
    packed = pack_conv_tile(t, plan.r, plan.c_in, 3, 3)
    alpha = jnp.abs(jax.random.normal(jax.random.PRNGKey(7), (4,))) + 0.1
    w = np.asarray(tiled_conv_dense_weight(packed, alpha, spec))
    for a in range(1, 4):
        np.testing.assert_allclose(
            w[a * 16:(a + 1) * 16] / float(alpha[a]),
            w[:16] / float(alpha[0]), rtol=1e-6,
        )

"""Quickstart: the TBN transform on one layer, then a tiny tiled model.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's Section 3 end to end on real tensors:
  1. plan a tiling for a weight (p, q, bits/param),
  2. training-time forward (reshape -> sum -> sign STE -> tile -> alpha),
  3. what actually ships (q packed bits + alpha scalars),
  4. the tile-reuse matmul == the dense matmul,
  5. a 3-layer MLP trained end-to-end with sub-bit weights.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_bits, storage_bytes
from repro.core.tiling import (export_tile, plan_tiling,
                               tiled_matmul_reference, tiled_weight)

# -- 1. plan ---------------------------------------------------------------
n_out, n_in, p = 512, 256, 4
spec = plan_tiling((n_out, n_in), p=p, min_size=0, alpha_mode="tile",
                   alpha_source="W")
print(f"weight ({n_out}x{n_in}) tiled p={spec.p}: tile q={spec.q} bits, "
      f"{spec.n_alpha} alphas -> {spec.bits_per_param:.3f} bits/param")

# -- 2. training-time forward ----------------------------------------------
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (n_out, n_in))
bhat = tiled_weight(w, spec)          # differentiable (straight-through)
print("B_hat unique |values| per tile block:",
      len(np.unique(np.abs(np.asarray(bhat)))))

# -- 3. the shipped representation ------------------------------------------
tile, alpha = export_tile(w, spec)
packed = pack_bits(tile)
print(f"shipped: {packed.nbytes} bytes of tile bits + {alpha.nbytes} bytes "
      f"of alphas = {storage_bytes(spec.q, spec.n_alpha)} bytes "
      f"(dense fp32 would be {w.nbytes})")

# -- 4. tile-reuse matmul == dense matmul ------------------------------------
x = jax.random.normal(jax.random.PRNGKey(1), (8, n_in))
y_fast = tiled_matmul_reference(x, tile, alpha, spec)   # p-fold fewer FLOPs
y_dense = x @ bhat.T
np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_dense),
                           rtol=1e-4, atol=1e-4)
print("tile-reuse matmul matches dense: OK")

# -- 5. train a tiny sub-bit MLP ---------------------------------------------
from repro.core.policy import tbn_policy
from repro.nn.context import ModelContext
from repro.nn.linear import Dense
from repro.nn import module as mod
from repro.optim import adamw, constant
from repro.train.step import build_train_step, init_state

ctx = ModelContext(policy=tbn_policy(p=4, min_size=256, alpha_source="A"),
                   compute_dtype=jnp.float32)
l1, l2 = (Dense(64, 128, ctx, name="l1", logical=(None, None)),
          Dense(128, 4, ctx, name="l2", kind="head", logical=(None, None)))
specs = {"l1": l1.specs(), "l2": l2.specs()}
params = mod.init_params(specs, key)

w_teacher = jax.random.normal(jax.random.PRNGKey(7), (64, 4))

def loss_fn(p, batch):
    h = jax.nn.relu(l1(p["l1"], batch["x"]))
    logits = l2(p["l2"], h)
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None], -1)[:, 0]
    return jnp.mean(logz - gold), {}

opt = adamw(constant(2e-3))
step = jax.jit(build_train_step(loss_fn, opt))
state = init_state(params, opt)
for i in range(300):
    k = jax.random.fold_in(key, i)
    x = jax.random.normal(k, (64, 64))
    y = jnp.argmax(x @ w_teacher, -1)
    state, metrics = step(state, {"x": x, "y": y})
    if i % 100 == 0:
        print(f"  step {i:3d} loss {float(metrics['loss']):.3f}")
print(f"final loss {float(metrics['loss']):.3f} — trained with "
      f"{ctx.ledger.report().bits_per_param():.3f} stored bits/parameter")

"""Fault-tolerance scenario: train, kill mid-run, resume, reshard.

    PYTHONPATH=src python examples/elastic_restart.py

Simulates the production incident flow on one host:
  1. train 120 steps with async checkpoints every 40,
  2. inject a hard failure at step ~90 (the RecoveryManager restores the
     step-80 checkpoint and replays the data stream deterministically),
  3. verify the recovered run is bit-identical to an uninterrupted one,
  4. "elastic" restore: place the same checkpoint onto a different device
     layout (here: the single CPU with a different sharding object).
"""
import shutil

import jax
import numpy as np

from repro.configs import build_model, get_config
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import lm_batch
from repro.ft.checkpoint import CheckpointManager, place, restore_into
from repro.ft.recovery import RecoveryManager
from repro.nn import module as mod
from repro.nn.context import TRAIN, ModelContext
from repro.optim import adamw, constant
from repro.train.step import build_train_step, init_state

CKPT = "/tmp/tbn_elastic_example"


def run(fail_at=None, steps=120):
    cfg = get_config("granite-8b").reduced()
    model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN))
    opt = adamw(constant(1e-3))
    raw_step = jax.jit(build_train_step(model.train_forward, opt))
    calls = {"n": 0}

    def step(state, batch):
        calls["n"] += 1
        if fail_at is not None and calls["n"] == fail_at:
            raise RuntimeError("simulated host failure (kill -9)")
        return raw_step(state, batch)

    ckpt = CheckpointManager(CKPT, save_every=40, max_to_keep=2)
    rm = RecoveryManager(
        ckpt,
        make_state=lambda: init_state(
            mod.init_params(model.specs(), jax.random.PRNGKey(0)), opt),
        make_data=lambda start: DataPipeline(
            lambda s: lm_batch(0, s, 8, 64, cfg.vocab), start_step=start),
    )
    final = rm.run(step, steps)
    return final, rm


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("run A: uninterrupted 120 steps")
    ref, _ = run()

    shutil.rmtree(CKPT, ignore_errors=True)
    print("run B: failure injected at step 90 -> auto-restart from 80")
    got, rm = run(fail_at=90)
    print(f"  restarts: {rm.restarts}")

    same = jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5),
        ref.params, got.params)
    ok = all(jax.tree_util.tree_leaves(same))
    print(f"  recovered params identical to uninterrupted run: {ok}")
    assert ok

    # elastic restore: same checkpoint, different placement
    step, host = restore_into(ref, CKPT)
    dev = jax.devices()[0]
    placed = place(host, jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), host))
    print(f"  elastic restore at step {step}: "
          f"{len(jax.tree_util.tree_leaves(placed))} tensors placed")


if __name__ == "__main__":
    main()

"""Serving scenario: batched generation with packed-tile weights across
three quantization regimes, reporting the shipped-bytes ladder.

    PYTHONPATH=src python examples/serve_tiled.py
"""
import dataclasses

import jax

from repro.configs import build_model, get_config
from repro.core.policy import bwnn_policy, fp32_policy, tbn_policy
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.weights import export_serving_params, serving_bytes


def build(cfg, policy):
    cfg = dataclasses.replace(cfg, tbn=policy)
    t = build_model(cfg, ModelContext(policy=policy, mode=TRAIN))
    s = build_model(cfg, ModelContext(policy=policy, mode=SERVE,
                                      use_pallas=False))
    return cfg, t, s


def main():
    base = get_config("qwen2-moe-a2.7b").reduced()
    masters = None
    rows = []
    outputs = {}
    for name, pol in [
        ("fp32", fp32_policy()),
        ("bwnn", bwnn_policy()),
        ("tbn4", tbn_policy(p=4, min_size=1024, alpha_source="W")),
        ("tbn8", tbn_policy(p=8, min_size=1024, alpha_source="W")),
    ]:
        cfg, tm, sm = build(base, pol)
        params = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
        sp = export_serving_params(tm.specs(), sm.specs(), params, pol)
        rows.append((name, serving_bytes(params), serving_bytes(sp)))
        eng = BatchedEngine(sm, sp, ServeConfig(n_slots=2, max_len=48,
                                                chunk_tokens=8))
        reqs = [eng.submit([3, 1, 4, 1, 5], SamplingParams(max_tokens=8)),
                eng.submit([2, 7, 1, 8], SamplingParams(max_tokens=8))]
        eng.run_until_drained()
        outputs[name] = [r.output for r in reqs]

    print(f"{'regime':8} {'masters MB':>12} {'shipped MB':>12} {'ratio':>7}")
    for name, mb, sb in rows:
        print(f"{name:8} {mb/1e6:12.3f} {sb/1e6:12.3f} {mb/sb:6.1f}x")
    print("\nsample generations (same prompts):")
    for name, outs in outputs.items():
        print(f"  {name:6} {outs}")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a TBN-quantized decoder LM for a few hundred
steps with checkpoint/restart, then export + serve it.

    # ~35M-param model, a few hundred steps (CPU-sized; scale --width/--layers up)
    PYTHONPATH=src python examples/train_tbn_lm.py --steps 300

This is the paper's full lifecycle on one screen: sub-bit training
(masters W, straight-through tiles), fault-tolerant loop (kill -9 and
re-run: it resumes), export to packed tiles, batched generation.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import build_model, get_config
from repro.core.policy import tbn_policy
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import lm_batch
from repro.ft.checkpoint import CheckpointManager
from repro.ft.recovery import RecoveryManager
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.optim import adamw, cosine_with_warmup
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.weights import export_serving_params, serving_bytes
from repro.train.step import build_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--p", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/tbn_lm_example")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("granite-8b"),
        name="tbn-lm-example",
        n_layers=args.layers, d_model=args.width,
        n_heads=max(4, args.width // 64), n_kv=max(2, args.width // 128),
        head_dim=64, d_ff=args.width * 3, vocab=args.vocab,
        attn_chunk=64, remat="none",
        tbn=tbn_policy(p=args.p, min_size=16_384, alpha_source="W",
                       alpha_mode="tile"),
    )
    ctx = ModelContext(policy=cfg.tbn, mode=TRAIN, compute_dtype=jnp.float32)
    model = build_model(cfg, ctx)
    n = mod.param_count(model.specs())
    rep = ctx.ledger.report()
    print(f"model: {n/1e6:.1f}M params, TBN p={args.p}, "
          f"{rep.bits_per_param():.3f} stored bits/param "
          f"({rep.savings_vs_binary():.1f}x smaller than 1-bit)")

    opt = adamw(cosine_with_warmup(3e-4, 30, args.steps), weight_decay=0.1)
    step = jax.jit(build_train_step(model.train_forward, opt),
                   donate_argnums=(0,))

    ckpt = CheckpointManager(args.ckpt_dir, save_every=100, max_to_keep=2)
    rm = RecoveryManager(
        ckpt,
        make_state=lambda: init_state(
            mod.init_params(model.specs(), jax.random.PRNGKey(0)), opt),
        make_data=lambda start: DataPipeline(
            lambda s: lm_batch(0, s, args.batch, args.seq, cfg.vocab),
            start_step=start),
    )

    def hooks(s, state, metrics):
        if s % 25 == 0 or s == 1:
            print(f"  step {s:4d} loss {float(metrics['loss']):.4f}")

    state = rm.run(step, args.steps, hooks=hooks)

    # ---- export + serve ----------------------------------------------------
    s_model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                            compute_dtype=jnp.float32,
                                            use_pallas=False))
    sp = export_serving_params(model.specs(), s_model.specs(),
                               state.params, cfg.tbn)
    print(f"export: {serving_bytes(state.params)/1e6:.1f}MB masters -> "
          f"{serving_bytes(sp)/1e6:.2f}MB packed tiles")
    page = 16                          # KV pool page size; max_len must be
    eng = BatchedEngine(s_model, sp, ServeConfig(  # a whole page multiple
        n_slots=4, max_len=-(-(args.seq + 32) // page) * page,
        chunk_tokens=16, page_tokens=page))
    reqs = [eng.submit([1 + i, 17 * (1 + i) % cfg.vocab],
                       SamplingParams(max_tokens=12)) for i in range(4)]
    eng.run_until_drained()
    for r in reqs:
        print(f"  prompt {list(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()

"""Table 1 — CNN compression: bit-width, #Params (M-bit), savings.

Exact accounting from the layer ledger at FULL model size (instantiation
only; no training). The paper's own numbers are carried alongside for
comparison. Accuracy at full CIFAR/ImageNet scale is out of scope on this
host — the trainability *ordering* claim is validated on synthetic data in
fig6/fig7 and the quickstart example.

A MEASURED section runs VGG-Small through the real serving path both ways
(fp32 dense weights vs packed conv tiles through ``tiled_conv_infer``) and
reports the actual shipped bytes and forward latency — the ledger numbers
above are predictions; these are observations of the same model.
"""
from __future__ import annotations

from benchmarks.common import (
    fmt_table,
    ledger_for,
    measure_serve_delta,
    save_rows,
)
from repro.core.policy import bwnn_policy, tbn_policy

# (model, kwargs, paper rows {method: (bitwidth, mbit, acc)})
PAPER = {
    "resnet18": {
        "bwnn": (1.0, 10.99, 92.9), "tbn4": (0.256, 2.85, 93.1),
        "tbn8": (0.131, 1.46, 92.4), "tbn16": (0.069, 0.77, 91.2)},
    "resnet50": {
        "bwnn": (1.0, 23.45, 93.2), "tbn4": (0.259, 6.10, 94.9),
        "tbn8": (0.136, 3.21, 94.3), "tbn16": (0.075, 1.76, 93.5)},
    "vgg-small": {
        "bwnn": (1.0, 4.656, 91.3), "tbn4": (0.288, 1.340, 92.6),
        "tbn8": (0.131, 0.722, 91.5), "tbn16": (0.117, 0.520, 90.2)},
    "resnet34-imagenet": {
        "bwnn": (1.0, 21.09, 70.4), "tbn2": (0.53, 11.13, 68.9)},
}


def run(quick: bool = False):
    rows = []
    for model, kw, ps, lam in [
        ("resnet18", {}, (4, 8, 16), 64_000),
        ("resnet50", {}, (4, 8, 16), 64_000),
        ("vgg-small", {}, (4, 8, 16), 64_000),
        ("resnet34", dict(imagenet=True, classes=1000), (2,), 150_000),
    ]:
        key = "resnet34-imagenet" if kw.get("imagenet") else model
        rep = ledger_for(model, bwnn_policy(), **kw)
        paper_b = PAPER[key]["bwnn"]
        rows.append(dict(
            model=key, method="bwnn", bits_per_param=1.0,
            mbit=round(rep.universe_params / 1e6, 3),
            paper_mbit=paper_b[1], paper_acc=paper_b[2]))
        for p in ps:
            pol = tbn_policy(p=p, min_size=lam, alpha_source="A",
                             alpha_mode="tile")
            rep = ledger_for(model, pol, **kw)
            ref = PAPER[key].get(f"tbn{p}", (None, None, None))
            rows.append(dict(
                model=key, method=f"tbn{p}",
                bits_per_param=round(rep.bits_per_param(), 3),
                mbit=round(rep.mbit(), 3),
                savings=f"{rep.savings_vs_binary():.1f}x",
                paper_bits=ref[0], paper_mbit=ref[1], paper_acc=ref[2]))
    save_rows("table1_cnn", rows)
    print(fmt_table(rows, ["model", "method", "bits_per_param", "mbit",
                           "savings", "paper_bits", "paper_mbit"]))

    # measured dense-vs-packed serving delta (real conv inference path)
    pol = tbn_policy(p=4, min_size=64_000, alpha_source="A", alpha_mode="tile")
    m = measure_serve_delta("vgg-small", pol, repeats=1 if quick else 3)
    mrows = [dict(variant=k, mbytes=round(v["bytes"] / 1e6, 3),
                  latency_ms=round(v["latency_ms"], 1))
             for k, v in m.items() if k != "delta"]
    mrows.append(dict(variant="delta",
                      mbytes=f'{m["delta"]["bytes_saving"]:.1f}x smaller',
                      latency_ms=f'{m["delta"]["latency_speedup"]:.2f}x'))
    save_rows("table1_cnn_measured", mrows)
    print("\nmeasured vgg-small serving (fp32 dense vs packed conv tiles):")
    print(fmt_table(mrows, ["variant", "mbytes", "latency_ms"]))
    return rows


if __name__ == "__main__":
    run()

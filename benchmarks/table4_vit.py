"""Table 4 — Vision Transformers (ViT, Swin-lite): bits accounting +
reduced-scale synthetic image-classification ordering check."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, ledger_for, save_rows, train_classifier
from repro.core.policy import bwnn_policy, fp32_policy, tbn_policy
from repro.models.paper import build_paper_model
from repro.nn import module as mod
from repro.nn.context import ModelContext

PAPER = {
    ("vit", "bwnn"): (1.0, 9.50, 82.2), ("vit", "tbn4"): (0.253, 2.40, 82.7),
    ("vit", "tbn8"): (0.129, 1.22, 82.1),
    ("swin-lite", "bwnn"): (1.0, 26.60, 85.8),
    ("swin-lite", "tbn4"): (0.259, 6.88, 85.8),
    ("swin-lite", "tbn8"): (0.135, 3.61, 84.6),
}


def synthetic_vit_accuracy(policy, steps=120):
    from repro.data.synthetic import image_like

    ctx = ModelContext(policy=policy, compute_dtype=jnp.float32)
    model = build_paper_model("vit", ctx, dim=64, depth=2, heads=4,
                              mlp_dim=64, patch=4, img=16, classes=8)
    params = mod.init_params(model.specs(), jax.random.PRNGKey(0))

    def data(step):
        x, y = image_like(0, step, 32, 16, 8)
        return {"x": x, "y": y}

    return train_classifier(model, params, data, steps=steps)


def run(quick: bool = False):
    rows = []
    for name in ("vit", "swin-lite"):
        rep = ledger_for(name, bwnn_policy())
        rows.append(dict(model=name, method="bwnn", bits=1.0,
                         mbit=round(rep.universe_params / 1e6, 3),
                         paper_mbit=PAPER[(name, "bwnn")][1]))
        for p in (4, 8):
            pol = tbn_policy(p=p, min_size=64_000, alpha_source="A")
            rep = ledger_for(name, pol)
            ref = PAPER[(name, f"tbn{p}")]
            rows.append(dict(model=name, method=f"tbn{p}",
                             bits=round(rep.bits_per_param(), 3),
                             mbit=round(rep.mbit(), 3),
                             savings=f"{rep.savings_vs_binary():.1f}x",
                             paper_bits=ref[0], paper_mbit=ref[1]))
    steps = 40 if quick else 120
    accs = {}
    for mode, pol in [("fp32", fp32_policy()), ("bwnn", bwnn_policy()),
                      ("tbn4", tbn_policy(p=4, min_size=2048, alpha_source="A"))]:
        accs[mode] = synthetic_vit_accuracy(pol, steps)
    rows.append(dict(model="synthetic-vit(reduced)", method="acc-ordering",
                     **{f"acc_{k}": round(v, 3) for k, v in accs.items()}))
    save_rows("table4_vit", rows)
    print(fmt_table(rows[:-1], ["model", "method", "bits", "mbit", "savings",
                                "paper_bits", "paper_mbit"]))
    print("synthetic reduced-scale accuracy:", rows[-1])
    return rows


if __name__ == "__main__":
    run()

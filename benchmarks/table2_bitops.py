"""Table 2 — Bit-ops of ResNets: full-precision vs binary vs TBN.

MACs per conv = weight params x output spatial positions (resolution
walked analytically per family); binary ops = MACs of binarized layers;
TBN executes one tile replica and replicates output channels, so tiled
layers cost MACs / p (the paper's Section 4.1 observation). Units: G-ops.

Besides the analytic paper rows (kind="analytic"), this bench emits one
MEASURED row (kind="measured"): wall-clock decode-matvec latency of the
float vs int8 vs xnor compute paths on the same packed tile words, on
this host (structured jnp backends, use_pallas=False — the Pallas
kernels replace them op-for-op on TPU). This pins the claim that the
integer paths do less work per tick, not just fewer analytic ops.
"""
from __future__ import annotations

import time

from benchmarks.common import fmt_table, save_rows
from repro.core.policy import tbn_policy
from repro.models.paper import ResNet
from repro.nn.context import ModelContext
import jax
import jax.numpy as jnp

PAPER = {  # (fp G-flops x32^2 scale aside, binary G-ops, tbn G-ops, saving)
    ("resnet18", 4): (35.03, 0.547, 0.082),
    ("resnet50", 4): (78.12, 1.22, 0.155),
    ("resnet34", 2): (225.66, 3.526, 0.58),
}


def conv_macs(model: ResNet, imagenet: bool):
    """[(name, params, out_hw, tiled_p)] resolution walk."""
    res = 56 if imagenet else 32    # post stem (+pool for imagenet)
    out = []
    ledger = {r.name: r for r in model.ctx.ledger.records}
    stem = ledger["stem"]
    stem_hw = (112 if imagenet else 32) ** 2
    out.append(("stem", stem.n, stem_hw, stem.spec.p if stem.spec else 1))
    for name, c_mid, stride, c_out in model.block_names:
        res = res // stride
        for suffix in ([".c1", ".c2"] if model.kind == "basic"
                       else [".c1", ".c2", ".c3"]) + [".down"]:
            rec = ledger.get(name + suffix)
            if rec is None:
                continue
            out.append((name + suffix, rec.n, res * res,
                        rec.spec.p if rec.spec else 1))
    head = ledger["head"]
    out.append(("head", head.n, 1, head.spec.p if head.spec else 1))
    return out


def measured_decode_matvec(quick: bool = False) -> dict:
    """Best-of-N jitted latency of the three compute paths on the decode
    matvec shape (m=4 tokens, n_in=2048, r=512 unique tile rows)."""
    from repro.core.packing import pack_bits
    from repro.kernels.ops import _dense_unique_local
    from repro.roofline.analysis import integer_dense_ops

    m, n_in, r = 4, 2048, 512
    repeats = 5 if quick else 20
    kx, kt = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (m, n_in))
    tiles = jnp.where(jax.random.bernoulli(kt, 0.5, (r, n_in)), 1.0, -1.0)
    packed = pack_bits(tiles)

    row = dict(kind="measured", model="decode_matvec",
               m=m, n_in=n_in, r=r)
    for path in ("float", "int8", "xnor"):
        fwd = jax.jit(lambda xx, pp, cp=path: _dense_unique_local(
            xx, pp, n_in=n_in, block_m=128, block_r=256, block_k=1024,
            use_pallas=False, compute_path=cp))
        fwd(x, packed).block_until_ready()       # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fwd(x, packed).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        row[f"{path}_us"] = round(1e6 * best, 2)
        row[f"{path}_int_ops"] = integer_dense_ops(m, n_in, r,
                                                   compute_path=path)
    row["int8_speedup_vs_float"] = round(
        row["float_us"] / row["int8_us"], 3)
    row["xnor_speedup_vs_float"] = round(
        row["float_us"] / row["xnor_us"], 3)
    return row


def run(quick: bool = False):
    rows = []
    for depth, p, imagenet, lam in [(18, 4, False, 64_000),
                                    (50, 4, False, 64_000),
                                    (34, 2, True, 150_000)]:
        pol = tbn_policy(p=p, min_size=lam, alpha_source="A")
        ctx = ModelContext(policy=pol, compute_dtype=jnp.float32)
        kw = dict(imagenet=imagenet, classes=1000 if imagenet else 10)
        model = ResNet(depth, ctx, **kw)
        macs = conv_macs(model, imagenet)
        total = sum(n * hw for _, n, hw, _ in macs)
        binary_ops = total                       # 1 bit-op per MAC
        tbn_ops = sum(n * hw / pp for _, n, hw, pp in macs)
        key = (f"resnet{depth}", p)
        paper = PAPER[key]
        rows.append(dict(
            kind="analytic",
            model=f"resnet{depth}" + ("-imagenet" if imagenet else ""),
            p=p,
            fp_gflops=round(32 * 32 * total / 1e9, 2),
            binary_gops=round(binary_ops / 1e9, 3),
            tbn_gops=round(tbn_ops / 1e9, 3),
            saving=f"{binary_ops / tbn_ops:.1f}x",
            paper_binary=paper[1], paper_tbn=paper[2],
            paper_saving=f"{paper[1] / paper[2]:.1f}x",
        ))
    measured = measured_decode_matvec(quick)
    rows.append(measured)
    save_rows("table2_bitops", rows)
    analytic = [r for r in rows if r["kind"] == "analytic"]
    print(fmt_table(analytic,
                    ["model", "p", "binary_gops", "tbn_gops", "saving",
                     "paper_binary", "paper_tbn", "paper_saving"]))
    print()
    print(fmt_table([measured],
                    ["model", "float_us", "int8_us", "xnor_us",
                     "int8_speedup_vs_float", "xnor_speedup_vs_float"]))
    return rows


if __name__ == "__main__":
    run()

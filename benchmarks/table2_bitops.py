"""Table 2 — Bit-ops of ResNets: full-precision vs binary vs TBN.

MACs per conv = weight params x output spatial positions (resolution
walked analytically per family); binary ops = MACs of binarized layers;
TBN executes one tile replica and replicates output channels, so tiled
layers cost MACs / p (the paper's Section 4.1 observation). Units: G-ops.
"""
from __future__ import annotations


from benchmarks.common import fmt_table, save_rows
from repro.core.policy import tbn_policy
from repro.models.paper import ResNet
from repro.nn.context import ModelContext
import jax.numpy as jnp

PAPER = {  # (fp G-flops x32^2 scale aside, binary G-ops, tbn G-ops, saving)
    ("resnet18", 4): (35.03, 0.547, 0.082),
    ("resnet50", 4): (78.12, 1.22, 0.155),
    ("resnet34", 2): (225.66, 3.526, 0.58),
}


def conv_macs(model: ResNet, imagenet: bool):
    """[(name, params, out_hw, tiled_p)] resolution walk."""
    res = 56 if imagenet else 32    # post stem (+pool for imagenet)
    out = []
    ledger = {r.name: r for r in model.ctx.ledger.records}
    stem = ledger["stem"]
    stem_hw = (112 if imagenet else 32) ** 2
    out.append(("stem", stem.n, stem_hw, stem.spec.p if stem.spec else 1))
    for name, c_mid, stride, c_out in model.block_names:
        res = res // stride
        for suffix in ([".c1", ".c2"] if model.kind == "basic"
                       else [".c1", ".c2", ".c3"]) + [".down"]:
            rec = ledger.get(name + suffix)
            if rec is None:
                continue
            out.append((name + suffix, rec.n, res * res,
                        rec.spec.p if rec.spec else 1))
    head = ledger["head"]
    out.append(("head", head.n, 1, head.spec.p if head.spec else 1))
    return out


def run(quick: bool = False):
    rows = []
    for depth, p, imagenet, lam in [(18, 4, False, 64_000),
                                    (50, 4, False, 64_000),
                                    (34, 2, True, 150_000)]:
        pol = tbn_policy(p=p, min_size=lam, alpha_source="A")
        ctx = ModelContext(policy=pol, compute_dtype=jnp.float32)
        kw = dict(imagenet=imagenet, classes=1000 if imagenet else 10)
        model = ResNet(depth, ctx, **kw)
        macs = conv_macs(model, imagenet)
        total = sum(n * hw for _, n, hw, _ in macs)
        binary_ops = total                       # 1 bit-op per MAC
        tbn_ops = sum(n * hw / pp for _, n, hw, pp in macs)
        key = (f"resnet{depth}", p)
        paper = PAPER[key]
        rows.append(dict(
            model=f"resnet{depth}" + ("-imagenet" if imagenet else ""),
            p=p,
            fp_gflops=round(32 * 32 * total / 1e9, 2),
            binary_gops=round(binary_ops / 1e9, 3),
            tbn_gops=round(tbn_ops / 1e9, 3),
            saving=f"{binary_ops / tbn_ops:.1f}x",
            paper_binary=paper[1], paper_tbn=paper[2],
            paper_saving=f"{paper[1] / paper[2]:.1f}x",
        ))
    save_rows("table2_bitops", rows)
    print(fmt_table(rows, ["model", "p", "binary_gops", "tbn_gops", "saving",
                           "paper_binary", "paper_tbn", "paper_saving"]))
    return rows


if __name__ == "__main__":
    run()

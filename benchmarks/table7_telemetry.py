"""Telemetry overhead guard: decode tick latency with telemetry on vs off.

The telemetry layer is allowed on the tick thread only because it is
cheap — a handful of ``perf_counter`` reads, one ``bisect`` per
histogram observe, and ``block_until_ready`` fences the tick loop was
already paying implicitly at the host sync. This bench measures that
claim instead of asserting it in a comment: two FRESH engines (jit
caches never shared), identical stochastic request batches, alternating
measurement rounds so neither variant systematically rides a warmer
machine, and per-tick wall clock sampled around ``step()`` from the
outside — the same clock both variants pay.

Reported per variant: steady-state decode tick p50 (min of per-round
p50s, which strips scheduler-noise outliers) and p99, plus the on/off
p50 ratio and a token-parity flag on the telemetry row. CI asserts
``p50_ratio <= 1.05`` and ``parity == true`` from the saved JSON — the
acceptance gate that telemetry is observation-only and under 5%.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save_rows


def _build_engine(telemetry: bool):
    """Fresh TRAIN->SERVE export + engine per variant: the jitted tick
    callables cache on the model object, so sharing one would let the
    second variant skip compiles the first one paid."""
    from repro.configs import build_model, get_config
    from repro.nn import module as mod
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.serve.engine import BatchedEngine, ServeConfig
    from repro.serve.weights import export_serving_params

    cfg = get_config("granite-8b").reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    eng = BatchedEngine(sm, sp, ServeConfig(
        n_slots=4, max_len=64, chunk_tokens=16, page_tokens=8, seed=0,
        telemetry=telemetry))
    return cfg, eng


def _round(eng, prompts, max_tokens: int, skip_ticks: int):
    """Submit one identical batch, drain it, and return (per-tick wall
    seconds past the prefill ramp, outputs). All requests go in before
    the first tick so every measured tick carries the same live-slot
    load in both variants."""
    from repro.serve.sampling import SamplingParams

    reqs = [eng.submit(p, SamplingParams(temperature=0.8, top_k=8,
                                         max_tokens=max_tokens, seed=7 + i))
            for i, p in enumerate(prompts)]
    ticks = []
    while eng.has_work:
        t0 = time.perf_counter()
        eng.step()
        ticks.append(time.perf_counter() - t0)
        if len(ticks) > 10_000:
            raise RuntimeError("engine failed to drain")
    outputs = [list(r.output) for r in reqs]
    # the first ticks are admission + chunked prefill; the steady-state
    # decode tick is what the overhead budget is written against
    return ticks[skip_ticks:], outputs


def run(quick: bool = False):
    rounds = 3 if quick else 5
    max_tokens = 24 if quick else 48
    rng = np.random.RandomState(0)

    engines = {}
    for variant in ("off", "on"):
        cfg, eng = _build_engine(telemetry=(variant == "on"))
        eng.warmup()  # AOT: no variant pays trace+compile inside a tick
        engines[variant] = eng
    prompts = [rng.randint(0, cfg.vocab, size=8).tolist() for _ in range(4)]

    samples = {"off": [], "on": []}
    round_p50 = {"off": [], "on": []}
    outputs = {}
    for r in range(rounds):
        # alternate which variant goes first each round so neither one
        # systematically runs on a warmer machine
        order = ("off", "on") if r % 2 == 0 else ("on", "off")
        for variant in order:
            ticks, outs = _round(engines[variant], prompts, max_tokens,
                                 skip_ticks=4)
            samples[variant].extend(ticks)
            round_p50[variant].append(float(np.percentile(ticks, 50)))
            prev = outputs.setdefault(variant, outs)
            assert prev == outs, f"{variant}: tokens drifted across rounds"
    # observation-only means observation-only: the telemetry engine must
    # emit byte-identical tokens, or the 5% budget is measuring a lie
    parity = outputs["on"] == outputs["off"]
    assert parity, "telemetry changed sampled tokens"

    rows = []
    for variant in ("off", "on"):
        p50 = min(round_p50[variant])
        rows.append(dict(
            variant=f"telemetry={variant}",
            rounds=rounds,
            ticks=len(samples[variant]),
            tick_p50_ms=round(1e3 * p50, 4),
            tick_p99_ms=round(1e3 * float(
                np.percentile(samples[variant], 99)), 4),
        ))
    off, on = rows
    on["p50_ratio"] = round(on["tick_p50_ms"] / off["tick_p50_ms"], 4)
    on["parity"] = parity
    tel = engines["on"].tel
    on["retraces"] = tel.retraces.get()
    on["tick_observations"] = tel.registry.value_of("serve_tick_seconds")
    save_rows("table7_telemetry", rows)
    print(fmt_table(rows, [
        "variant", "rounds", "ticks", "tick_p50_ms", "tick_p99_ms",
        "p50_ratio", "parity", "retraces",
    ]))
    return rows


if __name__ == "__main__":
    run(quick=True)

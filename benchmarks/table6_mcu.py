"""Table 6 — MCU deployment accounting + Algorithm 1 golden model.

The paper's numbers are byte-exact reproducible:

  storage  BWNN = (784*128 + 128*10) bits /8           = 12.70 KB
           TBN4 = 784*128/4 bits + 4 alphas + 1280 bits = 3.32 KB
  memory   BWNN = fp32 input (3.14) + layer-1 weights (12.54) + out (0.5)
           TBN4 = fp32 input (3.14) + one tile          (3.14) + out (0.5)

We recompute those from the ledger/TileSpec (no hand constants) and
validate the C kernel of Algorithm 1 (tile index walking + per-tile alpha,
fused ReLU) as a Python golden model against the tiled matmul oracle.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import fmt_table, save_rows
from repro.core.tiling import export_tile, plan_tiling, tiled_weight

PAPER = dict(bwnn_storage_kb=12.70, tbn_storage_kb=3.32,
             bwnn_mem_kb=16.20, tbn_mem_kb=6.80,
             bwnn_fps=704.5, tbn_fps=705.1)


def algorithm1_forward(tile, alphas, x, m, n, q):
    """Literal Algorithm 1: FC layer with tiling, many alphas, fused ReLU.

    Walks the flat weight row-major, reusing tile t of size q and stepping
    alpha at each tile boundary — the C kernel's exact control flow.
    """
    y = np.zeros(m, np.float32)
    t_i = 0
    a_i = 0
    for i in range(m):
        acc = 0.0
        for j in range(n):
            acc += float(tile[t_i]) * float(x[j]) * float(alphas[a_i])
            if t_i == q - 1:
                t_i = 0
                a_i += 1
            else:
                t_i += 1
        y[i] = max(0.0, acc)
    return y


def run(quick: bool = False):
    p = 4
    spec1 = plan_tiling((128, 784), p=p, min_size=1024, alpha_mode="tile",
                        alpha_source="W", require_aligned=True)
    n1, n2 = 128 * 784, 128 * 10

    # ---- storage (bits actually shipped) ----
    bwnn_storage = (n1 + n2) / 8 / 1024
    tbn_storage = (spec1.q / 8 + 4 * spec1.n_alpha + n2 / 8) / 1024

    # ---- peak memory (first layer live set) ----
    x_kb = 784 * 4 / 1024
    out_kb = 128 * 4 / 1024
    bwnn_mem = x_kb + n1 / 8 / 1024 + out_kb
    tbn_mem = x_kb + spec1.q / 8 / 1024 + out_kb

    rows = [
        dict(model="bwnn", storage_kb=round(bwnn_storage, 2),
             mem_kb=round(bwnn_mem, 2),
             paper_storage=PAPER["bwnn_storage_kb"],
             paper_mem=PAPER["bwnn_mem_kb"]),
        dict(model="tbn4", storage_kb=round(tbn_storage, 2),
             mem_kb=round(tbn_mem, 2),
             paper_storage=PAPER["tbn_storage_kb"],
             paper_mem=PAPER["tbn_mem_kb"]),
    ]

    # ---- Algorithm 1 golden model vs the oracle ----
    k = jax.random.PRNGKey(0)
    w = jax.random.normal(k, (128, 784))
    t, alphas = export_tile(w, spec1)
    x = jax.random.normal(jax.random.PRNGKey(1), (784,))
    y_alg1 = algorithm1_forward(
        np.asarray(t), np.asarray(alphas), np.asarray(x), 128, 784, spec1.q)
    bhat = tiled_weight(w, spec1)
    y_ref = np.maximum(0.0, np.asarray(x) @ np.asarray(bhat).T)
    err = float(np.max(np.abs(y_alg1 - y_ref)))
    rows.append(dict(model="algorithm1-vs-oracle", max_abs_err=round(err, 5),
                     match=bool(err < 1e-2)))
    save_rows("table6_mcu", rows)
    print(fmt_table(rows, ["model", "storage_kb", "mem_kb", "paper_storage",
                           "paper_mem", "max_abs_err", "match"]))
    assert err < 1e-2, "Algorithm 1 golden model diverged from the oracle"
    return rows


if __name__ == "__main__":
    run()

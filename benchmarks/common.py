"""Shared benchmark utilities: ledgers, short synthetic training runs."""
from __future__ import annotations

import functools
import json
import pathlib
import platform
import subprocess
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core.policy import TBNPolicy, bwnn_policy, fp32_policy, tbn_policy
from repro.models.paper import build_paper_model
from repro.nn import module as mod
from repro.nn.context import ModelContext

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results" / "bench"


def ledger_for(name: str, policy: TBNPolicy, **kw):
    ctx = ModelContext(policy=policy, compute_dtype=jnp.float32)
    build_paper_model(name, ctx, **kw)
    return ctx.ledger.report()


def policies(p: int, lam: int = 64_000, alpha_source="A", alpha_mode="tile"):
    return {
        "fp32": fp32_policy(),
        "bwnn": bwnn_policy(),
        f"tbn{p}": tbn_policy(p=p, min_size=lam, alpha_source=alpha_source,
                              alpha_mode=alpha_mode),
    }


def train_classifier(
    model, params, data_fn, *, steps=150, lr=1e-3, eval_batches=8,
    log=False,
) -> float:
    """Short AdamW run on synthetic labeled data; returns eval accuracy."""
    from repro.optim import adamw, constant
    from repro.train.step import build_train_step, init_state

    opt = adamw(constant(lr))

    def loss_fn(p, batch):
        logits = model(p, batch["x"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), {}

    step = jax.jit(build_train_step(loss_fn, opt))
    state = init_state(params, opt)
    for i in range(steps):
        state, metrics = step(state, data_fn(i))
        if log and i % 50 == 0:
            print(f"    step {i} loss {float(metrics['loss']):.3f}")
    correct = total = 0
    for i in range(eval_batches):
        b = data_fn(10_000 + i)
        pred = jnp.argmax(model(state.params, b["x"]), axis=-1)
        correct += int(jnp.sum(pred == b["y"]))
        total += b["y"].shape[0]
    return correct / total


def measure_serve_delta(
    name: str,
    policy: TBNPolicy,
    *,
    img: int = 32,
    batch: int = 4,
    repeats: int = 3,
    **kw,
) -> Dict[str, Dict[str, float]]:
    """MEASURED dense-vs-packed serving delta for a conv model.

    Builds ``name`` once in TRAIN mode, exports the SERVE form twice — the
    fp32 dense representation and the packed TBN representation — and
    reports exact shipped bytes (``serving_bytes``) plus wall-clock forward
    latency of each jitted serve path on this host. The packed path is the
    structured tile-reuse math (``use_pallas=False``) so the numbers are
    host-measurable; on TPU the Pallas kernels replace it with the same
    FLOPs. This measures *cost* (bytes moved / work done), not accuracy —
    the function-parity claims live in tests/test_tiled_conv.py and
    tests/test_serve.py.
    """
    from repro.nn.context import SERVE, TRAIN
    from repro.serve.weights import export_serving_params, serving_bytes

    tctx = ModelContext(policy=policy, mode=TRAIN, compute_dtype=jnp.float32)
    tm = build_paper_model(name, tctx, **kw)
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, img, img, 3))

    out: Dict[str, Dict[str, float]] = {}
    for label, pol in [("dense_fp32", fp32_policy()), ("packed", policy)]:
        sctx = ModelContext(policy=pol, mode=SERVE, compute_dtype=jnp.float32,
                            use_pallas=False)
        sm = build_paper_model(name, sctx, **kw)
        sp = export_serving_params(tm.specs(), sm.specs(), tp, pol)
        fwd = jax.jit(lambda p, x, m=sm: m(p, x))
        fwd(sp, x).block_until_ready()  # compile
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fwd(sp, x).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        out[label] = {
            "bytes": float(serving_bytes(sp)),
            "latency_ms": 1e3 * best,
        }
    d, p_ = out["dense_fp32"], out["packed"]
    out["delta"] = {
        "bytes_saving": d["bytes"] / p_["bytes"],
        "latency_speedup": d["latency_ms"] / p_["latency_ms"],
    }
    return out


@functools.lru_cache(maxsize=1)
def provenance() -> dict:
    """Where did these numbers come from — stamped into every saved bench
    row so a JSON file found on disk six months later answers "which
    commit, which backend, which host" by itself. Cached once per
    process: the answer cannot change mid-run."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parents[1],
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        commit = "unknown"
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "host": platform.node(),
        "python": platform.python_version(),
        "commit": commit,
    }


def save_rows(name: str, rows: List[dict]):
    RESULTS.mkdir(parents=True, exist_ok=True)
    prov = provenance()
    stamped = [{**r, "provenance": prov} for r in rows]
    (RESULTS / f"{name}.json").write_text(json.dumps(stamped, indent=1))


def fmt_table(rows: List[dict], cols: List[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    out = ["  ".join(c.ljust(widths[c]) for c in cols)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
    return "\n".join(out)

"""Benchmark orchestrator — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1_cnn]

Prints ``name,seconds,rows`` CSV lines plus each benchmark's table;
row-level JSON lands under results/bench/. A per-bench status record
(``run_summary.json``) is written after EVERY benchmark — including the
ones that fail — and the process exits nonzero when any benchmark failed,
so CI sees both the signal and the partial results.
"""
from __future__ import annotations

import argparse
import importlib
import json
import time
import traceback

from benchmarks.common import RESULTS, provenance

BENCHES = [
    "table1_cnn",
    "table2_bitops",
    "table3_pointnet",
    "table4_vit",
    "table5_timeseries",
    "table6_mcu",
    "table7_inference_memory",
    "table7_load_serving",
    "table7_model_families",
    "table7_telemetry",
    "fig6_layer_size",
    "fig7_hparams",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short training runs (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else BENCHES
    RESULTS.mkdir(parents=True, exist_ok=True)
    summary_path = RESULTS / "run_summary.json"
    summary = []
    failures = 0
    for name in names:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        rec = dict(name=name, quick=args.quick, provenance=provenance())
        try:
            # import inside the try: a bench module that fails at import
            # is a recorded failure, not an orchestrator crash
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run(quick=args.quick)
            rec.update(status="ok", rows=len(rows) if rows is not None else 0)
        except Exception as e:
            traceback.print_exc()
            failures += 1
            rec.update(status="error", error=f"{type(e).__name__}: {e}")
        rec["seconds"] = round(time.time() - t0, 1)
        summary.append(rec)
        # flush after every bench so a later crash/kill loses nothing
        summary_path.write_text(json.dumps(summary, indent=1))
    print("\nname,seconds,rows")
    for rec in summary:
        print(f"{rec['name']},{rec['seconds']:.1f},{rec.get('rows', -1)}")
    if failures:
        print(f"{failures} benchmark(s) FAILED — see {summary_path}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Benchmark orchestrator — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table1_cnn]

Prints ``name,seconds,rows`` CSV lines plus each benchmark's table;
row-level JSON lands under results/bench/.
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

BENCHES = [
    "table1_cnn",
    "table2_bitops",
    "table3_pointnet",
    "table4_vit",
    "table5_timeseries",
    "table6_mcu",
    "table7_inference_memory",
    "fig6_layer_size",
    "fig7_hparams",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short training runs (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    names = [args.only] if args.only else BENCHES
    summary = []
    failures = 0
    for name in names:
        mod = importlib.import_module(f"benchmarks.{name}")
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            rows = mod.run(quick=args.quick)
            dt = time.time() - t0
            summary.append((name, dt, len(rows)))
        except Exception:
            traceback.print_exc()
            failures += 1
            summary.append((name, time.time() - t0, -1))
    print("\nname,seconds,rows")
    for name, dt, n in summary:
        print(f"{name},{dt:.1f},{n}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

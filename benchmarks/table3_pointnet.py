"""Table 3 — PointNet bits accounting (cls / part / sem) + a short
synthetic point-cloud training check (clustered point clouds; validates
the TBN_4 ~ BWNN ordering at reduced scale)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, ledger_for, save_rows, train_classifier
from repro.core.policy import bwnn_policy, fp32_policy, tbn_policy
from repro.models.paper import build_paper_model
from repro.nn import module as mod
from repro.nn.context import ModelContext

PAPER = {
    ("cls", "bwnn"): (1.0, 3.48, 89.20), ("cls", "tbn4"): (0.259, 0.90, 88.67),
    ("cls", "tbn8"): (0.136, 0.47, 87.20),
    ("part", "bwnn"): (1.0, 8.34, 76.1), ("part", "tbn4"): (0.340, 2.68, 76.3),
    ("part", "tbn8"): (0.207, 1.73, 75.1),
    ("sem", "bwnn"): (1.0, 3.53, 69.50), ("sem", "tbn4"): (0.431, 1.52, 67.55),
    ("sem", "tbn8"): (0.337, 1.19, 65.70),
}

TASKS = {
    "cls": dict(task="cls", classes=40, widths=(64, 64, 64, 128, 1024)),
    "part": dict(task="part", classes=50, widths=(64, 128, 128, 512, 2048)),
    "sem": dict(task="sem", classes=13, widths=(64, 64, 64, 128, 1024)),
}


def synthetic_cls_accuracy(policy, steps=120):
    """Tiny PointNet on clustered synthetic clouds."""
    from repro.data.synthetic import point_cloud

    ctx = ModelContext(policy=policy, compute_dtype=jnp.float32)
    model = build_paper_model(
        "pointnet", ctx, task="cls", classes=8,
        widths=(16, 16, 16, 32, 64))
    params = mod.init_params(model.specs(), jax.random.PRNGKey(0))

    def data(step):
        pts, labels = point_cloud(0, step, 32, 64, 8)
        return {"x": pts, "y": labels}

    return train_classifier(model, params, data, steps=steps)


def run(quick: bool = False):
    rows = []
    for task, kw in TASKS.items():
        rep = ledger_for("pointnet", bwnn_policy(), **kw)
        rows.append(dict(task=task, method="bwnn", bits=1.0,
                         mbit=round(rep.universe_params / 1e6, 3),
                         paper_mbit=PAPER[(task, "bwnn")][1]))
        for p in (4, 8):
            pol = tbn_policy(p=p, min_size=64_000, alpha_source="A")
            rep = ledger_for("pointnet", pol, **kw)
            ref = PAPER[(task, f"tbn{p}")]
            rows.append(dict(task=task, method=f"tbn{p}",
                             bits=round(rep.bits_per_param(), 3),
                             mbit=round(rep.mbit(), 3),
                             savings=f"{rep.savings_vs_binary():.1f}x",
                             paper_bits=ref[0], paper_mbit=ref[1]))
    steps = 40 if quick else 120
    accs = {}
    for mode, pol in [("fp32", fp32_policy()), ("bwnn", bwnn_policy()),
                      ("tbn4", tbn_policy(p=4, min_size=2048, alpha_source="A"))]:
        accs[mode] = synthetic_cls_accuracy(pol, steps)
    rows.append(dict(task="synthetic-cls(reduced)", method="acc-ordering",
                     **{f"acc_{k}": round(v, 3) for k, v in accs.items()}))
    save_rows("table3_pointnet", rows)
    print(fmt_table(rows[:-1], ["task", "method", "bits", "mbit", "savings",
                                "paper_bits", "paper_mbit"]))
    print("synthetic reduced-scale accuracy:", rows[-1])
    return rows


if __name__ == "__main__":
    run()

"""Load-serving benchmark: the async front-end under seeded traffic.

Boots the real ``EngineServer`` (HTTP + SSE, admission queue, detokenize
backlog thread) in-process, replays a deterministic Poisson trace
(benchmarks/loadgen.py) at fixed QPS through the actual wire protocol,
and reports client-observed tail latency:

* p50/p99 TTFT and p50/p99 ITL (from SSE event receive timestamps),
* sustained tokens/s over the replay window,
* engine counters — peak queue depth, pool page utilization,
  preempt-free tick rate — from the extended ``BatchedEngine.stats()``.

Two variants, FRESH models each (the jitted tick callables cache on the
model object, so reusing one would let the "cold" variant ride the warm
variant's traces):

* ``aot=off`` — first request pays trace+compile inside its TTFT,
* ``aot=on``  — ``warmup()`` AOT-compiles every tick executable before
  the socket binds; the benchmark asserts the warm first-request TTFT
  strictly beats the cold one (the point of shipping AOT at all).

Then two SCHEDULER variants on a saturating mixed-class trace (25%
interactive / 75% batch, same traffic byte-for-byte in both — the class
stream rides its own rng):

* ``sched=fifo``             — classes on the wire, engine ignores them,
* ``sched=priority+preempt`` — class-aware admission + preempt-and-resume.

The acceptance gate is the tentpole claim measured end-to-end: the
priority engine's INTERACTIVE p99 TTFT strictly beats FIFO's, while
per-request tokens stay byte-identical (scheduling moves when, not what).
"""
from __future__ import annotations

import asyncio
import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, save_rows
from benchmarks.loadgen import (
    LoadSpec,
    check_metrics,
    generate,
    replay,
    scrape_metrics,
    server_quantiles,
    summarize,
    summarize_by_class,
)


def _build_engine(vocab_hint=None, *, max_queued, n_slots, max_len, seed=0,
                  **cfg_over):
    """Fresh TRAIN->SERVE export + engine (never shares jit caches)."""
    from repro.configs import build_model, get_config
    from repro.nn import module as mod
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.serve.engine import BatchedEngine, ServeConfig
    from repro.serve.weights import export_serving_params

    cfg = get_config("granite-8b").reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(seed))
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    eng = BatchedEngine(sm, sp, ServeConfig(
        n_slots=n_slots, max_len=max_len, chunk_tokens=16,
        page_tokens=8, seed=seed, max_queued=max_queued, **cfg_over))
    return cfg, eng


async def _run_variant(aot: bool, spec: LoadSpec, *, n_slots, max_len) -> dict:
    from repro.serve.server import EngineServer, ServerConfig

    cfg, eng = _build_engine(max_queued=max(64, spec.n_requests + 1),
                             n_slots=n_slots, max_len=max_len)
    spec = LoadSpec(**{**spec.__dict__, "vocab": cfg.vocab})
    schedule = generate(spec)
    srv = EngineServer(eng, ServerConfig(host="127.0.0.1", port=0))
    t0 = time.perf_counter()
    port = await srv.start(aot=aot)
    startup_s = time.perf_counter() - t0
    try:
        # scrape /metrics around the replay: the telemetry contract
        # (required families present, counters monotonic) is checked on
        # every bench run, and the server-side histogram quantiles land
        # beside the client-measured ones in the same row
        before = await scrape_metrics("127.0.0.1", port)
        results = await replay("127.0.0.1", port, spec, schedule)
        after = await scrape_metrics("127.0.0.1", port)
        check_metrics(before, after)
        stats = srv.stats()
    finally:
        await srv.close()
    row = dict(variant=f"aot={'on' if aot else 'off'}",
               qps=spec.qps, startup_s=round(startup_s, 2))
    row.update(summarize(results))
    row.update(server_quantiles(after))
    first = min((r for r in results if r["ttft_s"] is not None),
                key=lambda r: r["index"], default=None)
    row["first_ttft_ms"] = (round(1e3 * first["ttft_s"], 2)
                            if first else None)
    row.update(
        peak_queue_depth=stats["peak_queue_depth"],
        page_utilization=round(float(stats.get("page_utilization", 0.0)), 3),
        preempt_free_tick_rate=round(
            float(stats["preempt_free_tick_rate"]), 3),
        detok_backlog=stats["detok_backlog"],
    )
    return row


async def _run_sched_variant(mode: str, spec: LoadSpec, *,
                             n_slots, max_len) -> dict:
    """One scheduler variant (AOT-warm both times, fresh model): replay
    the mixed-class trace and report per-class client-observed TTFT plus
    the engine's preemption counters."""
    from repro.serve.server import EngineServer, ServerConfig

    pri = mode != "fifo"
    cfg, eng = _build_engine(max_queued=max(64, spec.n_requests + 1),
                             n_slots=n_slots, max_len=max_len,
                             priorities=pri, preempt=pri)
    spec = LoadSpec(**{**spec.__dict__, "vocab": cfg.vocab})
    schedule = generate(spec)
    srv = EngineServer(eng, ServerConfig(host="127.0.0.1", port=0))
    port = await srv.start(aot=True)
    try:
        results = await replay("127.0.0.1", port, spec, schedule)
        stats = srv.stats()
    finally:
        await srv.close()
    row = dict(variant=f"sched={mode}", qps=spec.qps)
    row.update(summarize(results))
    by_class = summarize_by_class(results)
    for cls in ("interactive", "batch"):
        s = by_class.get(cls, {})
        row[f"{cls}_ttft_p50_ms"] = s.get("ttft_p50_ms")
        row[f"{cls}_ttft_p99_ms"] = s.get("ttft_p99_ms")
    row.update(
        preempts=stats["preempts"],
        resumes=stats["resumes"],
        preempted_tokens=stats["preempted_tokens"],
        peak_queue_depth=stats["peak_queue_depth"],
        preempt_free_tick_rate=round(
            float(stats["preempt_free_tick_rate"]), 3),
    )
    return row


def run(quick: bool = False):
    spec = LoadSpec(
        qps=8.0 if quick else 16.0,
        n_requests=12 if quick else 48,
        seed=0,
        prompt_mix=((6, 0.5), (12, 0.35), (20, 0.15)),
        output_mix=((4, 0.5), (8, 0.3), (12, 0.2)),
        shared_prefix_ratio=0.5,
        shared_prefix_len=8,
        n_prefix_groups=2,
    )
    n_slots, max_len = 4, 64
    rows = []
    for aot in (False, True):  # cold first: warm must not inherit traces
        rows.append(asyncio.run(_run_variant(
            aot, spec, n_slots=n_slots, max_len=max_len)))
    cold, warm = rows
    # the acceptance gate: AOT warmup must strictly reduce the first
    # request's TTFT (otherwise the warmup path compiled the wrong shapes)
    assert warm["first_ttft_ms"] < cold["first_ttft_ms"], (
        f"AOT warmup did not reduce first-request TTFT: "
        f"cold {cold['first_ttft_ms']}ms vs warm {warm['first_ttft_ms']}ms")
    speedup = cold["first_ttft_ms"] / max(warm["first_ttft_ms"], 1e-9)
    for r in rows:
        r["first_ttft_speedup"] = round(speedup, 1) if r is warm else 1.0
    # --- scheduler variants: interactive arrivals inside a batch flood,
    # engine saturated (2 slots, long outputs, arrival rate > service
    # rate) so FIFO queueing delay is what the interactive class pays
    sched_spec = LoadSpec(
        qps=40.0 if quick else 48.0,
        n_requests=16 if quick else 48,
        seed=1,
        prompt_mix=((6, 0.6), (12, 0.4)),
        output_mix=((12, 0.5), (20, 0.5)),
        priority_mix=(("interactive", 0.25), ("batch", 0.75)),
    )
    for mode in ("fifo", "priority+preempt"):
        rows.append(asyncio.run(_run_sched_variant(
            mode, sched_spec, n_slots=1, max_len=64)))
    fifo, prio = rows[-2], rows[-1]
    # the tentpole gate, measured over the real wire: the priority
    # scheduler must strictly cut the interactive tail
    assert (prio["interactive_ttft_p99_ms"] is not None
            and fifo["interactive_ttft_p99_ms"] is not None), (fifo, prio)
    assert (prio["interactive_ttft_p99_ms"]
            < fifo["interactive_ttft_p99_ms"]), (
        f"priority+preempt did not beat FIFO on interactive p99 TTFT: "
        f"{prio['interactive_ttft_p99_ms']}ms vs "
        f"{fifo['interactive_ttft_p99_ms']}ms")
    save_rows("table7_load_serving", rows)
    print(fmt_table(rows[:2], [
        "variant", "qps", "requests", "completed", "rejected",
        "first_ttft_ms", "ttft_p50_ms", "ttft_p99_ms",
        "itl_p50_ms", "itl_p99_ms", "sustained_tok_s",
        "server_ttft_p99_ms", "server_tick_p50_ms",
        "peak_queue_depth", "page_utilization", "preempt_free_tick_rate",
    ]))
    print(fmt_table(rows[2:], [
        "variant", "qps", "requests", "completed",
        "interactive_ttft_p50_ms", "interactive_ttft_p99_ms",
        "batch_ttft_p50_ms", "batch_ttft_p99_ms",
        "preempts", "resumes", "preempted_tokens",
        "peak_queue_depth", "preempt_free_tick_rate",
    ]))
    return rows


if __name__ == "__main__":
    run(quick=True)

"""Figure 6 — effect of layer size: ConvMixer vs MLPMixer across
compression rates. Two halves:

  1. exact bits/param + parameter counts at PAPER scale per p in
     {4, 8, 16, 32} (ConvMixer's biggest layer is 65k -> lambda leaves
     most of it untiled; MLPMixer's 131k layers keep compressing), and
  2. reduced-scale synthetic accuracy per p (the degradation ORDERING:
     ConvMixer falls off faster past p=4 because its layers are small).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (fmt_table, ledger_for, save_rows,
                               train_classifier)
from repro.core.policy import fp32_policy, tbn_policy
from repro.models.paper import build_paper_model
from repro.nn import module as mod
from repro.nn.context import ModelContext


def reduced_accuracy(name, policy, steps):
    from repro.data.synthetic import image_like

    ctx = ModelContext(policy=policy, compute_dtype=jnp.float32)
    if name == "convmixer":
        model = build_paper_model(name, ctx, dim=32, depth=4, kernel=4,
                                  patch=2, img=16, classes=8)
    else:
        model = build_paper_model(name, ctx, dim=64, depth=3, patch=4,
                                  img=16, classes=8, token_hidden=32,
                                  chan_hidden=32)
    params = mod.init_params(model.specs(), jax.random.PRNGKey(0))

    def data(step):
        x, y = image_like(0, step, 32, 16, 8)
        return {"x": x, "y": y}

    return train_classifier(model, params, data, steps=steps)


def run(quick: bool = False):
    rows = []
    for name in ("convmixer", "mlpmixer"):
        for p in (4, 8, 16, 32):
            pol = tbn_policy(p=p, min_size=64_000, alpha_source="A")
            rep = ledger_for(name, pol)
            rows.append(dict(model=name, p=p,
                             bits=round(rep.bits_per_param(), 3),
                             mbit=round(rep.mbit(), 3),
                             savings=f"{rep.savings_vs_binary():.1f}x"))
    steps = 40 if quick else 120
    for name in ("convmixer", "mlpmixer"):
        base = reduced_accuracy(name, fp32_policy(), steps)
        accs = {"fp32": round(base, 3)}
        for p in (4, 16):
            accs[f"tbn{p}"] = round(
                reduced_accuracy(
                    name, tbn_policy(p=p, min_size=256, alpha_source="A"),
                    steps),
                3)
        rows.append(dict(model=f"{name}-reduced-acc", **accs))
    save_rows("fig6_layer_size", rows)
    print(fmt_table(rows, ["model", "p", "bits", "mbit", "savings",
                           "fp32", "tbn4", "tbn16"]))
    return rows


if __name__ == "__main__":
    run()

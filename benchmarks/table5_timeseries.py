"""Table 5 — Multivariate time-series forecasting MSE.

This one trains for real at (near) paper scale — the models are small
enough for CPU. Sine-mixture synthetic series stand in for ECL/Weather;
the claim under test is the ORDERING: TBN_4 ~ BWNN ~ FP32 on single-step
forecasting (paper: 0.209 vs 0.210 vs 0.212 on ECL)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, ledger_for, save_rows
from repro.core.policy import bwnn_policy, fp32_policy, tbn_policy
from repro.models.paper import build_paper_model
from repro.nn import module as mod
from repro.nn.context import ModelContext

PAPER = {
    ("electricity", "fp32"): (32, 145.2, 0.212),
    ("electricity", "bwnn"): (1.0, 4.5, 0.210),
    ("electricity", "tbn4"): (0.25, 1.1, 0.209),
    ("weather", "fp32"): (32, 11.8, 0.165),
    ("weather", "bwnn"): (1.0, 0.368, 0.165),
    ("weather", "tbn4"): (0.54, 0.197, 0.168),
}

DATASETS = {
    # (features, dim, d_ff, lambda) — ECL-like and Weather-like profiles
    "electricity": dict(features=321, dim=512, d_ff=512, lam=64_000),
    "weather": dict(features=7, dim=128, d_ff=128, lam=32_000),
}


def train_mse(policy, ds, *, steps, runs=2, reduced=True):
    """Short forecasting runs; returns mean eval MSE across seeds."""
    from repro.data.synthetic import sine_mixture
    from repro.optim import adamw, constant
    from repro.train.step import build_train_step, init_state

    feats = 7 if ds == "weather" else (32 if reduced else 321)
    dim = DATASETS[ds]["dim"] if not reduced else max(
        32, DATASETS[ds]["dim"] // 4)
    L = 48
    mses = []
    for seed in range(runs):
        ctx = ModelContext(policy=policy, compute_dtype=jnp.float32)
        model = build_paper_model(
            "ts-transformer", ctx, features=feats, dim=dim, depth=2,
            heads=4, d_ff=dim)
        params = mod.init_params(model.specs(), jax.random.PRNGKey(seed))
        opt = adamw(constant(1e-3))

        def loss_fn(p, batch):
            pred = model(p, batch["x"])            # (B, 1, F)
            return jnp.mean((pred[:, 0] - batch["y"]) ** 2), {}

        step = jax.jit(build_train_step(loss_fn, opt))
        state = init_state(params, opt)

        def batch_at(i):
            series = sine_mixture(seed, i, 32, L + 1, feats)
            return {"x": series[:, :L], "y": series[:, L]}

        for i in range(steps):
            state, _ = step(state, batch_at(i))
        errs = []
        for i in range(8):
            b = batch_at(50_000 + i)
            pred = model(state.params, b["x"])[:, 0]
            errs.append(float(jnp.mean((pred - b["y"]) ** 2)))
        mses.append(np.mean(errs))
    return float(np.mean(mses)), float(np.std(mses))


def run(quick: bool = False):
    rows = []
    # exact bits accounting at PAPER scale
    for ds, cfgd in DATASETS.items():
        for mode, pol in [
            ("bwnn", bwnn_policy()),
            ("tbn4", tbn_policy(p=4, min_size=cfgd["lam"], alpha_source="A")),
        ]:
            rep = ledger_for("ts-transformer", pol, features=cfgd["features"],
                             dim=cfgd["dim"], d_ff=cfgd["d_ff"])
            ref = PAPER[(ds, mode)]
            rows.append(dict(dataset=ds, method=mode,
                             bits=round(rep.bits_per_param(), 3),
                             mbit=round(rep.mbit(), 3),
                             paper_bits=ref[0], paper_mbit=ref[1]))
    # real (reduced) training: the MSE ordering claim
    steps = 60 if quick else 250
    for ds in DATASETS:
        accs = {}
        for mode, pol in [("fp32", fp32_policy()), ("bwnn", bwnn_policy()),
                          ("tbn4", tbn_policy(p=4, min_size=2048,
                                              alpha_source="A"))]:
            mse, std = train_mse(pol, ds, steps=steps,
                                 runs=1 if quick else 2)
            accs[mode] = mse
            rows.append(dict(dataset=f"{ds}-synth", method=mode,
                             mse=round(mse, 4), mse_std=round(std, 4),
                             paper_mse=PAPER[(ds, mode)][2]))
    save_rows("table5_timeseries", rows)
    print(fmt_table(rows, ["dataset", "method", "bits", "mbit", "mse",
                           "paper_bits", "paper_mbit", "paper_mse"]))
    return rows


if __name__ == "__main__":
    run()

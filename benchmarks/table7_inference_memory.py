"""Table 7 — inference memory on an ImageNet-scale ViT (dim 768, depth 6,
mlp 4096 — the paper's '6 attention layers x ~8.4M params' profile).

Weight-residency is exact from the ledger (the tile-reuse kernel keeps ONE
tile per layer live); activation residency is the max per-layer live set
for a single image. Four variants as in the paper: FP32, FP32+tiling
(full-precision tiles — the paper's Triton experiment), BWNN (1-bit), and
TBN (packed sub-bit tiles).

A MEASURED CNN section exercises the conv serving path itself: with
``tiled_conv_infer`` the dense OIHW weights never exist at inference, so
the shipped-bytes and latency numbers below are observed on the real
packed representation, not derived from the ledger. (The observed packed
bytes can sit slightly above q/8 per layer: the conv layout pads each
(kernel position, filter) row of channels to whole int32 words.)

A MEASURED SHARDED-SERVING section scales the claim over a tensor-parallel
mesh: the packed tile rows of a reduced LM shard over the model axis
(DESIGN.md §5) and we report per-device resident tile bytes, decode tick
latency, and the max |logit| deviation vs the single-device path. It runs
in a subprocess because the 8 forced host devices must be configured
before jax initializes (the same trick the multi-device tests use).

A MEASURED CHUNKED-PREFILL section runs the mixed workload the serving
scheduler exists for: one slot decoding while a long prompt streams in
through fixed-width extend chunks. It reports the long request's TTFT and
the decoding slot's inter-token latency (solo vs during-prefill, mean and
max) per chunk size, with the whole-prompt single chunk as the monolithic
baseline — decode ITL must stay flat in tick terms (1 token/tick) and the
max wall-clock ITL must shrink with the chunk.

A MEASURED PREFIX-CACHING section serves 8 concurrent requests sharing a
128-token prefix through the paged KV pool, with the radix-trie prefix
cache off / cold / warm: warm admissions map the shared pages in O(1)
and prefill only each request's distinct tail, so TTFT drops by roughly
the prefix/tail ratio while per-slot cache bytes stay <= the dense
layout at equal max_len (the pool defaults to dense-equivalent size).

A MEASURED DECODE-BLOCKING section times the decode hot path's matmul at
serving batch sizes: the old route padded an (n_slots, 1) decode batch to
the matmul kernel's 128-row m block (~97% zero rows at 4 slots); the
small-m dispatch in ``ops.tiled_dense_infer`` now routes those batches to
``tiled_matvec_unique`` (whole sublane-rounded batch as one m block,
widened r/k blocking). Both paths run the same backend (Pallas on TPU,
interpret elsewhere), so the reported delta is the blocking's, not the
platform's."""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import jax.numpy as jnp

from benchmarks.common import fmt_table, measure_serve_delta, save_rows
from repro.core.policy import tbn_policy
from repro.models.paper import build_paper_model
from repro.nn.context import ModelContext

ROOT = pathlib.Path(__file__).resolve().parents[1]

_SHARDED_PROG = """
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))
import json, time
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_auto_mesh
from repro.configs import build_model, get_config
from repro.distributed.sharding import axis_rules, param_shardings
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.weights import (
    export_serving_params, per_device_tile_bytes, tile_serving_bytes)
import contextlib

TPS = %(tps)s
TICKS = %(ticks)d
cfg = get_config("granite-8b").reduced()
tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                   compute_dtype=jnp.float32))
sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                   compute_dtype=jnp.float32,
                                   use_pallas=False))
tp0 = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
sp = export_serving_params(tm.specs(), sm.specs(), tp0, cfg.tbn)
batch = {"tokens": jnp.array([[5, 3, 2, 7, 1, 4, 6, 2]], jnp.int32)}
logical = mod.logical_axes(sm.specs())
abstract = jax.tree.map(lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), sp)
total_tile = tile_serving_bytes(sp)

rows, ref_logits = [], None
for tp in TPS:
    if tp == 1:
        mesh, params = None, sp
    else:
        mesh = make_auto_mesh((tp,), ("model",))
        params = jax.device_put(
            sp, param_shardings(mesh, logical, abstract_tree=abstract))
    ctx = axis_rules(mesh) if mesh is not None else contextlib.nullcontext()
    with ctx:
        prefill = jax.jit(lambda p, b: sm.prefill(p, b, 16))
        decode = jax.jit(sm.decode_step)
        logits, caches, lengths = prefill(params, batch)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        lg, caches, lengths = decode(params, tok, caches, lengths)  # compile
        lg.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(TICKS):
            lg, caches, lengths = decode(params, tok, caches, lengths)
        lg.block_until_ready()
        tick_ms = 1e3 * (time.perf_counter() - t0) / TICKS
    if ref_logits is None:
        ref_logits = np.asarray(logits, np.float32)
        diff = 0.0
    else:
        diff = float(np.max(np.abs(ref_logits - np.asarray(logits, np.float32))))
    per_dev = per_device_tile_bytes(params)
    worst = max(per_dev.values())
    rows.append(dict(
        tp=tp,
        tile_kb_total=round(total_tile / 1e3, 2),
        tile_kb_per_device=round(worst / 1e3, 2),
        sharding=f"{total_tile / worst:.1f}x",
        tick_ms=round(tick_ms, 1),
        max_logit_diff=f"{diff:.2e}",
    ))
print("SHARDED_JSON=" + json.dumps(rows))
"""


def measure_sharded_serving(quick: bool):
    """Per-device tile bytes + decode tick latency over a model-axis mesh.

    Returns the benchmark rows, or None when the subprocess fails (the
    main table still prints — the sharded section is additive)."""
    tps = [1, 4] if quick else [1, 2, 4]
    prog = _SHARDED_PROG % dict(tps=tps, ticks=4 if quick else 16)
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    try:
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            cwd=str(ROOT), timeout=900, env=env,
        )
    except subprocess.TimeoutExpired:
        print("sharded serving section skipped: subprocess timed out")
        return None
    for line in out.stdout.splitlines():
        if line.startswith("SHARDED_JSON="):
            return json.loads(line[len("SHARDED_JSON="):])
    print(f"sharded serving section skipped: rc={out.returncode}\n"
          f"{out.stderr[-2000:]}")
    return None

def measure_decode_blocking(quick: bool):
    """Old 128-row matmul blocking vs the small-m matvec dispatch at
    decode batch sizes (n_slots tokens per tick, one token per slot)."""
    import time

    import jax
    import numpy as np

    from repro.core.packing import pack_bits
    from repro.kernels.tiled_matmul import tiled_matmul_unique
    from repro.kernels.tiled_matvec import (
        DECODE_BLOCK_K, DECODE_BLOCK_R, sublane_rounded, tiled_matvec_unique)

    k_dim, r = (1024, 256) if quick else (2048, 512)
    reps = 3 if quick else 10
    key = jax.random.PRNGKey(0)
    packed = pack_bits(
        jnp.where(jax.random.bernoulli(key, 0.5, (r, k_dim)), 1.0, -1.0))

    def timed(fn, x):
        fn(x).block_until_ready()            # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(x)
        out.block_until_ready()
        return 1e3 * (time.perf_counter() - t0) / reps

    rows = []
    for m in (4, 16):
        x = jax.random.normal(jax.random.PRNGKey(m), (m, k_dim), jnp.float32)

        @jax.jit
        def old_path(x, m=m):
            xp = jnp.pad(x, ((0, 128 - m), (0, 0)))
            return tiled_matmul_unique(xp, packed, r=r)[:m]

        @jax.jit
        def new_path(x, m=m):
            xp = jnp.pad(x, ((0, sublane_rounded(m, x.dtype) - m), (0, 0)))
            return tiled_matvec_unique(
                xp, packed, r=r,
                block_r=min(DECODE_BLOCK_R, r),
                block_k=min(DECODE_BLOCK_K, k_dim),
            )[:m]

        np.testing.assert_allclose(                 # same math before timing
            np.asarray(old_path(x)), np.asarray(new_path(x)),
            rtol=1e-5, atol=1e-3)
        old_ms, new_ms = timed(old_path, x), timed(new_path, x)
        rows.append(dict(
            n_slots=m, k=k_dim, r=r,
            old_ms=round(old_ms, 3), new_ms=round(new_ms, 3),
            old_tok_s=round(1e3 * m / old_ms, 1),
            new_tok_s=round(1e3 * m / new_ms, 1),
            speedup=f"{old_ms / new_ms:.2f}x",
        ))
    return rows


def measure_chunked_prefill(quick: bool):
    """Mixed-workload tail latency: a slot decoding WHILE a long prompt
    prefills, across prefill chunk sizes.

    The last row admits the whole prompt as ONE chunk in ONE tick — the
    chunk width exceeds prompt + decode load, so the decode-priority
    budget cannot split it — i.e. the old admission-time monolithic
    behavior, and its max inter-token latency shows the head-of-line
    spike the chunked scheduler removes. In tick terms every row's
    decoder emits exactly 1 token/tick (the fairness invariant); the
    wall-clock ITL columns show how much prompt work each chunk size
    lets a single tick carry."""
    import time

    import jax
    import numpy as np

    from repro.configs import build_model, get_config
    from repro.nn import module as mod
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.serve.engine import BatchedEngine, ServeConfig
    from repro.serve.sampling import SamplingParams
    from repro.serve.weights import export_serving_params

    cfg = get_config("granite-8b").reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp0 = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    sp = export_serving_params(tm.specs(), sm.specs(), tp0, cfg.tbn)

    plen = 48 if quick else 96
    warm = 4 if quick else 8
    long_prompt = [int(x) for x in np.arange(plen) % cfg.vocab]
    rows = []
    # chunk = plen + n_slots: budget covers the whole prompt even after
    # every decoding slot is charged its token, so the prompt truly lands
    # in one tick (a bare chunk = plen would split it (plen-1) + 1)
    mono = plen + 2
    for chunk in (8, 16, mono):
        eng = BatchedEngine(sm, sp, ServeConfig(
            n_slots=2, max_len=plen + 32, chunk_tokens=chunk))
        dec = eng.submit([1, 2, 3], SamplingParams(max_tokens=plen + 64))
        for _ in range(1 + warm):          # admit+prefill, then warm decode
            eng.step()
        # baseline: decode-only tick latency
        t0 = time.perf_counter()
        for _ in range(warm):
            eng.step()
        itl_solo = 1e3 * (time.perf_counter() - t0) / warm

        lreq = eng.submit(long_prompt, SamplingParams(max_tokens=4))
        submit_step, ticks = eng.steps, []
        while not lreq.output:
            before = len(dec.output)
            t0 = time.perf_counter()
            eng.step()
            ticks.append(1e3 * (time.perf_counter() - t0))
            assert len(dec.output) == before + 1   # fairness, in tick terms
        rows.append(dict(
            chunk=chunk if chunk != mono else f"{chunk} (monolithic)",
            prompt=plen,
            prefill_ticks=eng.steps - submit_step,
            ttft_ms=round(sum(ticks), 1),
            itl_solo_ms=round(itl_solo, 1),
            itl_mixed_ms=round(float(np.mean(ticks)), 1),
            itl_mixed_max_ms=round(float(np.max(ticks)), 1),
            decode_tok_per_tick=1.0,
        ))
    return rows


def measure_prefix_caching(quick: bool):
    """Shared-prefix serving: TTFT with/without the radix-trie prefix
    cache for 8 concurrent requests sharing a 128-token prefix (a system
    prompt), plus paged-pool vs dense cache bytes.

    Three admission regimes on the same engine shape: ``no-cache``
    (prefix cache off — every admission prefills the full prompt),
    ``cache-cold`` (cache on, empty trie — the 8 concurrent requests all
    miss, since none has retired/published yet), and ``cache-warm`` (the
    trie holds the shared prefix from the previous batch — every
    admission maps its 128 prefix tokens in O(1) and prefills only the
    distinct tail). The pool is the default dense-equivalent size, so
    per-slot cache bytes never exceed the dense layout at equal max_len;
    the in-use column shows what the pool actually holds once shared
    pages are counted once."""
    import time

    import jax
    import numpy as np

    from repro.configs import build_model, get_config
    from repro.nn import module as mod
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.serve.engine import BatchedEngine, ServeConfig
    from repro.serve.sampling import SamplingParams
    from repro.serve.weights import export_serving_params

    cfg = get_config("granite-8b").reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp0 = mod.init_params(tm.specs(), jax.random.PRNGKey(0))
    sp = export_serving_params(tm.specs(), sm.specs(), tp0, cfg.tbn)

    plen, n_req, max_len = 128, 8, 160
    gen_toks = 2 if quick else 4
    shared = [int(x) % cfg.vocab for x in np.arange(plen)]

    def tails(salt):
        rng = np.random.default_rng(salt)
        return [[int(t) for t in rng.integers(0, cfg.vocab, size=6)]
                for _ in range(n_req)]

    def make_engine(prefix_cache):
        return BatchedEngine(sm, sp, ServeConfig(
            n_slots=n_req, max_len=max_len, chunk_tokens=32,
            page_tokens=16, prefix_cache=prefix_cache))

    def run_batch(eng, salt):
        reqs = [eng.submit(shared + tail, SamplingParams(max_tokens=gen_toks))
                for tail in tails(salt)]
        base = eng.steps
        tick_ends, t0 = [], time.perf_counter()
        eng.run_until_drained(
            on_tick=lambda _: tick_ends.append(time.perf_counter() - t0))
        ttfts = [1e3 * tick_ends[r.token_steps[0] - base] for r in reqs]
        return ttfts, reqs

    def cache_bytes(eng):
        return sum(v.nbytes for v in jax.tree_util.tree_leaves(eng.caches))

    # compile + allocator warmup on a throwaway engine, at the SAME
    # 8-concurrent load as the timed batches: the tick functions are
    # cached on the model, so the timed engines below all run
    # pre-compiled. Post-compile drains still jitter run-to-run on CPU,
    # so every variant averages over ``reps`` full batches.
    reps = 2 if quick else 4
    warm_eng = make_engine(False)
    run_batch(warm_eng, salt=0)

    rows = []
    # no-cache baseline: every admission prefills all plen+6 tokens
    eng = make_engine(False)
    ttfts = [t for i in range(reps) for t in run_batch(eng, salt=1 + i)[0]]
    rows.append(dict(
        variant="no-cache",
        ttft_mean_ms=round(float(np.mean(ttfts)), 1),
        ttft_max_ms=round(float(np.max(ttfts)), 1),
        prefill_skipped_tok=0,
        cache_mb_per_slot=round(cache_bytes(eng) / n_req / 1e6, 3),
        pool_pages="-",
    ))
    dense_per_slot = rows[0]["cache_mb_per_slot"]

    # cache-cold: first batch on a FRESH trie each rep (each batch's 8
    # concurrent admissions all miss — nothing retired/published yet)
    ttfts = []
    for i in range(reps):
        eng = make_engine(True)
        ttfts += run_batch(eng, salt=1 + i)[0]
    st = eng.stats()
    rows.append(dict(
        variant="cache-cold",
        ttft_mean_ms=round(float(np.mean(ttfts)), 1),
        ttft_max_ms=round(float(np.max(ttfts)), 1),
        prefill_skipped_tok=0,
        cache_mb_per_slot=round(cache_bytes(eng) / n_req / 1e6, 3),
        pool_pages=f"{st['pages_in_use']}/{st['pool_pages']}",
    ))

    # cache-warm: one engine, an untimed seeding batch, then timed
    # batches with distinct tails — every admission maps the shared 128
    # prefix tokens from the trie
    eng = make_engine(True)
    run_batch(eng, salt=100)                       # seeds the trie
    before = eng.stats()["prefill_tokens_skipped"]
    ttfts = [t for i in range(reps)
             for t in run_batch(eng, salt=101 + i)[0]]
    st = eng.stats()
    rows.append(dict(
        variant="cache-warm",
        ttft_mean_ms=round(float(np.mean(ttfts)), 1),
        ttft_max_ms=round(float(np.max(ttfts)), 1),
        prefill_skipped_tok=(st["prefill_tokens_skipped"] - before) // reps,
        cache_mb_per_slot=round(cache_bytes(eng) / n_req / 1e6, 3),
        pool_pages=f"{st['pages_in_use']}/{st['pool_pages']}",
    ))
    assert all(r["cache_mb_per_slot"] <= dense_per_slot for r in rows)
    warm, base = rows[2]["ttft_mean_ms"], rows[0]["ttft_mean_ms"]
    for r in rows:
        r["ttft_vs_nocache"] = f"{base / max(r['ttft_mean_ms'], 1e-9):.2f}x"
    print(f"\nwarm shared-prefix TTFT {base / max(warm, 1e-9):.2f}x faster "
          f"than no-cache ({plen}-token shared prefix, {n_req} concurrent)")
    return rows


PAPER = dict(fp=(222.5, 208.0), fp_tiled=(78.5, 52.0),
             bwnn=(18.4, 6.5), tbn=(13.4, 1.6))


def weight_bytes(rep, variant: str, p: int = 4) -> float:
    total = 0.0
    for r in rep.layers:
        if r.kind not in ("dense", "conv", "head"):
            continue
        if variant == "fp":
            total += 4 * r.n
        elif variant == "fp_tiled":
            total += 4 * (r.n // r.spec.p if r.spec else r.n)
        elif variant == "bwnn":
            total += r.n / 8
        elif variant == "tbn":
            total += r.stored_bits() / 8
    return total


def act_bytes(dim=768, tokens=197, mlp=4096, heads=12) -> float:
    """Max live activations for one image: in + out + qkv or mlp hidden."""
    token_buf = tokens * dim * 4
    qkv = tokens * 3 * dim * 4
    scores = heads * tokens * tokens * 4
    mlp_h = tokens * mlp * 4
    attn_peak = 2 * token_buf + qkv + scores
    mlp_peak = 2 * token_buf + mlp_h
    return max(attn_peak, mlp_peak)


def run(quick: bool = False):
    pol = tbn_policy(p=4, min_size=150_000, alpha_source="W")
    ctx = ModelContext(policy=pol, compute_dtype=jnp.float32)
    build_paper_model("vit", ctx, dim=768, depth=6, heads=12,
                      mlp_dim=4096, patch=16, img=224, classes=1000)
    rep = ctx.ledger.report()
    acts = act_bytes()
    rows = []
    for variant, pretty in [("fp", "Full Precision"),
                            ("fp_tiled", "FP, Tiled4"),
                            ("bwnn", "BWNN"), ("tbn", "TBN4")]:
        wb = weight_bytes(rep, variant)
        peak = wb + acts
        ref = PAPER[variant]
        rows.append(dict(
            variant=pretty,
            peak_mb=round(peak / 1e6, 1),
            param_mb=round(wb / 1e6, 1),
            pct_param=f"{100 * wb / peak:.1f}%",
            paper_peak=ref[0], paper_param=ref[1],
        ))
    fp_peak = rows[0]["peak_mb"]
    for r in rows:
        r["peak_saving"] = f"{fp_peak / r['peak_mb']:.1f}x"
    save_rows("table7_inference_memory", rows)
    print(fmt_table(rows, ["variant", "peak_mb", "param_mb", "pct_param",
                           "peak_saving", "paper_peak", "paper_param"]))

    # measured conv serving path: dense weights vs packed conv tiles
    cnn_pol = tbn_policy(p=4, min_size=64_000, alpha_source="W")
    m = measure_serve_delta("resnet18", cnn_pol, repeats=1 if quick else 3)
    mrows = [dict(variant=k, weight_mb=round(v["bytes"] / 1e6, 3),
                  latency_ms=round(v["latency_ms"], 1))
             for k, v in m.items() if k != "delta"]
    mrows.append(dict(variant="delta",
                      weight_mb=f'{m["delta"]["bytes_saving"]:.1f}x smaller',
                      latency_ms=f'{m["delta"]["latency_speedup"]:.2f}x'))
    save_rows("table7_cnn_measured", mrows)
    print("\nmeasured resnet18 serving (dense fp32 vs packed conv tiles):")
    print(fmt_table(mrows, ["variant", "weight_mb", "latency_ms"]))

    # measured decode blocking: the old 128-row-padded matmul vs the
    # small-m matvec dispatch the decode tick now takes
    drows = measure_decode_blocking(quick)
    save_rows("table7_decode_matvec", drows)
    print("\nmeasured decode-tick matmul (old 128-row blocking vs small-m "
          "matvec dispatch, per jitted call):")
    print(fmt_table(drows, ["n_slots", "k", "r", "old_ms", "new_ms",
                            "old_tok_s", "new_tok_s", "speedup"]))

    # measured chunked-prefill scheduling: decode tail latency while a
    # long prompt streams in, vs the monolithic single-chunk admission
    crows = measure_chunked_prefill(quick)
    save_rows("table7_chunked_prefill", crows)
    print("\nmeasured chunked-prefill mixed workload (decoding slot beside "
          "a long-prompt admission; ITL = decode inter-token latency):")
    print(fmt_table(crows, ["chunk", "prompt", "prefill_ticks", "ttft_ms",
                            "itl_solo_ms", "itl_mixed_ms",
                            "itl_mixed_max_ms", "decode_tok_per_tick"]))

    # measured prefix caching: shared-prefix TTFT with/without the
    # radix-trie cache + paged-pool vs dense cache bytes
    prows = measure_prefix_caching(quick)
    save_rows("table7_prefix_caching", prows)
    print("\nmeasured prefix caching (8 concurrent requests sharing a "
          "128-token prefix; paged KV pool at dense-equivalent size):")
    print(fmt_table(prows, ["variant", "ttft_mean_ms", "ttft_max_ms",
                            "ttft_vs_nocache", "prefill_skipped_tok",
                            "cache_mb_per_slot", "pool_pages"]))

    # measured tensor-parallel serving: tile rows sharded over the model
    # axis — per-device bytes must scale as 1/TP with unchanged logits
    srows = measure_sharded_serving(quick)
    if srows:
        save_rows("table7_sharded_serving", srows)
        print("\nmeasured sharded serving (reduced LM, tile rows over the "
              "model axis, 8 forced host devices):")
        print(fmt_table(srows, ["tp", "tile_kb_total", "tile_kb_per_device",
                                "sharding", "tick_ms", "max_logit_diff"]))
    return rows


if __name__ == "__main__":
    run()

"""Table 7 — inference memory on an ImageNet-scale ViT (dim 768, depth 6,
mlp 4096 — the paper's '6 attention layers x ~8.4M params' profile).

Weight-residency is exact from the ledger (the tile-reuse kernel keeps ONE
tile per layer live); activation residency is the max per-layer live set
for a single image. Four variants as in the paper: FP32, FP32+tiling
(full-precision tiles — the paper's Triton experiment), BWNN (1-bit), and
TBN (packed sub-bit tiles).

A MEASURED CNN section exercises the conv serving path itself: with
``tiled_conv_infer`` the dense OIHW weights never exist at inference, so
the shipped-bytes and latency numbers below are observed on the real
packed representation, not derived from the ledger. (The observed packed
bytes can sit slightly above q/8 per layer: the conv layout pads each
(kernel position, filter) row of channels to whole int32 words.)"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import fmt_table, measure_serve_delta, save_rows
from repro.core.policy import bwnn_policy, fp32_policy, tbn_policy
from repro.models.paper import build_paper_model
from repro.nn.context import ModelContext

PAPER = dict(fp=(222.5, 208.0), fp_tiled=(78.5, 52.0),
             bwnn=(18.4, 6.5), tbn=(13.4, 1.6))


def weight_bytes(rep, variant: str, p: int = 4) -> float:
    total = 0.0
    for r in rep.layers:
        if r.kind not in ("dense", "conv", "head"):
            continue
        if variant == "fp":
            total += 4 * r.n
        elif variant == "fp_tiled":
            total += 4 * (r.n // r.spec.p if r.spec else r.n)
        elif variant == "bwnn":
            total += r.n / 8
        elif variant == "tbn":
            total += r.stored_bits() / 8
    return total


def act_bytes(dim=768, tokens=197, mlp=4096, heads=12) -> float:
    """Max live activations for one image: in + out + qkv or mlp hidden."""
    token_buf = tokens * dim * 4
    qkv = tokens * 3 * dim * 4
    scores = heads * tokens * tokens * 4
    mlp_h = tokens * mlp * 4
    attn_peak = 2 * token_buf + qkv + scores
    mlp_peak = 2 * token_buf + mlp_h
    return max(attn_peak, mlp_peak)


def run(quick: bool = False):
    pol = tbn_policy(p=4, min_size=150_000, alpha_source="W")
    ctx = ModelContext(policy=pol, compute_dtype=jnp.float32)
    build_paper_model("vit", ctx, dim=768, depth=6, heads=12,
                      mlp_dim=4096, patch=16, img=224, classes=1000)
    rep = ctx.ledger.report()
    acts = act_bytes()
    rows = []
    for variant, pretty in [("fp", "Full Precision"),
                            ("fp_tiled", "FP, Tiled4"),
                            ("bwnn", "BWNN"), ("tbn", "TBN4")]:
        wb = weight_bytes(rep, variant)
        peak = wb + acts
        ref = PAPER[variant]
        rows.append(dict(
            variant=pretty,
            peak_mb=round(peak / 1e6, 1),
            param_mb=round(wb / 1e6, 1),
            pct_param=f"{100 * wb / peak:.1f}%",
            paper_peak=ref[0], paper_param=ref[1],
        ))
    fp_peak = rows[0]["peak_mb"]
    for r in rows:
        r["peak_saving"] = f"{fp_peak / r['peak_mb']:.1f}x"
    save_rows("table7_inference_memory", rows)
    print(fmt_table(rows, ["variant", "peak_mb", "param_mb", "pct_param",
                           "peak_saving", "paper_peak", "paper_param"]))

    # measured conv serving path: dense weights vs packed conv tiles
    cnn_pol = tbn_policy(p=4, min_size=64_000, alpha_source="W")
    m = measure_serve_delta("resnet18", cnn_pol, repeats=1 if quick else 3)
    mrows = [dict(variant=k, weight_mb=round(v["bytes"] / 1e6, 3),
                  latency_ms=round(v["latency_ms"], 1))
             for k, v in m.items() if k != "delta"]
    mrows.append(dict(variant="delta",
                      weight_mb=f'{m["delta"]["bytes_saving"]:.1f}x smaller',
                      latency_ms=f'{m["delta"]["latency_speedup"]:.2f}x'))
    save_rows("table7_cnn_measured", mrows)
    print("\nmeasured resnet18 serving (dense fp32 vs packed conv tiles):")
    print(fmt_table(mrows, ["variant", "weight_mb", "latency_ms"]))
    return rows


if __name__ == "__main__":
    run()

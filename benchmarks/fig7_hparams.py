"""Figures 7/8 — hyperparameter ablation on a reduced MLPMixer:

  1. global tiling (lambda=0) vs minimum-layer-size lambda,
  2. alpha from W vs from the separate tensor A,
  3. single alpha per layer vs one per tile.

The paper's finding: lambda matters a lot (global tiling clearly worst);
W+A and multi-alpha give small gains."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, save_rows, train_classifier
from repro.core.policy import tbn_policy
from repro.models.paper import build_paper_model
from repro.nn import module as mod
from repro.nn.context import ModelContext


def accuracy(policy, steps):
    from repro.data.synthetic import image_like

    ctx = ModelContext(policy=policy, compute_dtype=jnp.float32)
    model = build_paper_model("mlpmixer", ctx, dim=64, depth=3, patch=4,
                              img=16, classes=8, token_hidden=64,
                              chan_hidden=64)
    params = mod.init_params(model.specs(), jax.random.PRNGKey(0))

    def data(step):
        x, y = image_like(0, step, 32, 16, 8)
        return {"x": x, "y": y}

    return train_classifier(model, params, data, steps=steps)


CONFIGS = {
    # name -> (min_size, alpha_source, alpha_mode)
    "lambda+A+multi": (1024, "A", "tile"),      # paper default/best
    "lambda+W+multi": (1024, "W", "tile"),
    "lambda+A+single": (1024, "A", "layer"),
    "global+A+multi": (0, "A", "tile"),         # global tiling (worst)
}


def run(quick: bool = False):
    steps = 40 if quick else 150
    rows = []
    for name, (lam, src, mode) in CONFIGS.items():
        pol = tbn_policy(p=4, min_size=lam, alpha_source=src,
                         alpha_mode=mode)
        acc = accuracy(pol, steps)
        rows.append(dict(config=name, min_size=lam, alpha_source=src,
                         alpha_mode=mode, accuracy=round(acc, 3)))
    save_rows("fig7_hparams", rows)
    print(fmt_table(rows, ["config", "min_size", "alpha_source",
                           "alpha_mode", "accuracy"]))
    return rows


if __name__ == "__main__":
    run()

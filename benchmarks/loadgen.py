"""Seeded traffic generator + replay harness for the serving front-end.

Produces a DETERMINISTIC request schedule from a single integer seed:
Poisson arrivals (exponential inter-arrival gaps at the target QPS),
prompt/output lengths drawn from weighted discrete mixes, and an
optional shared-prefix population (a fraction of requests re-use one of
``n_prefix_groups`` common prefixes — the traffic shape the radix-trie
prefix cache exists for), and an optional PRIORITY-CLASS mix
(``priority_mix``: each request draws a scheduling class from weighted
names — the interactive-under-batch-flood traffic the pressure
scheduler exists for). Same ``LoadSpec`` -> byte-identical schedule,
every time, on every host: the schedule is pure ``numpy.random.default_rng``
state, no wall clock anywhere (tests/test_loadgen.py pins this).

``replay`` then plays a schedule against a live ``EngineServer`` over
the real HTTP/SSE wire (repro.serve.client), honouring each request's
arrival offset, and returns per-request latency records — TTFT measured
submit->first-token-event and ITLs as gaps between token events — which
``summarize`` folds into the p50/p99 table the load benchmark reports.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

# (value, weight) pairs; weights need not sum to 1 (normalised at draw)
Mix = Tuple[Tuple[int, float], ...]
# (priority class, weight) pairs, same normalisation
ClassMix = Tuple[Tuple[str, float], ...]


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """Everything that determines a traffic trace, and nothing else."""
    qps: float = 16.0
    n_requests: int = 32
    seed: int = 0
    vocab: int = 256
    prompt_mix: Mix = ((6, 0.5), (12, 0.35), (20, 0.15))
    output_mix: Mix = ((4, 0.5), (8, 0.3), (12, 0.2))
    shared_prefix_ratio: float = 0.0   # fraction drawing a shared prefix
    shared_prefix_len: int = 0
    n_prefix_groups: int = 1
    temperature: float = 0.0
    top_k: Optional[int] = None
    priority_mix: Optional[ClassMix] = None  # per-request scheduling class
    # drawn from these weights (e.g. (("interactive", 0.2), ("batch",
    # 0.8))); None sends no "priority" field at all — the engine default

    def __post_init__(self):
        if self.qps <= 0:
            raise ValueError(f"qps must be > 0, got {self.qps}")
        if not 0.0 <= self.shared_prefix_ratio <= 1.0:
            raise ValueError("shared_prefix_ratio must be in [0, 1]")
        if self.shared_prefix_ratio > 0 and self.shared_prefix_len <= 0:
            raise ValueError("shared_prefix_len must be > 0 when "
                             "shared_prefix_ratio > 0")
        if self.priority_mix is not None:
            if not self.priority_mix:
                raise ValueError("priority_mix must be non-empty or None")
            if any(w <= 0 for _, w in self.priority_mix):
                raise ValueError("priority_mix weights must be > 0")


@dataclasses.dataclass(frozen=True)
class TimedRequest:
    index: int
    at_s: float                 # arrival offset from trace start
    prompt: Tuple[int, ...]
    max_tokens: int
    seed: int                   # per-request sampling seed (rid-invariant)
    prefix_group: Optional[int]  # which shared prefix, None = unique prompt
    priority: Optional[str] = None  # scheduling class; None = engine default

    def payload(self, spec: LoadSpec) -> dict:
        """The POST /generate body for this request."""
        from repro.serve.client import generate_payload

        return generate_payload(
            self.prompt, max_tokens=self.max_tokens,
            temperature=spec.temperature, top_k=spec.top_k,
            seed=self.seed, priority=self.priority)


def _pick(rng: np.random.Generator, mix: Mix) -> int:
    values = np.array([v for v, _ in mix])
    weights = np.array([w for _, w in mix], dtype=np.float64)
    return int(rng.choice(values, p=weights / weights.sum()))


def generate(spec: LoadSpec) -> List[TimedRequest]:
    """One deterministic trace. Single rng, fixed draw order."""
    rng = np.random.default_rng(spec.seed)
    # the class stream gets its OWN rng: drawing classes from the main
    # stream would advance its state and perturb every later request's
    # arrival/length/prefix draws — FIFO vs priority benchmark variants
    # must replay the SAME traffic, classes aside
    prio_rng = np.random.default_rng([spec.seed, 0x70726976])
    gaps = rng.exponential(1.0 / spec.qps, size=spec.n_requests)
    arrivals = np.cumsum(gaps)
    prefixes = [
        tuple(int(t) for t in rng.integers(0, spec.vocab,
                                           size=spec.shared_prefix_len))
        for _ in range(spec.n_prefix_groups)
    ]
    out: List[TimedRequest] = []
    for i in range(spec.n_requests):
        plen = _pick(rng, spec.prompt_mix)
        max_tokens = _pick(rng, spec.output_mix)
        group = None
        if rng.random() < spec.shared_prefix_ratio:
            group = int(rng.integers(0, spec.n_prefix_groups))
        tail = tuple(int(t) for t in rng.integers(0, spec.vocab, size=plen))
        prompt = (prefixes[group] + tail) if group is not None else tail
        seed = int(rng.integers(0, 2**31 - 1))
        priority = None
        if spec.priority_mix is not None:
            weights = np.array([w for _, w in spec.priority_mix],
                               dtype=np.float64)
            j = int(prio_rng.choice(len(spec.priority_mix),
                                    p=weights / weights.sum()))
            priority = spec.priority_mix[j][0]
        out.append(TimedRequest(
            index=i, at_s=float(arrivals[i]), prompt=prompt,
            max_tokens=max_tokens, seed=seed,
            prefix_group=group, priority=priority))
    return out


async def replay(host: str, port: int, spec: LoadSpec,
                 schedule: Optional[Sequence[TimedRequest]] = None,
                 *, speed: float = 1.0) -> List[dict]:
    """Play a trace against a live server; one record per request.

    Each request sleeps until its scheduled arrival (scaled by ``speed``:
    2.0 = replay twice as fast), then rides the real SSE wire. TTFT and
    ITLs come from client-side event receive timestamps, so they include
    everything a user would see: queueing, prefill, detokenize backlog,
    and the write path.
    """
    from repro.serve.client import sse_generate

    t0 = time.perf_counter()

    async def one(req: TimedRequest) -> dict:
        delay = req.at_s / speed - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        submit = time.perf_counter()
        status, events, times = await sse_generate(
            host, port, req.payload(spec))
        tok_times = [t for e, t in zip(events, times) if "token" in e]
        done = next((e for e in events if e.get("done")), None)
        return dict(
            index=req.index,
            priority=req.priority,
            status=status,
            tokens=[e["token"] for e in events if "token" in e],
            text=done.get("text") if done else None,
            finish_reason=done.get("finish_reason") if done else None,
            ttft_s=(tok_times[0] - submit) if tok_times else None,
            itls_s=[b - a for a, b in zip(tok_times, tok_times[1:])],
            end_s=time.perf_counter() - t0,
        )

    return list(await asyncio.gather(*(one(r) for r in (
        schedule if schedule is not None else generate(spec)))))


def summarize(results: Sequence[dict]) -> dict:
    """Fold replay records into the p50/p99 + sustained-rate row."""
    ok = [r for r in results if r["status"] == 200]
    ttfts = [r["ttft_s"] for r in ok if r["ttft_s"] is not None]
    itls = [g for r in ok for g in r["itls_s"]]
    n_tokens = sum(len(r["tokens"]) for r in ok)
    span = max((r["end_s"] for r in ok), default=0.0)

    def pct(xs, q):
        return round(1e3 * float(np.percentile(xs, q)), 2) if xs else None

    return dict(
        requests=len(results),
        completed=len(ok),
        rejected=sum(1 for r in results if r["status"] == 429),
        tokens=n_tokens,
        ttft_p50_ms=pct(ttfts, 50),
        ttft_p99_ms=pct(ttfts, 99),
        itl_p50_ms=pct(itls, 50),
        itl_p99_ms=pct(itls, 99),
        sustained_tok_s=round(n_tokens / span, 1) if span > 1e-9 else None,
    )


def summarize_by_class(results: Sequence[dict]) -> dict:
    """Per-priority-class ``summarize`` rows keyed by class name — the
    scheduler benchmark's shape: the whole point of priorities is that
    the interactive column moves while the batch column barely pays."""
    classes = sorted({r.get("priority") or "default" for r in results})
    return {
        cls: summarize([r for r in results
                        if (r.get("priority") or "default") == cls])
        for cls in classes
    }


# ---------------------------------------------------------------------------
# /metrics scraping: server-side telemetry beside the client-side numbers
# ---------------------------------------------------------------------------

# every telemetry-enabled server must expose these families; the load
# harness asserts their presence so a silent registry regression fails
# the bench, not a dashboard three weeks later
REQUIRED_METRICS = (
    "serve_requests_submitted_total",
    "serve_requests_finished_total",
    "serve_tokens_total",
    "serve_request_ttft_seconds",
    "serve_request_itl_seconds",
    "serve_request_e2e_seconds",
    "serve_tick_seconds",
    "serve_tick_phase_seconds",
    "serve_retraces_total",
    "serve_queue_depth",
    "serve_live_slots",
    "serve_http_request_seconds",
    "serve_streams_opened_total",
)


def parse_metrics(text: str) -> dict:
    """Prometheus text exposition -> ``{name{labels}: float}`` plus the
    family name set. Minimal by design (the serving registry emits a
    known subset of the format); unparsable lines raise — a malformed
    exposition is a bug, not noise."""
    samples: dict = {}
    families = set()
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            families.add(line.split()[2])
            continue
        if line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        if not key:
            raise ValueError(f"malformed exposition line: {line!r}")
        samples[key] = float(val)
    return {"samples": samples, "families": families}


async def scrape_metrics(host: str, port: int) -> dict:
    """GET /metrics from a live server, parsed."""
    from repro.serve.client import request_text

    status, text = await request_text(host, port, "GET", "/metrics")
    if status != 200:
        raise RuntimeError(f"/metrics returned {status}: {text[:200]}")
    return parse_metrics(text)


def check_metrics(before: dict, after: dict) -> dict:
    """Assert the telemetry contract across a load run: every required
    family exists, counters are monotonic, and the run actually moved
    the token/tick counters. Returns the counter deltas."""
    for name in REQUIRED_METRICS:
        if name not in after["families"]:
            raise AssertionError(
                f"required metric family missing from /metrics: {name}")
    deltas = {}
    for key, v_after in after["samples"].items():
        base = key.split("{")[0]
        if not (base.endswith("_total") or base.endswith("_count")
                or base.endswith("_bucket") or base.endswith("_sum")):
            continue                     # gauges may move either way
        v_before = before["samples"].get(key)
        if v_before is not None and v_after < v_before - 1e-9:
            raise AssertionError(
                f"counter went backwards: {key} {v_before} -> {v_after}")
        deltas[key] = v_after - (v_before or 0.0)
    if deltas.get("serve_tokens_total", 0) <= 0:
        raise AssertionError(
            "load run emitted no tokens per server-side telemetry")
    if deltas.get("serve_tick_seconds_count", 0) <= 0:
        raise AssertionError(
            "load run recorded no engine ticks per server-side telemetry")
    return deltas


def server_quantiles(metrics: dict) -> dict:
    """Bucket-interpolated p50/p99 (ms) for the latency histograms in a
    parsed /metrics scrape — the server-side column ``summarize`` rows
    carry beside the client-measured numbers."""
    out = {}
    for family, key in (("serve_request_ttft_seconds", "server_ttft"),
                        ("serve_request_itl_seconds", "server_itl"),
                        ("serve_tick_seconds", "server_tick")):
        buckets = []
        for name, v in metrics["samples"].items():
            if name.startswith(family + "_bucket{"):
                le = name.split('le="')[1].split('"')[0]
                buckets.append((float("inf") if le == "+Inf"
                                else float(le), v))
        buckets.sort()
        total = buckets[-1][1] if buckets else 0
        if not total:
            out[f"{key}_p50_ms"] = out[f"{key}_p99_ms"] = None
            continue
        for q in (0.50, 0.99):
            target = q * total
            prev_edge, prev_cum = 0.0, 0.0
            est = buckets[-2][0] if len(buckets) > 1 else 0.0
            for edge, cum in buckets:
                if cum >= target:
                    if edge == float("inf"):
                        est = prev_edge
                    else:
                        frac = ((target - prev_cum)
                                / max(cum - prev_cum, 1e-12))
                        est = prev_edge + frac * (edge - prev_edge)
                    break
                prev_edge, prev_cum = edge, cum
            out[f"{key}_p{int(q * 100)}_ms"] = round(1e3 * est, 3)
    return out

"""Model-family serving benchmark: MoE decode ticks, encdec TTFT.

The ServableModel contract lets one engine drive decoder-only, MoE, and
encoder-decoder configs; this bench measures what the two new families
cost under the SAME scheduler:

* **MoE decode tick latency** — slots saturated with decoding requests,
  wall time per jitted decode tick: the drop-free serve dispatch
  (capacity = tokens * k, fixed-shape; nn/moe.py) vs the dense baseline
  arch at the same slot count. One compile each, then steady state.
* **encdec TTFT with/without encoder reuse** — first request over a
  fresh source pays the ENCODE tick; a second request over the SAME
  source hits the digest-keyed EncoderCache, maps the existing cross
  pages, and skips encode. Reported in engine ticks (deterministic) and
  wall ms; the bench asserts warm strictly beats cold in ticks — the
  reuse path's whole point.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, save_rows


def _build_engine(arch, *, n_slots, max_len=64, chunk_tokens=8,
                  seed=0, **cfg_over):
    from repro.configs import build_model, get_config
    from repro.nn import module as mod
    from repro.nn.context import SERVE, TRAIN, ModelContext
    from repro.serve.engine import BatchedEngine, ServeConfig
    from repro.serve.weights import export_serving_params

    cfg = get_config(arch).reduced()
    tm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN,
                                       compute_dtype=jnp.float32))
    sm = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                       compute_dtype=jnp.float32,
                                       use_pallas=False))
    tp = mod.init_params(tm.specs(), jax.random.PRNGKey(seed))
    sp = export_serving_params(tm.specs(), sm.specs(), tp, cfg.tbn)
    eng = BatchedEngine(sm, sp, ServeConfig(
        n_slots=n_slots, max_len=max_len, chunk_tokens=chunk_tokens,
        page_tokens=8, seed=seed, **cfg_over))
    return cfg, eng


def _decode_tick_row(arch, *, n_slots=4, decode_ticks=40) -> dict:
    """Saturate every slot, prefill through, then time pure decode ticks."""
    from repro.serve.sampling import SamplingParams

    cfg, eng = _build_engine(arch, n_slots=n_slots)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab, size=8).astype(np.int32),
                       SamplingParams(max_tokens=decode_ticks + 8))
            for _ in range(n_slots)]
    # burn prefill + the first decode tick (compile) out of the timing
    while any(not r.output for r in reqs):
        eng.step()
    eng.step()
    times = []
    for _ in range(decode_ticks):
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    eng.abort_all()
    ms = np.array(times) * 1e3
    return dict(section="moe_decode_tick", arch=arch, n_slots=n_slots,
                decode_ticks=decode_ticks,
                tick_ms_mean=round(float(ms.mean()), 2),
                tick_ms_p50=round(float(np.percentile(ms, 50)), 2),
                tick_ms_p99=round(float(np.percentile(ms, 99)), 2))


def _ttft(eng, prompt, frames) -> dict:
    """Submit one request and step until its first token; returns ticks
    and wall ms from submission."""
    from repro.serve.sampling import SamplingParams

    req = eng.submit(np.asarray(prompt, np.int32),
                     SamplingParams(max_tokens=4), frames=frames)
    ticks = 0
    t0 = time.perf_counter()
    while eng.has_work and not req.output:
        eng.step()
        ticks += 1
    wall_ms = (time.perf_counter() - t0) * 1e3
    while eng.has_work:            # drain the tail tokens
        eng.step()
    return dict(req=req, ticks=ticks, wall_ms=wall_ms)


def _encdec_rows(arch="seamless-m4t-large-v2", *, enc_tokens=16) -> list:
    cfg, eng = _build_engine(arch, n_slots=2, enc_tokens=enc_tokens,
                             prefix_cache=True)
    eng.warmup()                   # compiles land outside both TTFTs
    rng = np.random.default_rng(0)
    frames = rng.standard_normal((enc_tokens - 2, cfg.d_model)).astype(
        np.float32)
    prompt = rng.integers(0, cfg.vocab, size=7).astype(np.int32)
    cold = _ttft(eng, prompt, frames)
    warm = _ttft(eng, prompt, frames)
    st = eng.stats()
    assert st["encode_ticks"] == 1, st["encode_ticks"]
    assert warm["req"].enc_reused
    assert warm["ticks"] < cold["ticks"], (
        f"warm TTFT {warm['ticks']} ticks !< cold {cold['ticks']}"
    )
    rows = []
    for label, r in (("cold (encode)", cold), ("warm (reuse)", warm)):
        rows.append(dict(section="encdec_ttft", arch=arch,
                         variant=label, enc_frames=int(frames.shape[0]),
                         ttft_ticks=r["ticks"],
                         ttft_ms=round(r["wall_ms"], 1),
                         enc_reused=bool(r["req"].enc_reused)))
    return rows


def run(quick: bool = False):
    decode_ticks = 10 if quick else 40
    rows = []
    for arch in ("granite-8b", "qwen2-moe-a2.7b"):
        print(f"  decode ticks: {arch}", flush=True)
        rows.append(_decode_tick_row(arch, decode_ticks=decode_ticks))
    print(fmt_table([r for r in rows if r["section"] == "moe_decode_tick"],
                    ["arch", "n_slots", "tick_ms_mean", "tick_ms_p50",
                     "tick_ms_p99"]))
    print("  encdec TTFT cold vs warm", flush=True)
    enc_rows = _encdec_rows()
    rows.extend(enc_rows)
    print(fmt_table(enc_rows, ["variant", "enc_frames", "ttft_ticks",
                               "ttft_ms", "enc_reused"]))
    save_rows("table7_model_families", rows)
    return rows


if __name__ == "__main__":
    run()

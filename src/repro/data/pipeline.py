"""Shard-aware host data pipeline with background prefetch.

Deterministic: iterator state is just (seed, step); a restart at step N
regenerates the identical stream (used by ft.recovery)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict



class DataPipeline:
    def __init__(
        self,
        gen: Callable[[int], Dict],
        start_step: int = 0,
        prefetch: int = 2,
    ):
        self._gen = gen
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=max(1, prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._gen(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    @property
    def step(self) -> int:
        return self._step

    def close(self):
        """Stop and JOIN the prefetch thread. Leaving it running as a daemon
        is not safe: it calls into jax, and a daemon thread killed mid-XLA
        call at interpreter exit aborts the process from C++ ("terminate
        called without an active exception")."""
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():  # generator stuck >5s — still unsafe
            import logging

            logging.getLogger("repro.data").warning(
                "prefetch thread did not stop within 5s; process exit may "
                "abort if it is inside a jax call"
            )

"""Deterministic synthetic datasets (no external data on this box).

Every generator is a pure function of (seed, step, shard) so that
  * restarts reproduce the exact token stream from a step counter
    (fault-tolerance requirement: the recovery manager replays data), and
  * each data-parallel host pulls disjoint shards without coordination.

Tasks:
  * lm_batch          — Zipf-ish Markov token stream (LM pretraining proxy)
  * teacher_mlp       — teacher-student regression/classification
  * point_cloud       — clustered 3-D point clouds (PointNet proxy)
  * sine_mixture      — multivariate time-series forecasting (paper Table 5)
  * image_like        — low-res "images" with class-dependent textures
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _key(seed: int, step: int, shard: int = 0) -> jax.Array:
    k = jax.random.PRNGKey(seed)
    k = jax.random.fold_in(k, step)
    return jax.random.fold_in(k, shard)


def lm_batch(
    seed: int, step: int, batch: int, seq: int, vocab: int, shard: int = 0
) -> Dict[str, jax.Array]:
    """Markov-chain token stream: learnable low-entropy structure so small
    models visibly reduce loss within a few hundred steps."""
    k1, k2, k3 = jax.random.split(_key(seed, step, shard), 3)
    # deterministic per-seed transition "matrix" via hashing: next token is
    # a fixed function of current token plus noise.
    base = jax.random.randint(k1, (batch, 1), 0, vocab)
    mults = jnp.asarray([17, 31, 101], jnp.int32)

    def gen(tok, k):
        noise = jax.random.bernoulli(k, 0.1, tok.shape)
        rand = jax.random.randint(k, tok.shape, 0, vocab)
        nxt = (tok * mults[0] + 7) % vocab
        return jnp.where(noise, rand, nxt)

    toks = [base]
    keys = jax.random.split(k2, seq - 1)
    for i in range(seq - 1):
        toks.append(gen(toks[-1], keys[i]))
    tokens = jnp.concatenate(toks, axis=1)
    return {"tokens": tokens}


def teacher_mlp(
    seed: int, step: int, batch: int, dim: int, classes: int, shard: int = 0
) -> Tuple[jax.Array, jax.Array]:
    """Fixed random teacher network labels random inputs."""
    kw = jax.random.PRNGKey(seed + 7777)  # teacher fixed across steps
    w1 = jax.random.normal(kw, (dim, 64))
    w2 = jax.random.normal(jax.random.fold_in(kw, 1), (64, classes))
    kx = _key(seed, step, shard)
    x = jax.random.normal(kx, (batch, dim))
    y = jnp.argmax(jnp.tanh(x @ w1) @ w2, axis=-1)
    return x, y


def point_cloud(
    seed: int, step: int, batch: int, n_points: int, classes: int, shard: int = 0
) -> Tuple[jax.Array, jax.Array]:
    """Class = which of `classes` fixed anchor layouts generated the cloud."""
    kanchor = jax.random.PRNGKey(seed + 4242)
    anchors = jax.random.normal(kanchor, (classes, 8, 3)) * 2.0
    k1, k2 = jax.random.split(_key(seed, step, shard))
    labels = jax.random.randint(k1, (batch,), 0, classes)
    sel = anchors[labels]                                   # (B, 8, 3)
    idx = jax.random.randint(k2, (batch, n_points), 0, 8)
    centers = jnp.take_along_axis(
        sel, idx[..., None].repeat(3, -1), axis=1
    )
    pts = centers + 0.1 * jax.random.normal(k2, (batch, n_points, 3))
    return pts, labels


def sine_mixture(
    seed: int, step: int, batch: int, length: int, features: int, shard: int = 0
) -> jax.Array:
    """Multivariate series: per-feature frequency/phase mixtures + noise."""
    kf = jax.random.PRNGKey(seed + 99)
    freqs = jax.random.uniform(kf, (features, 3), minval=0.02, maxval=0.3)
    amps = jax.random.uniform(jax.random.fold_in(kf, 1), (features, 3))
    k = _key(seed, step, shard)
    phase = jax.random.uniform(k, (batch, features, 3), maxval=2 * np.pi)
    t = jnp.arange(length, dtype=jnp.float32)
    sig = jnp.sum(
        amps[None, :, :, None]
        * jnp.sin(freqs[None, :, :, None] * t + phase[..., None]),
        axis=2,
    )  # (B, F, L)
    noise = 0.05 * jax.random.normal(k, sig.shape)
    return jnp.moveaxis(sig + noise, 1, 2)  # (B, L, F)


def image_like(
    seed: int, step: int, batch: int, res: int, classes: int, shard: int = 0
) -> Tuple[jax.Array, jax.Array]:
    kpat = jax.random.PRNGKey(seed + 31337)
    patterns = jax.random.normal(kpat, (classes, res, res, 3))
    k1, k2 = jax.random.split(_key(seed, step, shard))
    labels = jax.random.randint(k1, (batch,), 0, classes)
    x = patterns[labels] + 0.5 * jax.random.normal(k2, (batch, res, res, 3))
    return x, labels


def frames_batch(seed: int, step: int, batch: int, seq: int, cfg, shard: int = 0):
    """Enc-dec batch: synthetic frame embeddings + markov decoder tokens."""
    k = _key(seed, step, shard)
    frames = 0.1 * jax.random.normal(k, (batch, seq, cfg.d_model))
    toks = lm_batch(seed, step, batch, max(2, seq // cfg.dec_ratio), cfg.vocab,
                    shard=shard)["tokens"]
    return {"frames": frames, "tokens": toks}

"""Normalization layers (never quantized — paper policy)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import module as mod
from repro.nn.context import ModelContext


@dataclasses.dataclass
class RMSNorm:
    dim: int
    ctx: ModelContext
    name: str = "rmsnorm"
    eps: float = 1e-6

    def specs(self) -> mod.SpecTree:
        return {"scale": mod.ParamSpec((self.dim,), jnp.float32, ("embed",), mod.ones_init())}

    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        # Reduction in f32; elementwise math stays in the input dtype so no
        # (B, S, d) f32 copy of the residual stream is ever materialized
        # (XLA keeps the widest version of a fused elementwise chain alive —
        # an f32 x here costs 2x the dominant training buffer).
        dt = x.dtype
        var = jnp.mean(
            jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True
        )
        inv = jax.lax.rsqrt(var + self.eps).astype(dt)
        return x * inv * params["scale"].astype(dt)


@dataclasses.dataclass
class LayerNorm:
    dim: int
    ctx: ModelContext
    name: str = "layernorm"
    eps: float = 1e-5

    def specs(self) -> mod.SpecTree:
        return {
            "scale": mod.ParamSpec((self.dim,), jnp.float32, ("embed",), mod.ones_init()),
            "bias": mod.ParamSpec((self.dim,), jnp.float32, ("embed",), mod.zeros_init()),
        }

    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        dt = x.dtype
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        # f32 reductions only; elementwise apply in the input dtype (see
        # RMSNorm note).
        y = (x - mu.astype(dt)) * jax.lax.rsqrt(var + self.eps).astype(dt)
        return y * params["scale"].astype(dt) + params["bias"].astype(dt)

"""NN substrate: functional modules with TBN-aware layers."""
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.nn.module import (
    ParamSpec,
    abstract_params,
    init_params,
    logical_axes,
    param_count,
)

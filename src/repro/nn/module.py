"""Minimal declarative parameter system (no flax on this box).

A module is a plain dataclass exposing
    specs()  -> nested dict of ParamSpec            (declaration)
    __call__(params, *args)                         (pure apply)

From the spec tree we derive everything the distributed runtime needs:
    init_params(specs, key)      concrete fp32 parameters (deterministic
                                 per-path key folding)
    abstract_params(specs)       jax.ShapeDtypeStruct tree (dry-run, no
                                 allocation)
    logical_axes(specs)          PartitionSpec-of-logical-names tree, mapped
                                 to mesh axes by repro.distributed.sharding
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


# ---------------------------------------------------------------------------
# Per-slot state slicing, shared by every serving cache family that keeps a
# slot axis (SSM / RG-LRU carries, windowed-attention rings): one
# implementation of "one slot's rows as a standalone pytree" and its
# inverse, so slot-axis handling cannot diverge between families.
# ``axis`` is the slot axis (1 under a stacked layer scan).
# ---------------------------------------------------------------------------
def slice_slot_rows(tree, slot, axis: int = 0):
    return jax.tree.map(lambda v: v[(slice(None),) * axis + (slot,)], tree)


def set_slot_rows(tree, slot, rows, axis: int = 0):
    return jax.tree.map(
        lambda v, s: v.at[(slice(None),) * axis + (slot,)].set(
            s.astype(v.dtype)
        ),
        tree, rows,
    )


def kaiming(scale: float = 1.0, fan_axis: int = -1) -> Initializer:
    def init(key, shape, dtype):
        fan_in = shape[fan_axis] if len(shape) else 1
        if len(shape) > 2:  # conv OIHW: fan_in = I*kh*kw
            fan_in = int(np.prod(shape[1:]))
        std = scale * float(np.sqrt(2.0 / max(1, fan_in)))
        return std * jax.random.normal(key, shape, dtype)

    return init


def normal(stddev: float = 0.02) -> Initializer:
    return lambda key, shape, dtype: stddev * jax.random.normal(key, shape, dtype)


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def constant_init(v: float) -> Initializer:
    return lambda key, shape, dtype: jnp.full(shape, v, dtype)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    logical: Tuple[Optional[str], ...] = ()  # logical axis name per dim
    init: Initializer = dataclasses.field(default_factory=lambda: normal(0.02))

    def __post_init__(self):
        if self.logical and len(self.logical) != len(self.shape):
            raise ValueError(
                f"logical {self.logical} does not match shape {self.shape}"
            )


SpecTree = Union[ParamSpec, Dict[str, "SpecTree"]]


def _walk(tree: SpecTree, path=()):  # yields (path, ParamSpec)
    if isinstance(tree, ParamSpec):
        yield path, tree
    elif isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], path + (k,))
    elif tree is None:
        return
    else:
        raise TypeError(f"bad spec node at {path}: {type(tree)}")


def _set(out: dict, path, value):
    node = out
    for k in path[:-1]:
        node = node.setdefault(k, {})
    node[path[-1]] = value


def _path_key(key: jax.Array, path: Tuple[str, ...]) -> jax.Array:
    digest = hashlib.md5("/".join(path).encode()).digest()
    fold = int.from_bytes(digest[:4], "little")
    return jax.random.fold_in(key, fold)


def init_params(specs: SpecTree, key: jax.Array) -> dict:
    """Deterministic, path-keyed parameter initialization."""
    out: dict = {}
    for path, spec in _walk(specs):
        k = _path_key(key, path)
        _set(out, path, spec.init(k, spec.shape, spec.dtype))
    return out


def abstract_params(specs: SpecTree) -> dict:
    out: dict = {}
    for path, spec in _walk(specs):
        _set(out, path, jax.ShapeDtypeStruct(spec.shape, spec.dtype))
    return out


def logical_axes(specs: SpecTree) -> dict:
    """Tree of logical-axis tuples, mirroring the param tree."""
    out: dict = {}
    for path, spec in _walk(specs):
        _set(out, path, tuple(spec.logical) if spec.logical else (None,) * len(spec.shape))
    return out


def param_count(specs: SpecTree) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _walk(specs))


def stack_specs(specs: SpecTree, n: int, axis_name: Optional[str] = "layers") -> SpecTree:
    """Prepend a stacking dim (for scan-over-layers parameter stacking)."""
    out: dict = {}
    for path, spec in _walk(specs):
        _set(
            out,
            path,
            ParamSpec(
                shape=(n,) + spec.shape,
                dtype=spec.dtype,
                logical=(axis_name,) + (tuple(spec.logical) or (None,) * len(spec.shape)),
                init=_stacked_init(spec.init, n),
            ),
        )
    return out


def _stacked_init(inner: Initializer, n: int) -> Initializer:
    def init(key, shape, dtype):
        keys = jax.random.split(key, n)
        return jnp.stack([inner(k, shape[1:], dtype) for k in keys])

    return init

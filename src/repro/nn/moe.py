"""Mixture-of-Experts FFN: top-k routing, shared experts, EP-shardable.

Dispatch is the sort-based capacity scheme (GShard/MaxText "dropped"
family): token->expert assignments are sorted by expert id, each expert
takes its first C tokens into a dense (E, C, d) buffer (overflow dropped —
zero gradient), expert FFNs run as one batched einsum over E, results
scatter back weighted by the router gates. All shapes static; the (E, ...)
buffers carry the "experts" logical axis so the runtime shards them over the
model axis (expert parallelism — GSPMD inserts the all-to-alls).

Beyond-paper: each expert's FFN matrices are TBN-tiled *per expert* (the
paper never evaluates MoE; per-expert tiles keep the sub-bit storage story:
E tiles of q bits instead of E dense expert matrices).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.tiling import TileSpec, tiled_weight
from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.context import SERVE, ModelContext
from repro.nn.ffn import MLP
from repro.nn.linear import bwnn_weight
from repro.core.packing import packed_len, unpack_bits


def _cumsum_exclusive(x):
    return jnp.cumsum(x) - x


@dataclasses.dataclass
class ExpertBank:
    """E stacked (n_out, n_in) matrices with per-expert TBN tiles."""

    n_experts: int
    n_in: int
    n_out: int
    ctx: ModelContext
    name: str = "experts"

    def __post_init__(self):
        self.spec: Optional[TileSpec] = self.ctx.policy.spec_for(
            (self.n_out, self.n_in), kind="dense"
        )
        # The bank is E independent tiled layers for bit accounting.
        for e in range(self.n_experts):
            self.ctx.note(
                f"{self.name}[{e}]",
                (self.n_out, self.n_in),
                kind="dense",
                spec=self.spec,
            )

    def specs(self) -> mod.SpecTree:
        pd = self.ctx.param_dtype
        e = self.n_experts
        if self.ctx.mode == SERVE:
            if self.spec is not None and self.spec.aligned_rows:
                # Row-packed per-expert tiles (E, r, words). "experts" wins
                # the model axis (expert parallelism, first-claim rule in
                # distributed/sharding.py); "tile_rows" then shards only on
                # meshes where the expert axis is absent or dropped.
                return {
                    "tile": mod.ParamSpec(
                        (e, self.spec.rows_per_tile, packed_len(self.n_in)),
                        jnp.int32, ("experts", "tile_rows", None),
                        mod.zeros_init(),
                    ),
                    "alpha": mod.ParamSpec(
                        (e, self.spec.n_alpha), jnp.float32,
                        ("experts", None), mod.ones_init(),
                    ),
                }
            if self.spec is not None:  # unaligned: flat per-expert tiles
                return {
                    "tile": mod.ParamSpec(
                        (e, packed_len(self.spec.q)), jnp.int32,
                        ("experts", None), mod.zeros_init(),
                    ),
                    "alpha": mod.ParamSpec(
                        (e, self.spec.n_alpha), jnp.float32,
                        ("experts", None), mod.ones_init(),
                    ),
                }
            return {
                "w": mod.ParamSpec(
                    (e, self.n_out, self.n_in), self.ctx.compute_dtype,
                    ("experts", "mlp", "embed"), mod.kaiming(),
                )
            }
        out = {
            "w": mod.ParamSpec(
                (e, self.n_out, self.n_in), pd,
                ("experts", "mlp", "embed"), mod.kaiming(),
            )
        }
        if self.spec is not None and self.spec.alpha_source == "A":
            out["a"] = mod.ParamSpec(
                (e, self.n_out, self.n_in), pd,
                ("experts", "mlp", "embed"), mod.kaiming(),
            )
        return out

    def effective(self, params: dict) -> jax.Array:
        """(E, n_out, n_in) effective weights in compute dtype."""
        cd = self.ctx.compute_dtype
        if self.ctx.mode == SERVE:
            if self.spec is not None:
                tile = params["tile"]
                if tile.ndim == 3:  # row-packed (E, r, words)
                    t = unpack_bits(tile, self.n_in, dtype=cd)  # (E, r, n_in)
                    t = t.reshape(self.n_experts, self.spec.q)
                else:               # flat (E, ceil(q/32))
                    t = unpack_bits(tile, self.spec.q, dtype=cd)  # (E, q)
                def rebuild(te, ae):
                    from repro.core.tiling import reconstruct_from_tile
                    return reconstruct_from_tile(te, ae, self.spec, dtype=cd)
                return jax.vmap(rebuild)(t, params["alpha"])
            return params["w"].astype(cd)
        w = params["w"]
        if self.spec is not None:
            a = params.get("a")
            if self.spec.aligned_rows:
                # axis-sum construction: only the p-fold smaller tile
                # crosses the network (partial-sum AR), not the weights
                from repro.core.tiling import tiled_weight_rows

                return tiled_weight_rows(w, self.spec, a=a, dtype=cd)
            if a is None:
                vm = jax.vmap(lambda we: tiled_weight(we, self.spec, dtype=cd))(w)
            else:
                vm = jax.vmap(
                    lambda we, ae: tiled_weight(we, self.spec, a=ae, dtype=cd)
                )(w, a)
            return vm.reshape(self.n_experts, self.n_out, self.n_in)
        if self.ctx.policy.binarize("dense"):
            return jax.vmap(lambda we: bwnn_weight(we, cd))(w)
        return w.astype(cd)


@dataclasses.dataclass
class MoE:
    """Top-k routed MoE layer with optional shared experts."""

    d_model: int
    d_ff: int                    # per-expert hidden
    n_experts: int
    top_k: int
    ctx: ModelContext
    n_shared: int = 0            # shared experts (always-on), same d_ff each
    name: str = "moe"
    capacity_factor: float = 1.25
    gated: bool = True           # SwiGLU experts
    activation: str = "silu"

    def __post_init__(self):
        c = self.ctx
        self.router_logical = ("experts", "embed")
        self.up = ExpertBank(self.n_experts, self.d_model, self.d_ff, c,
                             name=f"{self.name}.up")
        if self.gated:
            self.gate_bank = ExpertBank(self.n_experts, self.d_model, self.d_ff, c,
                                        name=f"{self.name}.gate")
        self.down = ExpertBank(self.n_experts, self.d_ff, self.d_model, c,
                               name=f"{self.name}.down")
        if self.n_shared:
            self.shared = MLP(self.d_model, self.d_ff * self.n_shared, c,
                              name=f"{self.name}.shared", gated=self.gated,
                              activation=self.activation)
        c.note(f"{self.name}.router", (self.n_experts, self.d_model),
               kind="norm", spec=None)  # router stays fp32 (below lambda)

    def specs(self) -> mod.SpecTree:
        out = {
            "router": mod.ParamSpec(
                (self.n_experts, self.d_model), jnp.float32,
                self.router_logical, mod.normal(0.02),
            ),
            "up": self.up.specs(),
            "down": self.down.specs(),
        }
        if self.gated:
            out["gate"] = self.gate_bank.specs()
        if self.n_shared:
            out["shared"] = self.shared.specs()
        return out

    def _act(self, x):
        return dict(silu=jax.nn.silu, gelu=jax.nn.gelu, relu=jax.nn.relu,
             relu2=lambda v: jnp.square(jax.nn.relu(v)))[
            self.activation
        ](x)

    def _n_groups(self, t_tokens: int) -> int:
        """Dispatch groups: tokens are routed/sorted/scattered WITHIN a
        group; groups shard over the whole mesh (act_tok). Keeps every
        index op (argsort/gather/scatter) local to a shard — GSPMD
        partitions vmapped index ops along batch dims but replicates
        global ones (a global 1M-token argsort/scatter forced 51GB
        all-gathers). 512 covers the 2-pod mesh; smaller meshes place
        multiple groups per device, which is free.
        G=1 on small hosts == the paper-faithful single-group dispatch."""
        for g in (512, 256, 64, 32, 16, 8):
            if t_tokens % g == 0 and t_tokens >= g * 1024:
                return g
        return 1

    def _dispatch(self, xg, top_idx, gate_vals, cap):
        """Per-group dense dispatch. xg (tl, d); top_idx/gate (tl, k).
        Returns xbuf (E, cap, d) and (e_idx, pos_c, tok_of, gates) for the
        combine step. Dropped (over-capacity) slots are expressed as
        OUT-OF-BOUNDS scatter indices (jit default: dropped) and zeroed
        gates — no (tl*k, d)-sized `keep` mask multiply is materialized."""
        cd = self.ctx.compute_dtype
        tl, d = xg.shape
        e, k = self.n_experts, self.top_k
        flat_e = top_idx.reshape(-1)                                # (tl*k,)
        flat_g = gate_vals.reshape(-1).astype(cd)
        order = jnp.argsort(flat_e)
        tok_of = order // k
        e_sorted = flat_e[order]
        counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
        starts = _cumsum_exclusive(counts)
        pos = jnp.arange(tl * k) - starts[e_sorted]
        keep = (pos >= 0) & (pos < cap)
        e_idx = jnp.where(keep, e_sorted, e)      # e == OOB -> scatter drops
        pos_c = jnp.clip(pos, 0, cap - 1)
        gates = jnp.where(keep, flat_g[order], 0)
        # k-chunked scatter: one (tl, d) gather+scatter per top-k slot keeps
        # the transient at (tl, d) instead of (tl*k, d) — the index vectors
        # are expert-sorted so any static split is a valid partition.
        xbuf = jnp.zeros((e, cap, d), cd)
        for j in range(k):
            sl = slice(j * tl, (j + 1) * tl)
            xbuf = xbuf.at[e_idx[sl], pos_c[sl]].add(
                xg[tok_of[sl]].astype(cd)
            )
        return xbuf, (e_idx, pos_c, tok_of, gates)

    def _combine(self, ybuf, meta, tl):
        cd = self.ctx.compute_dtype
        e_idx, pos_c, tok_of, gates = meta
        k = self.top_k
        y = jnp.zeros((tl, ybuf.shape[-1]), cd)
        for j in range(k):
            sl = slice(j * tl, (j + 1) * tl)
            # OOB e_idx rows gather garbage but are zero-gated;
            # mode="fill" makes them exact zeros.
            yj = ybuf.at[e_idx[sl], pos_c[sl]].get(mode="fill", fill_value=0)
            y = y.at[tok_of[sl]].add(yj * gates[sl, None])
        return y

    # ---------------- serving dispatch ----------------
    def _dispatch_serve(self, xg, top_idx):
        """Drop-free, order-stable dispatch for the serving tick.

        The train-path capacity ``ceil(1.25*k*tl/e)`` DEPENDS on the
        token count tl, and its expert-sorted scatter-add sums in an
        order that depends on the whole batch — so a token's output
        would change with its chunking and its batch neighbors, breaking
        the engine's byte-identical chunked-vs-monolithic parity wall.
        Serving instead uses capacity ``tl * k`` (every (token, slot)
        assignment fits — nothing can drop) and derives each
        assignment's position-in-expert from a token-major one-hot
        exclusive cumsum: slot (t, j) gets a buffer cell that is a pure
        function of the assignments of tokens 0..t, never of capacity
        pressure. Every buffer cell holds exactly one token, so the
        dispatch scatter has no add-order ambiguity, and the combine
        gathers per token in gate-rank order — per-token output is
        independent of tl and of neighbors. All shapes are static in
        (tl, e, k): the decode tick compiles once."""
        cd = self.ctx.compute_dtype
        tl, d = xg.shape
        e, k = self.n_experts, self.top_k
        flat_e = top_idx.reshape(-1)                    # token-major (tl*k,)
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, flat_e[:, None], axis=1
        )[:, 0]
        tok_of = jnp.arange(tl * k) // k
        xbuf = jnp.zeros((e, tl * k, d), cd).at[flat_e, pos].set(
            xg[tok_of].astype(cd)
        )
        return xbuf, (flat_e, pos)

    def _combine_serve(self, ybuf, meta, gate_vals, tl):
        """Gate-rank-order combine: token t's output is the ordered sum
        over j = 0..k-1 of ``gate[t, j] * ybuf[e(t,j), pos(t,j)]`` — a
        fixed-length, fixed-order accumulation per token (no scatter-add
        whose order could vary with batch composition)."""
        cd = self.ctx.compute_dtype
        flat_e, pos = meta
        k = self.top_k
        y = jnp.zeros((tl, ybuf.shape[-1]), cd)
        for j in range(k):
            y = y + (ybuf[flat_e[j::k], pos[j::k]]
                     * gate_vals[:, j, None].astype(cd))
        return y

    def _serve_call(self, params: dict, x: jax.Array):
        """Fixed-shape serving forward: drop-free dispatch (see
        ``_dispatch_serve``), single dispatch group (serve token counts
        are n_slots * chunk at most), expert banks reconstructed from
        their packed (E, r, words) tiles."""
        b, s, d = x.shape
        tl = b * s
        xg = x.reshape(tl, d)

        logits = jnp.einsum(
            "td,ed->te", xg.astype(jnp.float32), params["router"]
        )
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, top_idx = jax.lax.top_k(probs, self.top_k)   # (tl, k)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

        xbuf, meta = self._dispatch_serve(xg, top_idx)          # (E, tl*k, d)
        # (E, cap, d) buffers keep the "experts" leading axis of the
        # weight banks, so on an EP mesh the expert einsums stay local to
        # the expert shard (the banks' first-claim "experts" -> model
        # mapping in distributed/sharding.py).
        w_up = self.up.effective(params["up"])
        h = jnp.einsum("ecd,efd->ecf", xbuf, w_up)
        if self.gated:
            w_gate = self.gate_bank.effective(params["gate"])
            h = self._act(jnp.einsum("ecd,efd->ecf", xbuf, w_gate)) * h
        else:
            h = self._act(h)
        w_down = self.down.effective(params["down"])
        ybuf = jnp.einsum("ecf,edf->ecd", h, w_down)

        y = self._combine_serve(ybuf, meta, gate_vals, tl)
        if self.n_shared:
            y = y + self.shared(params["shared"], xg[None])[0]
        y = y.reshape(b, s, d)
        return (
            logical_constraint(y, "act_batch", "act_seq", "act_embed"),
            jnp.zeros((), jnp.float32),
        )

    def __call__(self, params: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """Returns (output (B,S,d), aux load-balance loss scalar)."""
        if self.ctx.mode == SERVE:
            return self._serve_call(params, x)
        b, s, d = x.shape
        t_tokens = b * s
        # Token-parallel MoE: dispatch groups shard over EVERY mesh axis and
        # the whole layer (routing, dispatch, expert einsums, combine) runs
        # group-local. Expert weights are stored sharded (experts/mlp x
        # embed) and all-gathered at use, ZeRO-3 style — GSPMD overlap
        # prefetches the gather inside the layer scan. This beats the
        # expert-parallel domain switch on this mesh: the all-to-alls and
        # the partial-sum all-reduces (which XLA promotes to f32 and sinks
        # onto (tl*k, d) tensors) disappear entirely.
        g = self._n_groups(t_tokens)
        tl = t_tokens // g
        # Pin the (B,S,d) layout at entry: the constraint's transpose pins
        # the residual cotangent too — without it the backward of the
        # SP <-> token-layout reshape replicates d_x on the 3-axis mesh.
        x = logical_constraint(x, "act_batch", "act_res_seq", None)
        xg = logical_constraint(
            x.reshape(g, tl, d), "act_tok", None, None
        )

        # Router math stays token-sharded: the load-balance aux couples all
        # tokens through a scalar, and without the constraint its backward
        # broadcast marks d_logits replicated — the (T, d) f32 router
        # cotangent then materializes UNSHARDED (8.6 GB/device at 1M tokens).
        logits = logical_constraint(
            jnp.einsum("gtd,ed->gte", xg.astype(jnp.float32), params["router"]),
            "act_tok", None, None,
        )
        probs = logical_constraint(
            jax.nn.softmax(logits, axis=-1), "act_tok", None, None
        )
        gate_vals, top_idx = jax.lax.top_k(probs, self.top_k)   # (g, tl, k)
        gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

        # Switch-style load balance aux (over ALL tokens).
        density = jnp.mean(
            jax.nn.one_hot(top_idx[..., 0], self.n_experts), axis=(0, 1)
        )
        aux = self.n_experts * jnp.sum(density * jnp.mean(probs, axis=(0, 1)))

        e, k = self.n_experts, self.top_k
        cap = int(math.ceil(self.capacity_factor * k * tl / e))
        cap = max(8, -(-cap // 8) * 8)

        xbuf, meta = jax.vmap(
            lambda xi, ti, gi: self._dispatch(xi, ti, gi, cap)
        )(xg, top_idx, gate_vals)                       # (g, E, cap, d)
        tokp = lambda z: logical_constraint(
            z, *(("act_tok",) + (None,) * (z.ndim - 1))
        )
        xbuf = tokp(xbuf)

        w_up = self.up.effective(params["up"])
        h = tokp(jnp.einsum("gecd,efd->gecf", xbuf, w_up))
        if self.gated:
            w_gate = self.gate_bank.effective(params["gate"])
            h = self._act(
                tokp(jnp.einsum("gecd,efd->gecf", xbuf, w_gate))
            ) * h
        else:
            h = self._act(h)
        w_down = self.down.effective(params["down"])
        ybuf = tokp(jnp.einsum("gecf,edf->gecd", h, w_down))

        yg = jax.vmap(lambda yb, *m: self._combine(yb, m, tl))(ybuf, *meta)
        yg = tokp(yg)
        if self.n_shared:
            # shared experts run in the same token-grouped layout — feeding
            # them the (B, S, d) view lets the backward lose the batch
            # sharding (an 8.6 GB/device replicated f32 cotangent).
            yg = yg + tokp(
                self.shared(params["shared"], xg, act=("act_tok", None))
            )
        y = yg.reshape(b, s, d)
        return logical_constraint(y, "act_batch", "act_seq", "act_embed"), aux

"""Mamba2 (SSD — state-space duality) block, chunked matmul formulation.

Faithful to arXiv:2405.21060: the sequence is split into chunks; intra-chunk
terms are dense matmuls (MXU-friendly quadratic-in-chunk), inter-chunk state
is a short lax.scan over chunk boundaries. Decode is the O(1) recurrent
state update — this is why mamba2 runs the ``long_500k`` cell that pure
full-attention archs skip.

TBN applies to the in/out projections (>= lambda); the SSD-specific params
(A, D, dt bias, conv) are tiny and stay fp32 per the lambda policy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.context import ModelContext
from repro.nn.linear import Dense


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., q) -> (..., q, q) lower-triangular segment sums:
    out[i, j] = sum_{k=j+1..i} x[k]  (i >= j), -inf above diagonal."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


@dataclasses.dataclass
class Mamba2Block:
    d_model: int
    ctx: ModelContext
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256
    name: str = "mamba2"

    def __post_init__(self):
        c = self.ctx
        self.d_inner = self.expand * self.d_model
        assert self.d_inner % self.head_dim == 0
        self.n_heads = self.d_inner // self.head_dim
        self.d_conv = self.d_inner + 2 * self.n_groups * self.d_state
        d_in_proj = 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads
        self.in_proj = Dense(self.d_model, d_in_proj, c, name=f"{self.name}.in_proj",
                             logical=("mlp", "embed"))
        self.out_proj = Dense(self.d_inner, self.d_model, c, name=f"{self.name}.out_proj",
                              logical=("embed", "mlp"))

    def specs(self) -> mod.SpecTree:
        f32 = jnp.float32
        return {
            "in_proj": self.in_proj.specs(),
            "out_proj": self.out_proj.specs(),
            "conv_w": mod.ParamSpec((self.conv_width, self.d_conv), f32,
                                    (None, "mlp"), mod.normal(0.1)),
            "conv_b": mod.ParamSpec((self.d_conv,), f32, ("mlp",), mod.zeros_init()),
            "A_log": mod.ParamSpec((self.n_heads,), f32, (None,), mod.zeros_init()),
            "D": mod.ParamSpec((self.n_heads,), f32, (None,), mod.ones_init()),
            "dt_bias": mod.ParamSpec((self.n_heads,), f32, (None,), mod.zeros_init()),
            "norm_scale": mod.ParamSpec((self.d_inner,), f32, ("mlp",), mod.ones_init()),
        }

    # ------------------------------------------------------------------
    def _split(self, zxbcdt):
        di, g, n, h = self.d_inner, self.n_groups, self.d_state, self.n_heads
        z, xc, dt = jnp.split(zxbcdt, [di, di + self.d_conv - 0 * di], axis=-1)
        # xc holds (x, B, C) pre-conv; dt is (.., n_heads)
        return z, xc, dt

    def _conv(self, params, xc):
        """Causal depthwise conv over time (width conv_width)."""
        w = params["conv_w"]  # (cw, d_conv)
        pad = self.conv_width - 1
        xpad = jnp.pad(xc, ((0, 0), (pad, 0), (0, 0)))
        out = sum(
            xpad[:, i : i + xc.shape[1], :] * w[i][None, None, :]
            for i in range(self.conv_width)
        )
        return jax.nn.silu(out + params["conv_b"])

    def _ssd(self, x, dt, A, B, C):
        """Chunked SSD scan.

        x: (b, l, h, p); dt: (b, l, h); A: (h,); B, C: (b, l, g, n).
        Returns y (b, l, h, p) and final state (b, h, p, n).
        """
        b, l, h, p = x.shape
        g, n = B.shape[2], B.shape[3]
        q = min(self.chunk, l)
        while l % q:
            q -= 1
        nc = l // q
        rep = h // g

        xc = x.reshape(b, nc, q, h, p)
        dtc = dt.reshape(b, nc, q, h)
        Bc = jnp.repeat(B.reshape(b, nc, q, g, n), rep, axis=3)
        Cc = jnp.repeat(C.reshape(b, nc, q, g, n), rep, axis=3)

        dA = dtc * A[None, None, None, :]              # (b,nc,q,h) negative
        dA = jnp.moveaxis(dA, -1, -2)                  # (b,nc,h,q)
        A_cum = jnp.cumsum(dA, axis=-1)                # within-chunk cumsum

        # intra-chunk (diagonal block) output
        L = jnp.exp(_segsum(dA))                       # (b,nc,h,q,q)
        xdt = xc * dtc[..., None]                      # dt-weighted inputs
        Ydiag = jnp.einsum("bzihn,bzjhn,bzhij,bzjhp->bzihp", Cc, Bc, L, xdt)

        # per-chunk final states
        decay_to_end = jnp.exp(A_cum[..., -1:] - A_cum)  # (b,nc,h,q)
        states = jnp.einsum("bzjhn,bzhj,bzjhp->bzhpn", Bc, decay_to_end, xdt)

        # inter-chunk recurrence (short scan over nc)
        chunk_decay = jnp.exp(A_cum[..., -1])           # (b,nc,h)

        def step(hprev, inp):
            st, dec = inp
            hnew = hprev * dec[..., None, None] + st
            return hnew, hprev

        init = jnp.zeros((b, h, p, n), x.dtype)
        final, hprevs = jax.lax.scan(
            step,
            init,
            (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        )
        hprevs = jnp.moveaxis(hprevs, 0, 1)             # (b,nc,h,p,n) state entering chunk

        # off-diagonal: contribution of carried-in state
        in_decay = jnp.exp(A_cum)                       # decay from chunk start
        Yoff = jnp.einsum("bzihn,bzhpn,bzhi->bzihp", Cc, hprevs, in_decay)

        y = (Ydiag + Yoff).reshape(b, l, h, p)
        return y, final

    # ------------------------------------------------------------------
    def __call__(self, params: dict, u: jax.Array) -> jax.Array:
        y, _ = self.forward_with_state(params, u)
        return y

    def forward_with_state(self, params: dict, u: jax.Array):
        b, l, _ = u.shape
        cd = self.ctx.compute_dtype
        di, g, n, h = self.d_inner, self.n_groups, self.d_state, self.n_heads
        zxbcdt = self.in_proj(params["in_proj"], u)
        z = zxbcdt[..., :di]
        xc_raw = zxbcdt[..., di : di + self.d_conv]
        dt_raw = zxbcdt[..., di + self.d_conv :]
        # conv tail: decode resumes with the last (w-1) pre-conv inputs
        tail = xc_raw[:, -(self.conv_width - 1):, :].astype(jnp.float32)
        pad = self.conv_width - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        xc = self._conv(params, xc_raw)
        x = xc[..., :di].reshape(b, l, h, self.head_dim)
        Bm = xc[..., di : di + g * n].reshape(b, l, g, n)
        Cm = xc[..., di + g * n :].reshape(b, l, g, n)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
        A = -jnp.exp(params["A_log"])
        x = logical_constraint(x, "act_batch", "act_seq", "act_mlp", None)
        y, state = self._ssd(
            x.astype(jnp.float32), dt, A, Bm.astype(jnp.float32), Cm.astype(jnp.float32)
        )
        y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
        y = y.reshape(b, l, di).astype(cd)
        # gated RMSNorm (mamba2 uses norm before out_proj)
        y = y * jax.nn.silu(z)
        var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
        y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"]).astype(cd)
        out = self.out_proj(params["out_proj"], y)
        out = logical_constraint(out, "act_batch", "act_seq", "act_embed")
        return out, {"h": state, "conv": tail}

    # ------------------------------------------------------------------
    def init_state(self, batch: int, dtype=jnp.float32):
        return {
            "h": jnp.zeros((batch, self.n_heads, self.head_dim, self.d_state), dtype),
            "conv": jnp.zeros((batch, self.conv_width - 1, self.d_conv), dtype),
        }

    def snapshot_state(self, state: dict, slot, axis: int = 0) -> dict:
        """One slot's (h, conv) carry as a standalone pytree. Unlike the
        attention families there is no per-token cache to page: the SSM
        state at a prefix boundary IS the whole prefix, so the serving
        prefix trie (serve/prefix.py) pins exactly this snapshot at each
        page boundary. ``axis`` is the slot axis (1 under a stacked layer
        scan)."""
        return mod.slice_slot_rows(state, slot, axis)

    def restore_state(self, state: dict, slot, snap: dict,
                      axis: int = 0) -> dict:
        """Map a pinned snapshot back into a slot's rows — the O(1)
        prefix-hit admission for the recurrent family (no re-prefill)."""
        return mod.set_slot_rows(state, slot, snap, axis)

    def extend(self, params: dict, u: jax.Array, state: dict, valid: jax.Array):
        """Chunked-prefill step: u (B, C, d_model) advances the recurrent
        state by each row's count of valid columns.

        The in/out projections run once over the whole block (the m=C
        matmul path); the per-token recurrence is a lax.scan of exactly
        the ``decode_step`` update, with padding columns (valid False)
        leaving the (h, conv) carry untouched — so any chunking of the
        same token stream walks the state through the same sequence of
        values, which is what the chunk-size parity tests rely on.
        """
        b, c, _ = u.shape
        cd = self.ctx.compute_dtype
        di, g, n, h = self.d_inner, self.n_groups, self.d_state, self.n_heads
        zxbcdt = self.in_proj(params["in_proj"], u)
        z = zxbcdt[..., :di]
        xc_new = zxbcdt[..., di : di + self.d_conv]
        dt_raw = zxbcdt[..., di + self.d_conv :]
        A = -jnp.exp(params["A_log"])
        w = params["conv_w"]
        rep = h // g

        def step(carry, inp):
            hs, conv = carry
            xc_t, dt_t, v_t = inp          # (B, d_conv), (B, h), (B,)
            win = jnp.concatenate([conv, xc_t[:, None, :]], axis=1)
            xc = jax.nn.silu(
                jnp.einsum("bwd,wd->bd", win.astype(jnp.float32), w)
                + params["conv_b"]
            )
            x = xc[..., :di].reshape(b, h, self.head_dim)
            Bm = jnp.repeat(xc[..., di : di + g * n].reshape(b, g, n), rep, axis=1)
            Cm = jnp.repeat(xc[..., di + g * n :].reshape(b, g, n), rep, axis=1)
            dt = jax.nn.softplus(dt_t.astype(jnp.float32) + params["dt_bias"])
            decay = jnp.exp(dt * A)[..., None, None]
            h_upd = hs * decay + jnp.einsum("bh,bhn,bhp->bhpn", dt, Bm, x)
            y = jnp.einsum("bhn,bhpn->bhp", Cm, h_upd)
            y = y + params["D"][None, :, None] * x
            hs = jnp.where(v_t[:, None, None, None], h_upd, hs)
            conv = jnp.where(v_t[:, None, None], win[:, 1:], conv)
            return (hs, conv), y.reshape(b, di)

        (hstate, conv), ys = jax.lax.scan(
            step,
            (state["h"], state["conv"]),
            (
                jnp.moveaxis(xc_new, 1, 0),
                jnp.moveaxis(dt_raw, 1, 0),
                jnp.moveaxis(valid, 1, 0),
            ),
        )
        y = jnp.moveaxis(ys, 0, 1).astype(cd) * jax.nn.silu(z)   # (B, C, di)
        var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
        y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
             * params["norm_scale"]).astype(cd)
        out = self.out_proj(params["out_proj"], y)
        return out, {"h": hstate, "conv": conv}

    def decode_step(self, params: dict, u: jax.Array, state: dict):
        """u: (B, 1, d_model); O(1) recurrent update."""
        b = u.shape[0]
        cd = self.ctx.compute_dtype
        di, g, n, h = self.d_inner, self.n_groups, self.d_state, self.n_heads
        zxbcdt = self.in_proj(params["in_proj"], u)[:, 0]
        z = zxbcdt[..., :di]
        xc_new = zxbcdt[..., di : di + self.d_conv]
        dt_raw = zxbcdt[..., di + self.d_conv :]
        # conv window update
        win = jnp.concatenate([state["conv"], xc_new[:, None, :]], axis=1)
        w = params["conv_w"]
        xc = jax.nn.silu(
            jnp.einsum("bwd,wd->bd", win.astype(jnp.float32), w) + params["conv_b"]
        )
        new_conv = win[:, 1:]
        x = xc[..., :di].reshape(b, h, self.head_dim)
        Bm = xc[..., di : di + g * n].reshape(b, g, n)
        Cm = xc[..., di + g * n :].reshape(b, g, n)
        rep = h // g
        Bm = jnp.repeat(Bm, rep, axis=1)
        Cm = jnp.repeat(Cm, rep, axis=1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,h)
        A = -jnp.exp(params["A_log"])
        decay = jnp.exp(dt * A)[..., None, None]         # (b,h,1,1)
        hstate = state["h"] * decay + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt, Bm, x
        )
        y = jnp.einsum("bhn,bhpn->bhp", Cm, hstate)
        y = y + params["D"][None, :, None] * x
        y = y.reshape(b, di).astype(cd) * jax.nn.silu(z)
        var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
        y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6) * params["norm_scale"]).astype(cd)
        out = self.out_proj(params["out_proj"], y[:, None, :])
        return out, {"h": hstate, "conv": new_conv}

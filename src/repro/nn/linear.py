"""Dense / Conv layers with first-class TBN quantization.

Every layer consults the model's TBNPolicy:
  * fp32  — ordinary weights.
  * bwnn  — XNOR-style binary weights (sign STE + layer alpha), 1 bit/param.
  * tbn   — tiled sub-bit weights when N >= lambda (else falls back to bwnn,
            matching the paper's accounting for small layers).

In SERVE mode tiled layers carry only (packed tile bits, alpha) — the
shipped representation — and apply through the tile-reuse math
(`repro.kernels.tiled_dense_infer`, Pallas on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import packed_len, unpack_bits
from repro.core.tiling import (
    TileSpec,
    _ste_sign,
    plan_conv_tiling,
    reconstruct_from_tile,
    tiled_weight,
)
from repro.distributed.sharding import logical_constraint
from repro.kernels.ops import tbn_dense_train, tiled_conv_infer, tiled_dense_infer
from repro.nn import module as mod
from repro.nn.context import SERVE, ModelContext


def bwnn_weight(w: jax.Array, compute_dtype) -> jax.Array:
    """XNOR-Net style binary weight: sign(W) * mean|W| with identity STE."""
    alpha = jnp.mean(jnp.abs(w))
    return (_ste_sign(w) * alpha).astype(compute_dtype)


@dataclasses.dataclass
class Dense:
    """y = x @ W^T (+b). Weight stored (n_out, n_in) — paper layout, so the
    row-major tile replication lands on output rows (DESIGN.md §2)."""

    n_in: int
    n_out: int
    ctx: ModelContext
    name: str = "dense"
    kind: str = "dense"            # "dense" | "head"
    use_bias: bool = False
    logical: Tuple[Optional[str], Optional[str]] = ("mlp", "embed")  # (out, in)
    init_scale: float = 1.0

    def __post_init__(self):
        self.spec: Optional[TileSpec] = self.ctx.policy.spec_for(
            (self.n_out, self.n_in), kind=self.kind
        )
        self.ctx.note(
            self.name,
            (self.n_out, self.n_in),
            kind=self.kind,
            spec=self.spec,
            macs=0,
        )

    # -- declarations ------------------------------------------------------
    def specs(self) -> mod.SpecTree:
        pd = self.ctx.param_dtype
        if self.ctx.mode == SERVE:
            return self._serve_specs()
        out: dict = {
            "w": mod.ParamSpec(
                (self.n_out, self.n_in),
                pd,
                self.logical,
                mod.kaiming(self.init_scale),
            )
        }
        if self.spec is not None and self.spec.alpha_source == "A":
            out["a"] = mod.ParamSpec(
                (self.n_out, self.n_in), pd, self.logical, mod.kaiming(self.init_scale)
            )
        if self.use_bias:
            out["b"] = mod.ParamSpec(
                (self.n_out,), pd, (self.logical[0],), mod.zeros_init()
            )
        return out

    def _serve_specs(self) -> mod.SpecTree:
        out: dict = {}
        if self.spec is not None and self.spec.aligned_rows:
            # Shipped form: one packed word-padded row per unique weight
            # row, (r, ceil(n_in/32)). The leading axis is the tensor-
            # parallel shard axis — "tile_rows" maps to the model mesh axis
            # so each device holds r/TP rows (DESIGN.md §5).
            out["tile"] = mod.ParamSpec(
                (self.spec.rows_per_tile, packed_len(self.n_in)),
                jnp.int32, ("tile_rows", None), mod.zeros_init(),
            )
            out["alpha"] = mod.ParamSpec(
                (self.spec.n_alpha,), jnp.float32, (None,), mod.ones_init()
            )
        elif self.spec is not None:
            # Unaligned tiling (p | N but not p | n_out): flat q-bit tile,
            # dense reconstruction at apply time — mirrors Conv2D.
            out["tile"] = mod.ParamSpec(
                (packed_len(self.spec.q),), jnp.int32, (None,), mod.zeros_init()
            )
            out["alpha"] = mod.ParamSpec(
                (self.spec.n_alpha,), jnp.float32, (None,), mod.ones_init()
            )
        elif self.ctx.policy.binarize(self.kind):
            out["wbits"] = mod.ParamSpec(
                (self.n_out, packed_len(self.n_in)),
                jnp.int32,
                (self.logical[0], None),
                mod.zeros_init(),
            )
            out["alpha"] = mod.ParamSpec((1,), jnp.float32, (None,), mod.ones_init())
        else:
            out["w"] = mod.ParamSpec(
                (self.n_out, self.n_in),
                self.ctx.compute_dtype,
                self.logical,
                mod.kaiming(self.init_scale),
            )
        if self.use_bias:
            out["b"] = mod.ParamSpec(
                (self.n_out,), jnp.float32, (self.logical[0],), mod.zeros_init()
            )
        return out

    # -- apply -------------------------------------------------------------
    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        cd = self.ctx.compute_dtype
        if self.ctx.mode == SERVE:
            y = self._serve_apply(params, x)
        else:
            w = params["w"]
            if self.spec is not None and self.ctx.fused_train:
                a = params.get("a", w)
                y = tbn_dense_train(x.astype(cd), w, a, self.spec)
            else:
                if self.spec is not None and self.spec.aligned_rows:
                    from repro.core.tiling import tiled_weight_rows

                    # axis-sum construction (see core.tiling): the tile is
                    # what crosses the network, not the weight
                    weff = tiled_weight_rows(
                        w, self.spec, a=params.get("a"), dtype=cd
                    )
                elif self.spec is not None:
                    weff = tiled_weight(
                        w, self.spec, a=params.get("a"), dtype=cd
                    ).reshape(self.n_out, self.n_in)
                elif self.ctx.policy.binarize(self.kind):
                    weff = bwnn_weight(w, cd)
                else:
                    weff = w.astype(cd)
                if self.ctx.fsdp_weights:
                    # ZeRO-3 contract: masters stay 2D-sharded in HBM; the
                    # effective weight is gathered over the data axis at
                    # use. Stops GSPMD resolving the (2D-sharded weight) x
                    # (seq-sharded activation) contraction by replicating
                    # the activation batch.
                    weff = logical_constraint(weff, self.logical[0], None)
                y = jnp.einsum("...k,ok->...o", x.astype(cd), weff)
        if self.use_bias:
            y = y + params["b"].astype(y.dtype)
        return y

    def _serve_apply(self, params: dict, x: jax.Array) -> jax.Array:
        cd = self.ctx.compute_dtype
        x = x.astype(cd)
        if self.spec is not None and self.spec.aligned_rows:
            y = tiled_dense_infer(
                x,
                params["tile"],
                params["alpha"],
                self.spec,
                use_pallas=self.ctx.use_pallas,
                compute_path=self.ctx.compute_path,
            )
        elif self.spec is not None:  # unaligned: documented dense fallback
            t = unpack_bits(params["tile"], self.spec.q, dtype=cd)
            w = reconstruct_from_tile(t, params["alpha"], self.spec, dtype=cd)
            y = jnp.einsum("...k,ok->...o", x, w.reshape(self.n_out, self.n_in))
        elif "wbits" in params:
            w = unpack_bits(params["wbits"], self.n_in, dtype=cd)
            w = w * params["alpha"].astype(cd)
            y = jnp.einsum("...k,ok->...o", x, w)
        else:
            y = jnp.einsum("...k,ok->...o", x, params["w"].astype(cd))
        return self._constrain_out(y)

    def _constrain_out(self, y: jax.Array) -> jax.Array:
        """Shard the serve-path output so GSPMD partitions the bit-unpack
        and tile-reuse matmul over the model axis (back-propagated through
        the broadcast/reshape by sharding propagation)."""
        act = {
            "mlp": "act_mlp",
            "heads": "act_heads",
            "vocab": "act_vocab",
            "embed": "act_embed",
        }.get(self.logical[0])
        names = ("act_batch",) + (None,) * (y.ndim - 2) + (act,)
        return logical_constraint(y, *names)


@dataclasses.dataclass
class Conv2D:
    """NHWC conv with OIHW-stored weight (paper layout: tiles replicate
    whole output-channel filters -> the Table 2 bit-ops saving).

    In SERVE mode a tiled conv carries only the conv-layout packed tile +
    alpha and applies through ``tiled_conv_infer`` (fused im2col Pallas
    kernel on TPU, structured tile-bank conv elsewhere) — the dense OIHW
    weight is never reconstructed. Unaligned tilings (p does not divide
    c_out, only reachable with ``require_aligned=False``) ship the flat
    packed tile and fall back to dense reconstruction at apply time.
    """

    c_in: int
    c_out: int
    kernel: Tuple[int, int]
    ctx: ModelContext
    stride: Tuple[int, int] = (1, 1)
    padding: str = "SAME"
    name: str = "conv"
    use_bias: bool = False

    def __post_init__(self):
        kh, kw = self.kernel
        self.wshape = (self.c_out, self.c_in, kh, kw)
        self.spec: Optional[TileSpec] = self.ctx.policy.spec_for(
            self.wshape, kind="conv"
        )
        self.plan = plan_conv_tiling(self.spec)
        self.ctx.note(self.name, self.wshape, kind="conv", spec=self.spec)

    def specs(self) -> mod.SpecTree:
        if self.ctx.mode == SERVE:
            return self._serve_specs()
        out = {
            "w": mod.ParamSpec(
                self.wshape, self.ctx.param_dtype, (None,) * 4, mod.kaiming()
            )
        }
        if self.spec is not None and self.spec.alpha_source == "A":
            out["a"] = mod.ParamSpec(
                self.wshape, self.ctx.param_dtype, (None,) * 4, mod.kaiming()
            )
        if self.use_bias:
            out["b"] = mod.ParamSpec(
                (self.c_out,), jnp.float32, (None,), mod.zeros_init()
            )
        return out

    def _serve_specs(self) -> mod.SpecTree:
        out: dict = {}
        if self.plan is not None:
            # (kh*kw, r, words): the unique-filter axis is the tensor-
            # parallel shard axis, same contract as the dense row tile.
            out["tile_conv"] = mod.ParamSpec(
                self.plan.packed_shape(), jnp.int32,
                (None, "tile_rows", None), mod.zeros_init(),
            )
            out["alpha"] = mod.ParamSpec(
                (self.spec.n_alpha,), jnp.float32, (None,), mod.ones_init()
            )
        elif self.spec is not None:  # unaligned: flat tile, dense fallback
            out["tile"] = mod.ParamSpec(
                (packed_len(self.spec.q),), jnp.int32, (None,),
                mod.zeros_init(),
            )
            out["alpha"] = mod.ParamSpec(
                (self.spec.n_alpha,), jnp.float32, (None,), mod.ones_init()
            )
        elif self.ctx.policy.binarize("conv"):
            kh, kw = self.kernel
            out["wbits"] = mod.ParamSpec(
                (self.c_out, packed_len(self.c_in * kh * kw)),
                jnp.int32, (None, None), mod.zeros_init(),
            )
            out["alpha"] = mod.ParamSpec((1,), jnp.float32, (None,), mod.ones_init())
        else:
            out["w"] = mod.ParamSpec(
                self.wshape, self.ctx.compute_dtype, (None,) * 4, mod.kaiming()
            )
        if self.use_bias:
            out["b"] = mod.ParamSpec(
                (self.c_out,), jnp.float32, (None,), mod.zeros_init()
            )
        return out

    def __call__(self, params: dict, x: jax.Array) -> jax.Array:
        cd = self.ctx.compute_dtype
        if self.ctx.mode == SERVE:
            y = self._serve_apply(params, x)
        else:
            w = params["w"]
            if self.spec is not None:
                w = tiled_weight(w, self.spec, a=params.get("a"), dtype=cd).reshape(
                    self.wshape
                )
            elif self.ctx.policy.binarize("conv"):
                w = bwnn_weight(w, cd)
            else:
                w = w.astype(cd)
            y = self._dense_conv(x.astype(cd), w)
        if self.use_bias:
            y = y + params["b"].astype(y.dtype)
        return y

    def _dense_conv(self, x: jax.Array, w: jax.Array) -> jax.Array:
        return jax.lax.conv_general_dilated(
            x,
            w,
            window_strides=self.stride,
            padding=self.padding,
            dimension_numbers=("NHWC", "OIHW", "NHWC"),
        )

    def _serve_apply(self, params: dict, x: jax.Array) -> jax.Array:
        cd = self.ctx.compute_dtype
        x = x.astype(cd)
        if "tile_conv" in params:
            return tiled_conv_infer(
                x,
                params["tile_conv"],
                params["alpha"],
                self.spec,
                stride=self.stride,
                padding=self.padding,
                use_pallas=self.ctx.use_pallas,
            )
        if "tile" in params:  # unaligned tiling: documented dense fallback
            t = unpack_bits(params["tile"], self.spec.q, dtype=cd)
            w = reconstruct_from_tile(t, params["alpha"], self.spec, dtype=cd)
            return self._dense_conv(x, w)
        if "wbits" in params:
            kh, kw = self.kernel
            w = unpack_bits(params["wbits"], self.c_in * kh * kw, dtype=cd)
            w = (w * params["alpha"].astype(cd)).reshape(self.wshape)
            return self._dense_conv(x, w)
        return self._dense_conv(x, params["w"].astype(cd))

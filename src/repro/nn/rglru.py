"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Training-time recurrence uses jax.lax.associative_scan (log-depth) over
    h_t = a_t ⊙ h_{t-1} + b_t,
decode is the O(1) single-step update (the hybrid arch's long_500k path).

Input/gate projections are TBN-tileable; the per-channel recurrence params
(Lambda, conv) are tiny -> fp32 per the lambda policy.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.context import ModelContext
from repro.nn.linear import Dense

_C = 8.0  # Griffin's fixed exponent scale


def _lru_scan(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t along axis=1 via associative scan."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


@dataclasses.dataclass
class RGLRUBlock:
    d_model: int
    ctx: ModelContext
    d_rnn: int = 0          # defaults to d_model
    conv_width: int = 4
    name: str = "rglru"

    def __post_init__(self):
        c = self.ctx
        self.width = self.d_rnn or self.d_model
        self.in_x = Dense(self.d_model, self.width, c, name=f"{self.name}.in_x",
                          logical=("mlp", "embed"))
        self.in_gate = Dense(self.d_model, self.width, c, name=f"{self.name}.in_gate",
                             logical=("mlp", "embed"))
        self.out = Dense(self.width, self.d_model, c, name=f"{self.name}.out",
                         logical=("embed", "mlp"))
        # gate projections are full FC layers -> TBN-tileable (>= lambda)
        self.w_a = Dense(self.width, self.width, c,
                         name=f"{self.name}.w_a", logical=("mlp", "mlp"))
        self.w_i = Dense(self.width, self.width, c,
                         name=f"{self.name}.w_i", logical=("mlp", "mlp"))

    def specs(self) -> mod.SpecTree:
        f32 = jnp.float32
        w = self.width
        return {
            "in_x": self.in_x.specs(),
            "in_gate": self.in_gate.specs(),
            "out": self.out.specs(),
            "conv_w": mod.ParamSpec((self.conv_width, w), f32, (None, "mlp"),
                                    mod.normal(0.1)),
            "conv_b": mod.ParamSpec((w,), f32, ("mlp",), mod.zeros_init()),
            "lam": mod.ParamSpec((w,), f32, ("mlp",), mod.constant_init(2.2)),
            "w_a": self.w_a.specs(),
            "w_i": self.w_i.specs(),
        }

    def _gates(self, params, xi):
        """Recurrence and input gates (fp32 for stability)."""
        xf = xi.astype(jnp.float32)
        r = jax.nn.sigmoid(self.w_a(params["w_a"], xf).astype(jnp.float32))
        i = jax.nn.sigmoid(self.w_i(params["w_i"], xf).astype(jnp.float32))
        log_a_base = jax.nn.log_sigmoid(params["lam"])       # (w,) < 0
        log_a = _C * r * log_a_base                           # a_t = a^(c r_t)
        a = jnp.exp(log_a)
        b_scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8))
        return a, b_scale * (i * xf)

    def _conv(self, params, x):
        pad = self.conv_width - 1
        xpad = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
        w = params["conv_w"]
        return sum(
            xpad[:, i : i + x.shape[1], :] * w[i][None, None, :]
            for i in range(self.conv_width)
        ) + params["conv_b"]

    def __call__(self, params: dict, u: jax.Array) -> jax.Array:
        cd = self.ctx.compute_dtype
        xi = self._conv(params, self.in_x(params["in_x"], u))
        xi = logical_constraint(xi, "act_batch", "act_seq", "act_mlp")
        a, b = self._gates(params, xi)
        h = _lru_scan(a, b).astype(cd)
        gate = jax.nn.gelu(self.in_gate(params["in_gate"], u))
        y = self.out(params["out"], h * gate)
        return logical_constraint(y, "act_batch", "act_seq", "act_embed")

    # ------------------------------------------------------------------
    def init_state(self, batch: int, dtype=jnp.float32):
        return {
            "h": jnp.zeros((batch, self.width), dtype),
            "conv": jnp.zeros((batch, self.conv_width - 1, self.width), dtype),
        }

    def snapshot_state(self, state: dict, slot, axis: int = 0) -> dict:
        """One slot's (h, conv window) carry as a standalone pytree — what
        the serving prefix trie pins at a page boundary so an identical
        prompt prefix resumes the recurrence without replaying it.
        ``axis`` is the slot axis (1 under a stacked layer scan)."""
        return mod.slice_slot_rows(state, slot, axis)

    def restore_state(self, state: dict, slot, snap: dict,
                      axis: int = 0) -> dict:
        """Write a pinned snapshot into a slot's rows (prefix-hit
        admission): h resumes mid-sequence and the conv window replays
        the boundary's last (w-1) pre-conv inputs."""
        return mod.set_slot_rows(state, slot, snap, axis)

    def extend(self, params: dict, u: jax.Array, state: dict, valid: jax.Array):
        """Chunked-prefill step: u (B, C, d) advances (h, conv window) by
        each row's count of valid columns.

        Projections and gates run over the whole block (m=C matmul path);
        only the h recurrence is scanned, with padding columns leaving the
        carry untouched. The conv at column j reads the slot's stored
        (w-1)-deep tail plus columns <= j, so valid columns (a prefix)
        never see padding input.
        """
        b, c, _ = u.shape
        cd = self.ctx.compute_dtype
        cw = self.conv_width
        xin = self.in_x(params["in_x"], u)                       # (B, C, w)
        xcat = jnp.concatenate([state["conv"], xin], axis=1)     # (B, w-1+C, w)
        xf = xcat.astype(jnp.float32)
        w = params["conv_w"]
        xi = sum(
            xf[:, i : i + c, :] * w[i][None, None, :] for i in range(cw)
        ) + params["conv_b"]
        a, bg = self._gates(params, xi)                          # (B, C, w)

        def step(hs, inp):
            a_t, b_t, v_t = inp
            hs = jnp.where(v_t[:, None], a_t * hs + b_t, hs)
            return hs, hs

        h0 = state["h"].astype(jnp.float32)
        hfin, hs = jax.lax.scan(
            step, h0,
            (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bg, 1, 0),
             jnp.moveaxis(valid, 1, 0)),
        )
        hseq = jnp.moveaxis(hs, 0, 1)                            # (B, C, w)
        gate = jax.nn.gelu(self.in_gate(params["in_gate"], u))
        y = self.out(params["out"], hseq.astype(cd) * gate)
        # new conv tail = the w-1 inputs ending at each row's last valid
        # column: rows [n_new, n_new + w - 2] of xcat (n_new == 0 keeps the
        # stored tail verbatim)
        n_new = jnp.sum(valid, axis=1)
        gi = n_new[:, None] + jnp.arange(cw - 1)[None, :]
        tail = jnp.take_along_axis(xf, gi[:, :, None], axis=1)
        return y, {"h": hfin, "conv": tail.astype(state["conv"].dtype)}

    def decode_step(self, params: dict, u: jax.Array, state: dict):
        """u: (B, 1, d); returns (y (B,1,d), new state)."""
        cd = self.ctx.compute_dtype
        xin = self.in_x(params["in_x"], u)[:, 0]
        win = jnp.concatenate([state["conv"], xin[:, None]], axis=1)
        w = params["conv_w"]
        xi = jnp.einsum("bwd,wd->bd", win.astype(jnp.float32), w) + params["conv_b"]
        a, b = self._gates(params, xi)
        h = a * state["h"] + b
        gate = jax.nn.gelu(self.in_gate(params["in_gate"], u)[:, 0])
        y = self.out(params["out"], (h.astype(cd) * gate)[:, None])
        return y, {"h": h, "conv": win[:, 1:]}

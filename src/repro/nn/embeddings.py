"""Token embeddings + logits head (untiled per paper scope)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn import module as mod
from repro.nn.context import ModelContext


@dataclasses.dataclass
class Embedding:
    vocab: int
    dim: int
    ctx: ModelContext
    name: str = "embed"

    def __post_init__(self):
        self.ctx.note(self.name, (self.vocab, self.dim), kind="embedding", spec=None)

    def specs(self) -> mod.SpecTree:
        return {
            "table": mod.ParamSpec(
                (self.vocab, self.dim),
                self.ctx.param_dtype,
                ("vocab", "embed"),
                mod.normal(0.02),
            )
        }

    def __call__(self, params: dict, ids: jax.Array) -> jax.Array:
        return params["table"].astype(self.ctx.compute_dtype)[ids]

    def attend(self, params: dict, x: jax.Array) -> jax.Array:
        """Tied logits head: x @ table^T."""
        return jnp.einsum(
            "...d,vd->...v", x, params["table"].astype(self.ctx.compute_dtype)
        )

"""Build-time context shared by all layers of a model instance."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.bits import LayerLedger
from repro.core.policy import TBNPolicy, fp32_policy

TRAIN = "train"    # params are full-precision masters (W [, A])
SERVE = "serve"    # params are shipped form (packed tile bits + alpha)


@dataclasses.dataclass
class ModelContext:
    """Quantization policy + dtypes + accounting for one model build."""

    policy: TBNPolicy = dataclasses.field(default_factory=fp32_policy)
    mode: str = TRAIN
    param_dtype: object = jnp.float32
    compute_dtype: object = jnp.bfloat16
    use_pallas: Optional[bool] = None      # None = auto (TPU only)
    compute_path: str = "float"            # serve-mode dense compute:
    # "float" (byte-parity reference) | "int8" | "xnor" — the integer
    # paths quantize activations and accumulate against the packed tile
    # words at decode m (kernels/tiled_xnor.py); per-layer knob read by
    # each tiled Dense at apply time
    fused_train: bool = False              # use the fused construct kernel
    fsdp_weights: bool = False             # gather effective weights at use
    ledger: Optional[LayerLedger] = None

    def __post_init__(self):
        # deferred import: kernels pulls in the Pallas modules, which the
        # context (a build-time dataclass) shouldn't load at import time
        from repro.kernels.tiled_xnor import COMPUTE_PATHS

        if self.compute_path not in COMPUTE_PATHS:
            raise ValueError(
                f"unknown compute_path {self.compute_path!r}: expected "
                f"one of {COMPUTE_PATHS}"
            )
        if self.ledger is None:
            self.ledger = LayerLedger(self.policy)

    def note(self, name, shape, *, kind, spec, macs=0):
        self.ledger.note(name, shape, kind=kind, spec=spec, macs=macs)

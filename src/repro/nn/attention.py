"""Multi-head attention: GQA, RoPE, causal / sliding-window / cross.

Three execution paths share one softmax core:
  * full      — train / short prefill (scores materialized per layer, remat'd)
  * chunked   — long prefill: lax.scan over query chunks bounds the score
                memory to (chunk, T) per step (flash-style; see §Perf for the
                block-triangular FLOP refinement)
  * decode    — single-token step against a KV cache

All four projections are TBN-tileable Dense layers (the paper's central
claim: sub-bit compression of fully-connected transformer weights).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.context import ModelContext
from repro.nn.linear import Dense
from repro.nn.norms import RMSNorm
from repro.nn.rotary import apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (per-token, per-head symmetric scales).
# Exact roundtrip property: requantizing an unchanged row recovers identical
# int8 codes (max |code| is exactly 127), so incremental row updates never
# accumulate error.
# ---------------------------------------------------------------------------
def quantize_kv(x: jax.Array, axis: int = -1):
    """x (..., hd) -> (int8 codes, scale (...,) in f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(dtype) * scale[..., None].astype(dtype))


# ---------------------------------------------------------------------------
# Paged KV-cache translation (serve/kvpool.py holds the host-side pool).
# A paged cache leaf is (n_pages, page_tokens, ...) instead of the dense
# (n_slots, max_len, ...); the int32 page table (n_slots, max_len // pt)
# maps a slot's absolute token positions onto pool pages. Both helpers are
# plain XLA gather/scatter so the jitted serving tick stays one trace —
# shapes depend only on (pool, table) shapes, never on runtime content.
# ---------------------------------------------------------------------------
def gather_pages(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Per-slot contiguous cache view through the page table.

    pool (n_pages, pt, ...) + page_table (B, npp) -> (B, npp * pt, ...),
    where row p of slot b's view is the cache entry for absolute position
    p — exactly the dense layout the attend math expects, so the paged
    and dense paths share every mask and einsum bit-for-bit. Rows beyond
    the slot's frontier read whatever the mapped (or stale) page holds;
    the causal/validity masks already exclude them, identically to the
    dense path's zero-initialized rows."""
    n_pages, pt = pool.shape[:2]
    flat = pool.reshape(n_pages * pt, *pool.shape[2:])
    idx = page_table[:, :, None] * pt + jnp.arange(pt)[None, None, :]
    return flat[idx.reshape(page_table.shape[0], -1)]


def scatter_pages(
    pool: jax.Array,
    page_table: jax.Array,
    positions: jax.Array,      # (B, C) absolute token positions
    values: jax.Array,         # (B, C, ...) rows to write
    valid: jax.Array,          # (B, C) bool; False columns never write
) -> jax.Array:
    """Write cache rows at absolute positions through the page table.

    Invalid columns — padding, inactive slots, and positions past the
    table's reach — scatter to one past the flat pool and are DROPPED
    (the same out-of-bounds idiom the dense extend uses), so a shared
    prefix page can never be written by accident: the engine only maps
    writable positions onto private pages."""
    n_pages, pt = pool.shape[:2]
    npp = page_table.shape[1]
    flat = pool.reshape(n_pages * pt, *pool.shape[2:])
    pidx = positions // pt
    page = jnp.take_along_axis(page_table, jnp.clip(pidx, 0, npp - 1), axis=1)
    ok = valid & (pidx < npp) & (positions >= 0)
    idx = jnp.where(ok, page * pt + positions % pt, n_pages * pt)
    flat = flat.at[idx].set(values.astype(flat.dtype), mode="drop")
    return flat.reshape(pool.shape)


def _attend_core(
    q: jax.Array,          # (B, S, K, G, hd) grouped queries
    k: jax.Array,          # (B, T, K, hd)
    v: jax.Array,          # (B, T, K, hd)
    mask: jax.Array,       # (B, S, T) or (S, T) boolean, True = attend
    scale: float,
) -> jax.Array:
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32) * scale
    if mask.ndim == 2:
        mask_b = mask[None, None, None, :, :]
    else:
        mask_b = mask[:, None, None, :, :]
    scores = jnp.where(mask_b, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out


def make_mask(
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    k_valid: Optional[jax.Array] = None,
) -> jax.Array:
    """(S, T) or (B, S, T) attend-mask from position vectors."""
    m = jnp.ones((*q_pos.shape, k_pos.shape[-1]), bool)
    if causal:
        m &= q_pos[..., :, None] >= k_pos[..., None, :]
    if window is not None:
        m &= q_pos[..., :, None] - k_pos[..., None, :] < window
    if k_valid is not None:
        m &= k_valid[..., None, :]
    return m


@dataclasses.dataclass
class Attention:
    d_model: int
    n_heads: int
    n_kv: int
    ctx: ModelContext
    head_dim: Optional[int] = None
    name: str = "attn"
    causal: bool = True
    window: Optional[int] = None        # sliding-window size (recurrentgemma)
    cross: bool = False                 # encoder-decoder cross attention
    qkv_bias: bool = False              # qwen-style
    qk_norm: bool = False               # chameleon-style
    rope: bool = True
    rope_theta: float = 10_000.0
    q_chunk: int = 1024                 # chunked path query block
    act_mode: str = "heads"             # "heads" | "seq" (see configs.base)

    def __post_init__(self):
        self.hd = self.head_dim or self.d_model // self.n_heads
        assert self.n_heads % self.n_kv == 0
        self.groups = self.n_heads // self.n_kv
        c, d, hd = self.ctx, self.d_model, self.hd
        self.wq = Dense(d, self.n_heads * hd, c, name=f"{self.name}.wq",
                        logical=("heads", "embed"), use_bias=self.qkv_bias)
        self.wk = Dense(d, self.n_kv * hd, c, name=f"{self.name}.wk",
                        logical=("heads", "embed"), use_bias=self.qkv_bias)
        self.wv = Dense(d, self.n_kv * hd, c, name=f"{self.name}.wv",
                        logical=("heads", "embed"), use_bias=self.qkv_bias)
        self.wo = Dense(self.n_heads * hd, d, c, name=f"{self.name}.wo",
                        logical=("embed", "heads"))
        if self.qk_norm:
            self.qnorm = RMSNorm(hd, c, name=f"{self.name}.qnorm")
            self.knorm = RMSNorm(hd, c, name=f"{self.name}.knorm")

    def specs(self) -> mod.SpecTree:
        out = {
            "wq": self.wq.specs(),
            "wk": self.wk.specs(),
            "wv": self.wv.specs(),
            "wo": self.wo.specs(),
        }
        if self.qk_norm:
            out["qnorm"] = self.qnorm.specs()
            out["knorm"] = self.knorm.specs()
        return out

    # ------------------------------------------------------------------
    def _qkv(self, params, x, kv_src, positions, kv_positions):
        b, s, _ = x.shape
        q = self.wq(params["wq"], x).reshape(b, s, self.n_heads, self.hd)
        src = x if kv_src is None else kv_src
        t = src.shape[1]
        k = self.wk(params["wk"], src).reshape(b, t, self.n_kv, self.hd)
        v = self.wv(params["wv"], src).reshape(b, t, self.n_kv, self.hd)
        if self.qk_norm:
            q = self.qnorm(params["qnorm"], q)
            k = self.knorm(params["knorm"], k)
        if self.rope and not self.cross:
            q = apply_rope(q, positions, self.rope_theta)
            k = apply_rope(k, kv_positions, self.rope_theta)
        seq_ax = self._seq_ax()
        q = logical_constraint(q, "act_batch", seq_ax, "act_heads", None)
        k = logical_constraint(k, "act_batch", seq_ax, "act_kv_heads", None)
        v = logical_constraint(v, "act_batch", seq_ax, "act_kv_heads", None)
        return q, k, v

    def _seq_ax(self):
        """Activation layout per the arch's sharding recipe.

        "heads": seq replicated inside the block; head axes shard where
        divisible. "seq": q/k/v sequence-sharded over the model axis
        (flash-row-parallel) — required when head counts do not divide the
        mesh (qwen1.5: 40H, starcoder2: 36H), where head sharding would
        replicate the whole (B, H, S, T) score tensor."""
        return "act_seq" if self.act_mode == "heads" else "act_res_seq"

    def _group(self, q):
        b, s = q.shape[:2]
        return q.reshape(b, s, self.n_kv, self.groups, self.hd)

    def __call__(
        self,
        params: dict,
        x: jax.Array,                       # (B, S, d)
        *,
        positions: Optional[jax.Array] = None,
        kv_src: Optional[jax.Array] = None, # cross-attention memory
        kv_valid: Optional[jax.Array] = None,
        chunked: Optional[bool] = None,
    ) -> jax.Array:
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        t = s if kv_src is None else kv_src.shape[1]
        kv_positions = positions if kv_src is None else jnp.broadcast_to(jnp.arange(t), (b, t))
        q, k, v = self._qkv(params, x, kv_src, positions, kv_positions)
        scale = 1.0 / math.sqrt(self.hd)
        if chunked is None:
            chunked = s >= 4 * self.q_chunk
        causal = self.causal and not self.cross
        if not chunked:
            mask = make_mask(
                positions, kv_positions, causal=causal,
                window=self.window, k_valid=kv_valid,
            )
            out = _attend_core(self._group(q), k, v, mask, scale)
        else:
            out = self._chunked(q, k, v, positions, kv_positions, kv_valid, scale)
        out = out.reshape(b, s, self.n_heads * self.hd)
        y = self.wo(params["wo"], out)
        return logical_constraint(y, "act_batch", "act_seq", "act_embed")

    def _chunked(self, q, k, v, q_pos, k_pos, k_valid, scale):
        """lax.scan over query chunks; score memory = (chunk, T) per step."""
        b, s = q.shape[:2]
        c = min(self.q_chunk, s)
        while s % c:
            c -= 1
        n = s // c
        qg = self._group(q).reshape(b, n, c, self.n_kv, self.groups, self.hd)
        qg = jnp.moveaxis(qg, 1, 0)                    # (n, B, c, K, G, hd)
        qp = jnp.moveaxis(q_pos.reshape(b, n, c), 1, 0)

        def step(_, inp):
            qi, qpi = inp
            mask = make_mask(qpi, k_pos, causal=self.causal and not self.cross,
                             window=self.window, k_valid=k_valid)
            return None, _attend_core(qi, k, v, mask, scale)

        # Remat each chunk: without this the scan stacks every chunk's f32
        # score matrix ((n, B, K, G, c, T) — the full S x T scores!) as
        # backward residuals, defeating the point of chunking. With it the
        # backward recomputes one chunk's scores at a time (flash-style).
        step = jax.checkpoint(step)
        _, outs = jax.lax.scan(step, None, (qg, qp))
        return jnp.moveaxis(outs, 0, 1).reshape(b, s, self.n_kv, self.groups, self.hd)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def prefill(self, params, x, positions=None):
        """Forward + return the KV cache content (B, S, K, hd)."""
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q, k, v = self._qkv(params, x, None, positions, positions)
        scale = 1.0 / math.sqrt(self.hd)
        chunked = s >= 4 * self.q_chunk
        if chunked:
            out = self._chunked(q, k, v, positions, positions, None, scale)
        else:
            mask = make_mask(positions, positions, causal=True, window=self.window)
            out = _attend_core(self._group(q), k, v, mask, scale)
        y = self.wo(params["wo"], out.reshape(b, s, self.n_heads * self.hd))
        return y, (k, v)

    def decode_step(
        self,
        params: dict,
        x: jax.Array,              # (B, 1, d)
        cache_k: jax.Array,        # (B, T, K, hd) dense | (P, pt, K, hd) paged
        cache_v: jax.Array,
        lengths: jax.Array,        # (B,) tokens already in cache
        page_table: Optional[jax.Array] = None,   # (B, npp) -> paged layout
        active: Optional[jax.Array] = None,       # (B,) paged write mask
    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """One-token step. With ``page_table`` the caches are pool form:
        the new K/V row scatters through the table (inactive slots drop
        their write — the paged pool cannot be un-written by a post-hoc
        per-slot merge the way dense slot rows can) and the attend runs
        over the gathered per-slot view, which is laid out exactly like
        the dense cache so masks and math are unchanged."""
        b = x.shape[0]
        positions = lengths[:, None]                    # new token position
        q, k, v = self._qkv(params, x, None, positions, positions)
        if page_table is None:
            idx = jnp.arange(b)
            cache_k = cache_k.at[idx, lengths].set(k[:, 0])
            cache_v = cache_v.at[idx, lengths].set(v[:, 0])
            view_k, view_v = cache_k, cache_v
        else:
            ok = jnp.ones((b,), bool) if active is None else active
            cache_k = scatter_pages(cache_k, page_table, positions, k,
                                    ok[:, None])
            cache_v = scatter_pages(cache_v, page_table, positions, v,
                                    ok[:, None])
            view_k = gather_pages(cache_k, page_table)
            view_v = gather_pages(cache_v, page_table)
        t = view_k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(t), (b, t))
        mask = make_mask(
            positions, k_pos, causal=True, window=self.window,
            k_valid=k_pos <= lengths[:, None],
        )
        scale = 1.0 / math.sqrt(self.hd)
        out = _attend_core(self._group(q), view_k, view_v, mask, scale)
        y = self.wo(params["wo"], out.reshape(b, 1, self.n_heads * self.hd))
        return y, cache_k, cache_v

    def extend(
        self,
        params: dict,
        x: jax.Array,              # (B, C, d)
        cache_k: jax.Array,        # (B, T, K, hd)
        cache_v: jax.Array,
        positions: jax.Array,      # (B, C) absolute position per column
        valid: jax.Array,          # (B, C) bool, False = padding column
        page_table: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Chunked-prefill step: advance each row by its valid columns.

        Column j of row b carries the token at absolute position
        positions[b, j]; padding columns (valid False) scatter to an
        out-of-bounds row index and are DROPPED, so the cache is only ever
        written at true token offsets. Queries attend causally over the
        just-updated cache — every key at position <= the query's position
        has been written (by an earlier tick or this scatter), and the
        causal mask excludes everything later, so stale rows beyond the
        frontier are never read by a valid column. With ``page_table`` the
        caches are pool form and positions translate through the table;
        on a prefix hit the engine starts `positions` at the page-aligned
        boundary, so shared pages (all < boundary) are read, never hit by
        this scatter.
        """
        b, c, _ = x.shape
        q, k, v = self._qkv(params, x, None, positions, positions)
        if page_table is None:
            t = cache_k.shape[1]
            bidx = jnp.arange(b)[:, None]
            widx = jnp.where(valid, positions, t)    # t == out of bounds
            cache_k = cache_k.at[bidx, widx].set(
                k.astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[bidx, widx].set(
                v.astype(cache_v.dtype), mode="drop")
            view_k, view_v = cache_k, cache_v
        else:
            cache_k = scatter_pages(cache_k, page_table, positions, k, valid)
            cache_v = scatter_pages(cache_v, page_table, positions, v, valid)
            view_k = gather_pages(cache_k, page_table)
            view_v = gather_pages(cache_v, page_table)
        t = view_k.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(t), (b, t))
        mask = make_mask(positions, k_pos, causal=True, window=self.window)
        scale = 1.0 / math.sqrt(self.hd)
        out = _attend_core(self._group(q), view_k, view_v, mask, scale)
        y = self.wo(params["wo"], out.reshape(b, c, self.n_heads * self.hd))
        return y, cache_k, cache_v

    # -------- cross-attention (encoder-decoder) serving helpers --------
    def cross_kv(self, params, memory):
        """Project encoder memory to cross-attention K/V rows.

        memory (B, T, d) -> (k, v) each (B, T, K, hd). Computed ONCE per
        request in the engine's ENCODE phase, scattered into the
        cross-attention pool, and read-only ever after — decode/extend
        never re-project. No RoPE (cross attention is position-free, as
        in the dense ``__call__`` path where ``self.cross`` skips it)."""
        b, t, _ = memory.shape
        k = self.wk(params["wk"], memory).reshape(b, t, self.n_kv, self.hd)
        v = self.wv(params["wv"], memory).reshape(b, t, self.n_kv, self.hd)
        if self.qk_norm:
            k = self.knorm(params["knorm"], k)
        return k, v

    def cross_attend(self, params, x, cache_k, cache_v, kv_lens,
                     page_table=None):
        """Read-only cross attention over precomputed memory K/V.

        x (B, S, d) queries attend every VALID memory row (row t of slot
        b is valid iff ``t < kv_lens[b]``); no causal mask, no cache
        write. With ``page_table`` the caches are pool form and the
        attend runs over the gathered per-slot view — rows past
        ``kv_lens`` (padding inside the last page, stale pool content)
        are masked to exact zeros by the softmax's NEG_INF underflow,
        so the paged result is byte-identical to attending the dense
        unpadded memory."""
        b, s, _ = x.shape
        q = self.wq(params["wq"], x).reshape(b, s, self.n_heads, self.hd)
        if self.qk_norm:
            q = self.qnorm(params["qnorm"], q)
        if page_table is None:
            view_k, view_v = cache_k, cache_v
        else:
            view_k = gather_pages(cache_k, page_table)
            view_v = gather_pages(cache_v, page_table)
        t = view_k.shape[1]
        mask = jnp.broadcast_to(
            (jnp.arange(t)[None, :] < kv_lens[:, None])[:, None, :],
            (b, s, t),
        )
        out = _attend_core(self._group(q), view_k, view_v, mask,
                           1.0 / math.sqrt(self.hd))
        return self.wo(params["wo"],
                       out.reshape(b, s, self.n_heads * self.hd))

    def extend_quant(
        self,
        params: dict,
        x: jax.Array,              # (B, C, d)
        cache: dict,               # {"k","v" int8, "ks","vs" f32}
        positions: jax.Array,      # (B, C)
        valid: jax.Array,          # (B, C)
        page_table: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, dict]:
        """Chunked-prefill step against the int8 KV cache: quantize the new
        rows (per-token, per-head scales — the same per-row quantization a
        monolithic prefill would apply), drop padding-column writes, attend
        through the scale-factored path (no dequantized cache tensor). The
        codes AND scales page together (one table drives all four pools),
        so a shared int8 prefix replays bit-identical codes."""
        b, c, _ = x.shape
        q, k, v = self._qkv(params, x, None, positions, positions)
        kq, ks = quantize_kv(k)                # (B, C, K, hd) int8, (B, C, K)
        vq, vs = quantize_kv(v)
        if page_table is None:
            t = cache["k"].shape[1]
            bidx = jnp.arange(b)[:, None]
            widx = jnp.where(valid, positions, t)
            cache = {
                "k": cache["k"].at[bidx, widx].set(kq, mode="drop"),
                "v": cache["v"].at[bidx, widx].set(vq, mode="drop"),
                "ks": cache["ks"].at[bidx, widx].set(ks, mode="drop"),
                "vs": cache["vs"].at[bidx, widx].set(vs, mode="drop"),
            }
            vk, vv, vks, vvs = (cache["k"], cache["v"],
                                cache["ks"], cache["vs"])
        else:
            cache = {
                "k": scatter_pages(cache["k"], page_table, positions, kq, valid),
                "v": scatter_pages(cache["v"], page_table, positions, vq, valid),
                "ks": scatter_pages(cache["ks"], page_table, positions, ks, valid),
                "vs": scatter_pages(cache["vs"], page_table, positions, vs, valid),
            }
            vk = gather_pages(cache["k"], page_table)
            vv = gather_pages(cache["v"], page_table)
            vks = gather_pages(cache["ks"], page_table)
            vvs = gather_pages(cache["vs"], page_table)
        cd = v.dtype
        t = vk.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(t), (b, t))
        mask = make_mask(positions, k_pos, causal=True, window=self.window)
        qg = self._group(q)                           # (B, C, K, G, hd)
        scores = jnp.einsum(
            "bskgh,btkh->bkgst", qg, vk.astype(cd)
        ).astype(jnp.float32)
        scores = scores * vks.transpose(0, 2, 1)[:, :, None, None, :]
        scores = scores * (1.0 / math.sqrt(self.hd))
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(cd)
        pv = probs * vvs.transpose(0, 2, 1)[:, :, None, None, :].astype(cd)
        out = jnp.einsum("bkgst,btkh->bskgh", pv, vv.astype(cd))
        y = self.wo(params["wo"], out.reshape(b, c, self.n_heads * self.hd))
        return y, cache

    def decode_step_quant(
        self,
        params: dict,
        x: jax.Array,              # (B, 1, d)
        cache: dict,               # {"k","v" int8, "ks","vs" f32}
        lengths: jax.Array,
        page_table: Optional[jax.Array] = None,
        active: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, dict]:
        """Decode against an int8-quantized KV cache: quantize only the new
        token's row, dequantize per layer as a transient for the attend."""
        b = x.shape[0]
        positions = lengths[:, None]
        q, k, v = self._qkv(params, x, None, positions, positions)
        kq, ks = quantize_kv(k[:, 0])          # (B, K, hd) int8, (B, K)
        vq, vs = quantize_kv(v[:, 0])
        if page_table is None:
            idx = jnp.arange(b)
            cache = {
                "k": cache["k"].at[idx, lengths].set(kq),
                "v": cache["v"].at[idx, lengths].set(vq),
                "ks": cache["ks"].at[idx, lengths].set(ks),
                "vs": cache["vs"].at[idx, lengths].set(vs),
            }
            vk, vv, vks, vvs = (cache["k"], cache["v"],
                                cache["ks"], cache["vs"])
        else:
            ok = (jnp.ones((b,), bool) if active is None else active)[:, None]
            cache = {
                "k": scatter_pages(cache["k"], page_table, positions,
                                   kq[:, None], ok),
                "v": scatter_pages(cache["v"], page_table, positions,
                                   vq[:, None], ok),
                "ks": scatter_pages(cache["ks"], page_table, positions,
                                    ks[:, None], ok),
                "vs": scatter_pages(cache["vs"], page_table, positions,
                                    vs[:, None], ok),
            }
            vk = gather_pages(cache["k"], page_table)
            vv = gather_pages(cache["v"], page_table)
            vks = gather_pages(cache["ks"], page_table)
            vvs = gather_pages(cache["vs"], page_table)
        cd = v.dtype
        t = vk.shape[1]
        k_pos = jnp.broadcast_to(jnp.arange(t), (b, t))
        mask = make_mask(
            positions, k_pos, causal=True, window=self.window,
            k_valid=k_pos <= lengths[:, None],
        )
        # Scale-factored attention (§Perf iteration): the per-row scales
        # are rank-1 along hd, so they FACTOR OUT of both dots —
        #   scores = (q . k_q) * ks      out = (probs * vs) . v_q
        # No (B, T, K, hd) dequantized cache is ever materialized; the
        # scale multiplies live on the (B, K, G, 1, T)-sized tensors.
        qg = self._group(q)                           # (B, 1, K, G, hd)
        scores = jnp.einsum(
            "bskgh,btkh->bkgst", qg, vk.astype(cd)
        ).astype(jnp.float32)
        scores = scores * vks.transpose(0, 2, 1)[:, :, None, None, :]
        scores = scores * (1.0 / math.sqrt(self.hd))
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(cd)
        pv = probs * vvs.transpose(0, 2, 1)[:, :, None, None, :].astype(cd)
        out = jnp.einsum("bkgst,btkh->bskgh", pv, vv.astype(cd))
        y = self.wo(params["wo"], out.reshape(b, 1, self.n_heads * self.hd))
        return y, cache

"""Feed-forward blocks (GELU MLP and SwiGLU) — all TBN-tileable."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.context import ModelContext
from repro.nn.linear import Dense


@dataclasses.dataclass
class MLP:
    """up -> act -> down; gated (SwiGLU) when ``gated=True``."""

    d_model: int
    d_ff: int
    ctx: ModelContext
    name: str = "mlp"
    gated: bool = True
    activation: str = "silu"   # silu | gelu | relu

    def __post_init__(self):
        c = self.ctx
        self.up = Dense(self.d_model, self.d_ff, c, name=f"{self.name}.up",
                        logical=("mlp", "embed"))
        if self.gated:
            self.gate = Dense(self.d_model, self.d_ff, c, name=f"{self.name}.gate",
                              logical=("mlp", "embed"))
        self.down = Dense(self.d_ff, self.d_model, c, name=f"{self.name}.down",
                          logical=("embed", "mlp"))

    def specs(self) -> mod.SpecTree:
        out = {"up": self.up.specs(), "down": self.down.specs()}
        if self.gated:
            out["gate"] = self.gate.specs()
        return out

    def _act(self, x):
        return dict(silu=jax.nn.silu, gelu=jax.nn.gelu, relu=jax.nn.relu,
             relu2=lambda v: jnp.square(jax.nn.relu(v)))[
            self.activation
        ](x)

    def __call__(
        self, params: dict, x: jax.Array,
        act=("act_batch", "act_seq"),
    ) -> jax.Array:
        """``act`` names the leading two activation axes — the MoE shared
        expert runs this in the (group, token, d) layout with
        act=("act_tok", None) so the hidden keeps the full-mesh token
        sharding instead of being forced back to (batch, seq)."""
        h = self.up(params["up"], x)
        h = logical_constraint(h, *act, "act_mlp")
        if self.gated:
            h = self._act(self.gate(params["gate"], x)) * h
        else:
            h = self._act(h)
        y = self.down(params["down"], h)
        return logical_constraint(y, *act, "act_embed")

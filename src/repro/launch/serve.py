"""Batched serving driver with packed-tile weights and chunked prefill.

    python -m repro.launch.serve --arch granite-8b --reduced \\
        --requests 8 --max-tokens 16 --chunk-tokens 32

Or boot the async HTTP/SSE front-end instead of draining a synthetic
batch (``POST /generate``, ``GET /stats``, ``GET /healthz``; Ctrl-C to
stop):

    python -m repro.launch.serve --arch granite-8b --reduced \\
        --serve --port 8000

The driver dispatches on the config's model family (see ``--help`` for
the matrix): decoder-only families (dense / moe / ssm / hybrid / vlm)
drive token prompts; the encdec family additionally feeds each request a
synthetic source-frame clip and exercises the ENCODE phase + encoder
reuse (``--enc-sources`` distinct clips cycled over the batch):

    python -m repro.launch.serve --arch seamless-m4t-large-v2 --reduced \\
        --requests 6 --enc-tokens 16 --enc-sources 2

``--aot`` (default on in ``--serve`` mode) AOT-compiles the decode and
extend tick executables at startup so the FIRST request pays no
trace/compile inside its TTFT; ``--no-aot`` measures the difference.

``--priorities`` turns on class-aware admission (interactive > batch,
prefix-aware queue jumping with an aging floor) and ``--preempt``
additionally lets a waiting interactive request park a decoding batch
slot and resume it later byte-exactly (DESIGN.md §6.4); requests choose
a class with the HTTP body's ``"priority"`` field.

Tensor-parallel serving shards each layer's packed tile rows over the
model mesh axis (DESIGN.md §5):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    python -m repro.launch.serve --arch granite-8b --reduced --mesh 1x4

(`--mesh DPxTP`; on a real TPU slice the devices are the chips and the
XLA_FLAGS trick is unnecessary — it only fakes a multi-device host for
local testing.)

Flow: init TRAIN masters (or restore a checkpoint), export the SERVE
representation (packed tile bits + alpha scalars — repro.serve.weights),
stand up the slot-based BatchedEngine (mesh-placed when --mesh is given)
and drain a batch of synthetic prompts, timing every engine tick. Prints
the compression of the shipped weights vs the masters, the per-device
resident tile bytes, engine throughput, and a TTFT / inter-token-latency
report — the tail-latency numbers the chunked-prefill scheduler exists
to protect (`--chunk-tokens` bounds how much prompt work any one tick
carries beside the live decodes).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import build_model, get_config
from repro.ft.checkpoint import latest_step, restore_into
from repro.launch.mesh import parse_mesh_arg
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.serve.engine import BatchedEngine, ServeConfig
from repro.serve.sampling import SamplingParams
from repro.serve.servable import SERVABLE_FAMILIES, UnservableModelError
from repro.serve.weights import (
    export_serving_params,
    per_device_tile_bytes,
    serving_bytes,
    tile_serving_bytes,
)


def latency_report(reqs, tick_ends):
    """Per-request TTFT and inter-token latencies from the engine's
    token_steps tick indices + the driver's per-tick wall clock.

    tick_ends[i] is the cumulative wall time at the end of tick i; a
    token emitted at tick t therefore landed by tick_ends[t]."""
    ttfts, itls = [], []
    for r in reqs:
        if not r.token_steps:
            continue
        ttfts.append(tick_ends[r.token_steps[0]])
        for a, b in zip(r.token_steps, r.token_steps[1:]):
            itls.append(tick_ends[b] - tick_ends[a])
    return ttfts, itls


def main(argv=None):
    family_matrix = "servable model families:\n" + "\n".join(
        f"  {k:<8}{v}" for k, v in SERVABLE_FAMILIES.items()
    )
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=family_matrix,
    )
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore TRAIN masters before exporting")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="prefill chunk width == per-tick token budget "
                         "(clamped to --max-len)")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="attention KV pool page size (must divide "
                         "--max-len)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="KV pool capacity in pages (default: the "
                         "dense-equivalent slots * max_len / page_tokens)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="radix-trie shared-prefix reuse across admissions "
                         "(--no-prefix-cache disables)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common tokens to every prompt "
                         "(a synthetic system prompt — makes the prefix "
                         "cache line non-trivial)")
    ap.add_argument("--enc-tokens", type=int, default=None,
                    help="encoder capacity in source frames (encdec "
                         "family only; default --max-len)")
    ap.add_argument("--enc-sources", type=int, default=2,
                    help="distinct synthetic source clips cycled over "
                         "the batch (encdec family; >1 exercises "
                         "encoder-output reuse)")
    ap.add_argument("--compute-path", default="float",
                    choices=["float", "int8", "xnor"],
                    help="dense serve compute: float (byte-parity "
                         "reference), int8 (quantized activations, "
                         "integer MACs) or xnor (sign-binarized "
                         "activations, XNOR+popcount on the packed tile "
                         "words) — the integer paths apply to decode "
                         "ticks; outputs are approximate vs float")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=None,
                    help="engine-default top-k (per-request params override)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="1x1",
                    help="DPxTP serving mesh, e.g. 1x4 (default single device)")
    ap.add_argument("--serve", action="store_true",
                    help="boot the async HTTP/SSE front-end instead of "
                         "draining a synthetic batch")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="HTTP port (0 = OS-assigned, printed at startup)")
    ap.add_argument("--max-queued", type=int, default=64,
                    help="admission-queue capacity; a full queue returns "
                         "HTTP 429 (--serve mode)")
    ap.add_argument("--priorities", action="store_true",
                    help="class-aware admission (interactive > batch, "
                         "prefix-aware queue jumping, aging floor) instead "
                         "of FIFO; requests pick a class via the "
                         "'priority' field / SamplingParams.priority")
    ap.add_argument("--preempt", action="store_true",
                    help="preempt-and-resume: a waiting interactive "
                         "request may park a decoding batch slot "
                         "(snapshot + retained pages, restored "
                         "byte-exactly); implies --priorities")
    ap.add_argument("--default-priority", default="batch",
                    help="class for requests that don't set one "
                         "(interactive | batch)")
    ap.add_argument("--aot", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="AOT-compile the tick executables at startup "
                         "(default: on with --serve, off otherwise)")
    ap.add_argument("--telemetry", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serving telemetry: metric registry, request "
                         "spans, tick phase timing, retrace detector "
                         "(--no-telemetry for overhead-sensitive runs; "
                         "tokens are byte-identical either way)")
    ap.add_argument("--stats-interval", type=float, default=0.0,
                    help="print a one-line telemetry report every S "
                         "seconds (--serve mode; 0 disables)")
    ap.add_argument("--trace-log", default=None, metavar="FILE",
                    help="drain the structured trace-event ring "
                         "(submit/admit/preempt/resume/finish/retrace) "
                         "to FILE as JSON lines at shutdown")
    ap.add_argument("--trace-events", type=int, default=4096,
                    help="trace-event ring capacity (with --trace-log; "
                         "oldest events drop past it)")
    args = ap.parse_args(argv)
    if args.stats_interval < 0:
        raise SystemExit(
            f"--stats-interval must be >= 0: {args.stats_interval}")
    if args.trace_log and not args.telemetry:
        raise SystemExit("--trace-log requires --telemetry (the ring is "
                         "fed from the telemetry call sites)")
    mesh = parse_mesh_arg(args.mesh)
    if args.shared_prefix + 12 > args.max_len:
        # 12 = the max random tail length below; fail before minutes of
        # model build/compile, not at the first submit()
        raise SystemExit(
            f"--shared-prefix {args.shared_prefix} + tail (<=12) exceeds "
            f"--max-len {args.max_len}"
        )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # family dispatch: every SERVABLE_FAMILIES key rides the same
    # BatchedEngine; anything else fails with the menu attached
    family = getattr(cfg, "family", "dense")
    if family not in SERVABLE_FAMILIES:
        raise UnservableModelError(f"config family {family!r}")
    encdec = family == "encdec"
    if encdec and args.serve:
        raise SystemExit(
            "--serve (HTTP front-end) carries token prompts only; the "
            "encdec family needs per-request source frames — drive it "
            "with the synthetic batch (drop --serve)"
        )
    if encdec and args.enc_sources < 1:
        raise SystemExit(f"--enc-sources must be >= 1: {args.enc_sources}")

    t_model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN))
    s_model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=SERVE,
                                            use_pallas=False,
                                            compute_path=args.compute_path))
    if args.compute_path != "float":
        print(f"compute path: {args.compute_path} (decode ticks quantize "
              f"activations and accumulate on the packed tile words; "
              f"outputs are approximate vs --compute-path float)")
    params = mod.init_params(t_model.specs(), jax.random.PRNGKey(args.seed))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        step, restored = restore_into(params, args.ckpt_dir)
        params = restored
        print(f"restored masters at step {step}")

    sp = export_serving_params(
        t_model.specs(), s_model.specs(), params, cfg.tbn
    )
    master_b = serving_bytes(params)
    ship_b = serving_bytes(sp)
    print(f"arch={cfg.name} TBN p={cfg.tbn.p}: masters {master_b/1e6:.2f}MB "
          f"-> shipped {ship_b/1e6:.2f}MB ({master_b/ship_b:.1f}x smaller)")

    aot = args.aot if args.aot is not None else args.serve
    eng = BatchedEngine(
        s_model, sp,
        ServeConfig(n_slots=args.slots, max_len=args.max_len,
                    chunk_tokens=min(args.chunk_tokens, args.max_len),
                    temperature=args.temperature,
                    top_k=args.top_k, seed=args.seed,
                    page_tokens=args.page_tokens,
                    pool_pages=args.pool_pages,
                    prefix_cache=args.prefix_cache,
                    enc_tokens=(args.enc_tokens if encdec else None),
                    max_queued=args.max_queued if args.serve else None,
                    priorities=args.priorities or args.preempt,
                    preempt=args.preempt,
                    default_priority=args.default_priority,
                    compute_path=args.compute_path,
                    telemetry=args.telemetry,
                    trace_events=(args.trace_events if args.trace_log
                                  else 0)),
        mesh=mesh,
    )

    def _flush_trace_log():
        if args.trace_log and eng.tel is not None and eng.tel.ring:
            n = eng.tel.ring.write_jsonl(args.trace_log)
            dropped = eng.tel.ring.dropped
            print(f"trace log: {n} events -> {args.trace_log}"
                  + (f" ({dropped} older events dropped by the "
                     f"{eng.cfg.trace_events}-event ring)" if dropped
                     else ""))

    if args.serve:
        import asyncio

        from repro.serve.server import ServerConfig, run_server

        def _ready(_srv, port):
            # the readiness line subprocess harnesses wait for
            print(f"serving on http://{args.host}:{port} "
                  f"(aot={'on' if aot else 'off'})", flush=True)

        try:
            asyncio.run(run_server(
                eng, ServerConfig(host=args.host, port=args.port),
                aot=aot, ready=_ready,
                stats_interval=args.stats_interval))
        except KeyboardInterrupt:
            pass
        _flush_trace_log()
        print("server closed")
        return []
    if aot:
        t = eng.warmup()
        print(f"AOT warmup: {', '.join(f'{k} {v:.2f}s' for k, v in t.items())}")
    if mesh is not None:
        total_tile = tile_serving_bytes(sp)
        per_dev = per_device_tile_bytes(eng.params)
        worst = max(per_dev.values()) if per_dev else 0
        print(f"mesh={dict(mesh.shape)}: tile bits {total_tile/1e6:.3f}MB total, "
              f"{worst/1e6:.3f}MB max/device "
              f"({total_tile/max(worst, 1):.1f}x sharding)")
    rng = np.random.default_rng(args.seed)
    shared = rng.integers(0, cfg.vocab, size=args.shared_prefix)
    sources = None
    if encdec:
        # a small set of distinct source clips cycled over the batch:
        # every repeat admission past the first is an encoder-reuse hit
        cap = eng.enc_tokens
        sources = [
            rng.standard_normal(
                (int(rng.integers(max(1, cap // 2), cap + 1)), cfg.d_model)
            ).astype(np.float32)
            for _ in range(args.enc_sources)
        ]
    reqs = [
        eng.submit(
            np.concatenate([
                shared, rng.integers(0, cfg.vocab, size=rng.integers(3, 12))
            ]).astype(np.int32),
            SamplingParams(
                max_tokens=args.max_tokens,
                # under --priorities make the synthetic batch exercise the
                # scheduler: every 4th request is interactive
                priority=("interactive" if eng.cfg.priorities and i % 4 == 3
                          else None)),
            frames=(sources[i % len(sources)] if encdec else None))
        for i in range(args.requests)
    ]
    t0 = time.time()
    tick_ends = []
    ticks = eng.run_until_drained(
        on_tick=lambda _: tick_ends.append(time.time() - t0)
    )
    dt = tick_ends[-1] if tick_ends else 0.0
    tok = sum(len(r.output) for r in reqs)
    # a ~0s drain (tiny reduced config, everything cached) must not
    # divide-by-zero the throughput line
    rate = f"{tok / dt:.1f} tok/s on CPU" if dt > 1e-9 else "instant drain"
    print(f"{len(reqs)} requests, {tok} tokens in {ticks} engine ticks, "
          f"{dt:.2f}s ({rate})")
    ttfts, itls = latency_report(reqs, tick_ends)
    if ttfts:
        line = (f"TTFT mean {1e3 * np.mean(ttfts):.1f}ms "
                f"max {1e3 * np.max(ttfts):.1f}ms")
        if itls:
            line += (f" | ITL mean {1e3 * np.mean(itls):.1f}ms "
                     f"max {1e3 * np.max(itls):.1f}ms")
        print(f"latency (chunk={eng.cfg.chunk_tokens}): {line}")
    st = eng.stats()
    if encdec:
        fam = st["cache_families"]
        pools = ", ".join(
            f"{name} {f['in_use']}/{f['pages']} pages "
            f"({100 * f['utilization']:.0f}%)"
            for name, f in fam.items()
        )
        print(f"encode phase: {st['encode_ticks']} encode ticks, "
              f"{st['enc_cache_hits']}/{st['admitted']} admissions reused "
              f"a cached encoder output "
              f"({st['enc_cache_entries']} cached sources); {pools}")
    elif eng.cfg.prefix_cache:
        line = (f"hit rate {100 * st['hit_rate']:.0f}% "
                f"({st['prefix_hits']}/{st['admitted']} admissions), "
                f"{st['prefill_tokens_skipped']}/{st['prompt_tokens']} "
                f"prefill tokens skipped")
        if "pool_pages" in st:
            line += (f", pool {st['pages_in_use']}/{st['pool_pages']} pages "
                     f"({100 * st['page_utilization']:.0f}%)")
        print(f"prefix cache (page={eng.cfg.page_tokens}): {line}")
    else:
        print("prefix cache: disabled (--prefix-cache to enable)")
    if eng.cfg.priorities:
        per_cls = ", ".join(
            f"{cls} {t} ticks (n={st['class_counts'][cls]})"
            for cls, t in st["class_ttft_ticks"].items()
        )
        print(f"scheduler ({'priority+preempt' if eng.cfg.preempt else 'priority'}): "
              f"{st['preempts']} preempts / {st['resumes']} resumes, "
              f"{st['preempted_tokens']} context tokens parked, "
              f"preempt-free tick rate {st['preempt_free_tick_rate']:.2f}; "
              f"TTFT {per_cls or 'n/a'}")
    if "latency" in st:
        lat = st["latency"]
        print("telemetry: "
              + " ".join(f"{k} p50={v['p50']}ms p99={v['p99']}ms"
                         for k, v in lat.items()
                         if v["count"] and k in ("ttft_ms", "itl_ms",
                                                 "tick_ms"))
              + (f" | retraces={st['retraces']}" if st.get("retraces")
                 else ""))
    _flush_trace_log()
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.output}")
    return reqs


if __name__ == "__main__":
    main()

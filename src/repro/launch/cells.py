"""Build (function, abstract args, shardings) for every dry-run cell.

A cell = (architecture x input shape x mesh). Three kinds:
  train   — jit(train_step)   : (TrainState, batch) -> (TrainState, metrics)
  prefill — jit(prefill_fn)   : (params, batch)     -> (logits, caches, lengths)
  decode  — jit(decode_fn)    : (params, tokens, caches, lengths) -> (...)

Cost-model notes (see EXPERIMENTS.md §Roofline): XLA's cost_analysis visits
each while-loop body ONCE, so scan-over-layers FLOPs must be corrected by
trip count. Cells can therefore be built with a `depth` override and with
chunked attention disabled (`exact_flops=True`) — the roofline driver
compiles {full+chunked, d1+exact, d2+exact} and extrapolates:
    total = cost(d1) + (trips_full - 1) * (cost(d2) - cost(d1)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import build_model, get_config
from repro.configs.base import ArchConfig, SHAPES, ShapeCell, cell_applicable
from repro.distributed.sharding import DEFAULT_RULES, param_shardings
from repro.nn import module as mod
from repro.nn.context import SERVE, TRAIN, ModelContext
from repro.optim import adamw, cosine_with_warmup
from repro.train.step import TrainState, build_train_step


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------
def arch_rules(cfg: ArchConfig) -> Dict[str, Any]:
    return dict(DEFAULT_RULES, **dict(cfg.rules_override))


def batch_axes(mesh: Mesh, cfg: Optional[ArchConfig] = None) -> Tuple[str, ...]:
    want = ("pod", "data")
    if cfg is not None:
        v = arch_rules(cfg).get("act_batch") or ()
        want = (v,) if isinstance(v, str) else tuple(v)
    return tuple(a for a in want if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def fit_spec(mesh: Mesh, shape, *axes) -> NamedSharding:
    """PartitionSpec that degrades each axis (tuple: longest dividing
    prefix) and drops what cannot divide (e.g. batch=1)."""
    spec = []
    for dim, ax in zip(shape, axes):
        chosen = None
        if ax is not None:
            parts = (ax,) if isinstance(ax, str) else tuple(ax)
            for k in range(len(parts), 0, -1):
                if dim % _axis_size(mesh, parts[:k]) == 0:
                    chosen = parts[:k] if k > 1 else parts[0]
                    break
        spec.append(chosen)
    return NamedSharding(mesh, P(*spec))


def depth_cfg(cfg: ArchConfig, depth: Optional[int]) -> ArchConfig:
    """Reduce depth (keeping per-layer dims exact) for cost extrapolation.

    Depth variants are UNROLLED (no lax.scan): XLA's cost_analysis visits a
    while body once regardless of trip count, so scanned depth-1 and
    depth-2 modules would report identical costs and the per-layer delta
    would vanish (verified empirically — see EXPERIMENTS.md §Roofline).
    """
    if depth is None:
        return cfg
    kw: Dict[str, Any] = {"n_layers": depth, "force_unroll": True}
    if cfg.family == "encdec":
        kw.update(enc_layers=depth, dec_layers=depth, n_layers=2 * depth)
    if cfg.family == "moe" and cfg.moe.first_dense:
        kw["n_layers"] = depth + 1     # dense0 + `depth` scanned MoE layers
    if cfg.family == "hybrid":
        kw["n_layers"] = depth * len(cfg.pattern)  # whole super-blocks
    return dataclasses.replace(cfg, **kw)


def scan_trips(cfg: ArchConfig) -> int:
    """Iterations of the (dominant) layer scan at full depth."""
    if cfg.family == "encdec":
        assert cfg.enc_layers == cfg.dec_layers
        return cfg.enc_layers
    if cfg.family == "moe" and cfg.moe.first_dense:
        return cfg.n_layers - 1
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.pattern)
    return cfg.n_layers


def exact_cfg(cfg: ArchConfig) -> ArchConfig:
    """Disable chunked attention so every FLOP appears once in the HLO."""
    return dataclasses.replace(cfg, attn_chunk=1_000_000_000)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------
def train_batch_specs(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    b, s = cell.global_batch, cell.seq_len
    ba = batch_axes(mesh, cfg)
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family == "encdec":
        sd = max(2, s // cfg.dec_ratio)
        batch = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, sd), jnp.int32),
        }
        sh = {
            "frames": fit_spec(mesh, (b, s, cfg.d_model), ba, None, None),
            "tokens": fit_spec(mesh, (b, sd), ba, None),
        }
    elif cfg.modality == "vlm":
        batch = {
            "tokens": toks,
            "image_mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
            "image_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
        }
        sh = {
            "tokens": fit_spec(mesh, (b, s), ba, None),
            "image_mask": fit_spec(mesh, (b, s), ba, None),
            "image_embeds": fit_spec(mesh, (b, s, cfg.d_model), ba, None, None),
        }
    else:
        batch = {"tokens": toks}
        sh = {"tokens": fit_spec(mesh, (b, s), ba, None)}
    return batch, sh


def cache_shardings(mesh: Mesh, caches_abs, batch_size: int):
    """Map cache leaves to shardings by key name + rank (see module doc)."""
    ba = batch_axes(mesh)

    def map_leaf(path, leaf):
        key = None
        for pth in reversed(path):
            name = getattr(pth, "key", getattr(pth, "name", None))
            if isinstance(name, str):
                key = name
                break
        r = len(leaf.shape)
        if key in ("k", "v", "ck", "cv"):
            # (B, T, K, hd) or (L, B, T, K, hd): shard time over model
            spec = ([None] * (r - 4)) + [ba, "model", None, None]
        elif key in ("ks", "vs"):
            # int8-KV scales (B, T, K) / (L, B, T, K): same layout sans hd
            spec = ([None] * (r - 3)) + [ba, "model", None]
        elif key == "h":
            # recurrent state: shard batch + the widest state dim over model
            if r == 2:      # rglru (B, W)
                spec = [ba, "model"]
            elif r == 3:    # (L, B, W)
                spec = [None, ba, "model"]
            elif r == 4:    # mamba (B, H, P, N)
                spec = [ba, "model", None, None]
            else:           # (L, B, H, P, N)
                spec = [None, ba, "model", None, None]
        elif key == "conv":
            spec = ([None] * (r - 3)) + [ba, None, "model"]
        else:
            spec = [None] * r
        return fit_spec(mesh, leaf.shape, *spec)

    return jax.tree_util.tree_map_with_path(map_leaf, caches_abs)


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------
def build_train_cell(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    ctx = ModelContext(policy=cfg.tbn, mode=TRAIN, use_pallas=False,
                       fsdp_weights=cfg.fsdp_weights)
    model = build_model(cfg, ctx)
    specs = model.specs()
    params_abs = mod.abstract_params(specs)
    logical = mod.logical_axes(specs)
    p_sh = param_shardings(mesh, logical, rules=dict(cfg.rules_override),
                           abstract_tree=params_abs)

    opt = adamw(cosine_with_warmup(3e-4, 100, 10_000), weight_decay=0.1)
    step_fn = build_train_step(
        model.train_forward, opt, grad_accum=cfg.grad_accum
    )

    f32like = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), t
    )
    # AdamWState(step, mu, nu) — moments mirror the params in fp32
    from repro.optim.adamw import AdamWState

    state_abs = TrainState(
        params=params_abs,
        opt_state=AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=f32like(params_abs),
            nu=f32like(params_abs),
        ),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )
    rep = NamedSharding(mesh, P())
    state_sh = TrainState(
        params=p_sh,
        opt_state=AdamWState(step=rep, mu=p_sh, nu=p_sh),
        step=rep,
    )
    batch_abs, batch_sh = train_batch_specs(cfg, cell, mesh)

    metrics_abs = jax.eval_shape(step_fn, state_abs, batch_abs)[1]
    metrics_sh = jax.tree.map(lambda _: rep, metrics_abs)
    return dict(
        fn=step_fn,
        args=(state_abs, batch_abs),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        # new state aliases old state (fp32 masters + both moments) —
        # without donation the update holds two copies of all of it
        donate_argnums=(0,),
    )


def _serve_model(cfg: ArchConfig):
    ctx = ModelContext(
        policy=cfg.tbn, mode=SERVE, use_pallas=False,
        param_dtype=jnp.bfloat16, fsdp_weights=cfg.fsdp_weights,
    )
    return build_model(cfg, ctx)


def build_prefill_cell(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    model = _serve_model(cfg)
    specs = model.specs()
    params_abs = mod.abstract_params(specs)
    p_sh = param_shardings(
        mesh, mod.logical_axes(specs), rules=dict(cfg.rules_override),
        abstract_tree=params_abs,
    )
    b, s = cell.global_batch, cell.seq_len
    ba = batch_axes(mesh, cfg)
    max_len = s  # serve cache sized to the cell's seq_len

    if cfg.family == "encdec":
        sd = max(2, s // cfg.dec_ratio)
        batch_abs = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((b, sd), jnp.int32),
        }
        batch_sh = {
            "frames": fit_spec(mesh, (b, s, cfg.d_model), ba, None, None),
            "tokens": fit_spec(mesh, (b, sd), ba, None),
        }
        max_len = sd
    elif cfg.modality == "vlm":
        batch_abs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "image_mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
            "image_embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
        }
        batch_sh = {
            "tokens": fit_spec(mesh, (b, s), ba, None),
            "image_mask": fit_spec(mesh, (b, s), ba, None),
            "image_embeds": fit_spec(mesh, (b, s, cfg.d_model), ba, None, None),
        }
    else:
        batch_abs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        batch_sh = {"tokens": fit_spec(mesh, (b, s), ba, None)}

    def prefill_fn(params, batch):
        return model.prefill(params, batch, max_len)

    out_abs = jax.eval_shape(prefill_fn, params_abs, batch_abs)
    logits_sh = fit_spec(mesh, out_abs[0].shape, ba, "model")
    caches_sh = cache_shardings(mesh, out_abs[1], b)
    len_sh = fit_spec(mesh, (b,), ba)
    return dict(
        fn=prefill_fn,
        args=(params_abs, batch_abs),
        in_shardings=(p_sh, batch_sh),
        out_shardings=(logits_sh, caches_sh, len_sh),
    )


def build_decode_cell(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    model = _serve_model(cfg)
    specs = model.specs()
    params_abs = mod.abstract_params(specs)
    p_sh = param_shardings(
        mesh, mod.logical_axes(specs), rules=dict(cfg.rules_override),
        abstract_tree=params_abs,
    )
    b, s = cell.global_batch, cell.seq_len
    ba = batch_axes(mesh, cfg)

    if cfg.family == "encdec":
        caches_abs = jax.eval_shape(
            lambda: _encdec_caches(model, cfg, b, s),
        )
    else:
        caches_abs = jax.eval_shape(
            lambda: model.init_caches(b, s, jnp.bfloat16)
        )
    caches_sh = cache_shardings(mesh, caches_abs, b)
    toks_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    len_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    toks_sh = fit_spec(mesh, (b, 1), ba, None)
    len_sh = fit_spec(mesh, (b,), ba)

    def decode_fn(params, tokens, caches, lengths):
        return model.decode_step(params, tokens, caches, lengths)

    out_abs = jax.eval_shape(decode_fn, params_abs, toks_abs, caches_abs, len_abs)
    logits_sh = fit_spec(mesh, out_abs[0].shape, ba, "model")
    return dict(
        fn=decode_fn,
        args=(params_abs, toks_abs, caches_abs, len_abs),
        in_shardings=(p_sh, toks_sh, caches_sh, len_sh),
        out_shardings=(logits_sh, caches_sh, len_sh),
        # the KV cache updates in place — without donation the step holds
        # input AND output cache copies (2x the dominant decode buffer)
        donate_argnums=(2,),
    )


def _encdec_caches(model, cfg: ArchConfig, b: int, s: int):
    """Decoder self-cache (len s) + cross K/V over an encoder memory of len s."""
    hd = model.dec_block.self_attn.hd
    kv = cfg.n_kv
    L = cfg.dec_layers
    z = lambda *sh: jnp.zeros(sh, jnp.bfloat16)
    return {
        "k": z(L, b, s, kv, hd),
        "v": z(L, b, s, kv, hd),
        "ck": z(L, b, s, kv, hd),
        "cv": z(L, b, s, kv, hd),
    }


def build_cell(
    arch: str,
    shape: str,
    mesh: Mesh,
    *,
    depth: Optional[int] = None,
    exact_flops: bool = False,
):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        raise ValueError(f"{arch} x {shape}: {reason}")
    cfg = depth_cfg(cfg, depth)
    if exact_flops:
        cfg = exact_cfg(cfg)
    builder = {
        "train": build_train_cell,
        "prefill": build_prefill_cell,
        "decode": build_decode_cell,
    }[cell.kind]
    return builder(cfg, cell, mesh)

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. compiles the FULL config (chunked attention = the real memory plan),
     prints memory_analysis() (proves it fits) and cost_analysis(),
  2. optionally (--roofline) compiles depth-1 and depth-2 variants with
     exact (unchunked) attention and extrapolates scan trip counts to get
     true per-cell FLOPs/bytes/collective bytes (see roofline.analysis),
  3. writes one JSON record under results/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --roofline
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import cell_applicable
from repro.distributed.sharding import axis_rules
from repro.launch.cells import build_cell, scan_trips
from repro.launch.mesh import make_production_mesh
from repro.roofline import hw
from repro.roofline.analysis import (
    analyze_compiled,
    combine_extrapolated,
    model_flops,
    subtract,
)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _compile(arch, shape, mesh, *, depth=None, exact=False):
    plan = build_cell(arch, shape, mesh, depth=depth, exact_flops=exact)
    rules = dict(get_config(arch).rules_override)
    with axis_rules(mesh, rules):
        lowered = jax.jit(
            plan["fn"],
            in_shardings=plan["in_shardings"],
            out_shardings=plan["out_shardings"],
            donate_argnums=plan.get("donate_argnums", ()),
        ).lower(*plan["args"])
        compiled = lowered.compile()
    return compiled


def run_cell(arch: str, shape: str, mesh_kind: str, roofline: bool = True) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, reason = cell_applicable(cfg, cell)
    rec = dict(arch=arch, shape=shape, mesh=mesh_kind)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    compiled = _compile(arch, shape, mesh)
    t_full = time.time() - t0
    mem = compiled.memory_analysis()
    mem_rec = dict(
        argument_bytes=int(mem.argument_size_in_bytes),
        output_bytes=int(mem.output_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        alias_bytes=int(mem.alias_size_in_bytes),
    )
    peak = (
        mem.argument_size_in_bytes
        + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    full_terms = analyze_compiled(compiled)
    rec.update(
        status="ok",
        chips=chips,
        compile_s=round(t_full, 1),
        memory=mem_rec,
        peak_bytes_per_device=int(peak),
        fits_hbm=bool(peak <= hw.HBM_BYTES),
        full_cost=full_terms.as_dict(),
    )

    if roofline:
        t1 = time.time()
        c1 = _compile(arch, shape, mesh, depth=1, exact=True)
        c2 = _compile(arch, shape, mesh, depth=2, exact=True)
        terms1 = analyze_compiled(c1)
        terms2 = analyze_compiled(c2)
        delta = subtract(terms2, terms1)
        trips = scan_trips(cfg)
        total = combine_extrapolated(terms1, delta, trips - 1)
        # the grad-accumulation scan body is also visited once by
        # cost_analysis: scale to the full global batch (over-counts the
        # once-per-step optimizer update by ~1-2%; noted in EXPERIMENTS.md)
        accum = cfg.grad_accum if cell.kind == "train" else 1
        if accum > 1:
            total = combine_extrapolated(total, total, accum - 1)
        n_active = active_params(cfg)
        mf = model_flops(cfg, cell, n_active)
        hlo_flops_global = total.flops * chips
        rec.update(
            roofline=total.as_dict(),
            roofline_compile_s=round(time.time() - t1, 1),
            scan_trips=trips,
            n_params_active=n_active,
            model_flops_global=mf,
            useful_flops_ratio=(mf / hlo_flops_global) if hlo_flops_global else None,
        )
    return rec


def active_params(cfg) -> int:
    """Parameters touched per token (MoE: routed top-k of E + shared)."""
    from repro.configs import build_model
    from repro.nn.context import TRAIN, ModelContext

    model = build_model(cfg, ModelContext(policy=cfg.tbn, mode=TRAIN))
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(model.abstract()):
        keys = [str(getattr(p, "key", "")) for p in path]
        n = int(np.prod(leaf.shape))
        # routed expert banks carry a leading E dim under seg*/ffn/{up,down,gate}/w
        if (
            cfg.moe is not None
            and len(leaf.shape) == 3
            and any(k in ("up", "down", "gate") for k in keys)
            and "shared" not in keys
            and leaf.shape[0] == cfg.moe.n_experts
        ):
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--roofline", action="store_true", default=True)
    ap.add_argument("--no-roofline", dest="roofline", action="store_false")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            name = f"{arch}__{shape}__{mk}"
            out = pathlib.Path(args.out) if args.out else RESULTS / f"{name}.json"
            try:
                rec = run_cell(arch, shape, mk, roofline=args.roofline)
            except Exception as e:  # a failing cell is a bug in the system
                rec = dict(arch=arch, shape=shape, mesh=mk, status="error",
                           error=f"{type(e).__name__}: {e}",
                           traceback=traceback.format_exc()[-4000:])
                failures += 1
            out.write_text(json.dumps(rec, indent=2))
            summary = {k: rec.get(k) for k in
                       ("status", "compile_s", "peak_bytes_per_device", "fits_hbm")}
            print(f"{name}: {summary}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Production mesh definitions (TPU v5e pods).

make_production_mesh is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model per pod; (2,16,16) pod x data x model across two."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced-host devices for multi-device tests."""
    return make_auto_mesh((data, model), ("data", "model"))

"""Production mesh definitions (TPU v5e pods).

make_production_mesh is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.compat import make_auto_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model per pod; (2,16,16) pod x data x model across two."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4):
    """Small mesh over forced-host devices for multi-device tests."""
    return make_auto_mesh((data, model), ("data", "model"))


def parse_mesh_arg(spec: str):
    """CLI '--mesh DPxTP' (e.g. '1x4') -> (data, model) Mesh; None for 1x1.

    Shared by the train and serve launchers so both validate the device
    count the same way instead of surfacing a raw jax error."""
    try:
        dp, tp = (int(v) for v in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh wants DPxTP (e.g. 1x4), got {spec!r}")
    if dp < 1 or tp < 1:
        raise SystemExit(f"--mesh dims must be >= 1, got {spec!r}")
    if dp * tp == 1:
        return None
    n_dev = len(jax.devices())
    if dp * tp > n_dev:
        raise SystemExit(
            f"--mesh {spec} needs {dp * tp} devices, have {n_dev} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "for local testing)"
        )
    return make_auto_mesh((dp, tp), ("data", "model"))

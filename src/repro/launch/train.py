"""End-to-end training driver.

    python -m repro.launch.train --arch granite-8b --reduced \\
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Composes every production subsystem: config registry (--arch), TBN policy
override (--tbn-p / --mode), synthetic deterministic data pipeline,
AdamW + cosine schedule, microbatch accumulation, sharded train step under
the active mesh rules, checkpoint/restart via the RecoveryManager (resume
is automatic if --ckpt-dir holds a checkpoint), and the straggler
watchdog. On the CPU host use --reduced; on a real pod drop it and point
--mesh at the production topology.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import build_model, get_config
from repro.core.policy import bwnn_policy, fp32_policy
from repro.data.pipeline import DataPipeline
from repro.data.synthetic import lm_batch
from repro.distributed.sharding import axis_rules
from repro.ft.checkpoint import CheckpointManager
from repro.ft.recovery import RecoveryManager
from repro.ft.watchdog import StepWatchdog
from repro.nn import module as mod
from repro.nn.context import TRAIN, ModelContext
from repro.optim import adamw, cosine_with_warmup
from repro.train.step import build_train_step, init_state


def make_policy(cfg, args):
    if args.mode == "fp32":
        return fp32_policy()
    if args.mode == "bwnn":
        return bwnn_policy()
    p = args.tbn_p or cfg.tbn.p
    return dataclasses.replace(cfg.tbn, p=p)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU host)")
    ap.add_argument("--mode", default="tbn", choices=["tbn", "bwnn", "fp32"])
    ap.add_argument("--tbn-p", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2x4' data x model over local devices")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, tbn=make_policy(cfg, args))

    ctx = ModelContext(policy=cfg.tbn, mode=TRAIN,
                       fsdp_weights=cfg.fsdp_weights)
    model = build_model(cfg, ctx)
    opt = adamw(cosine_with_warmup(args.lr, args.warmup, args.steps),
                weight_decay=0.1)
    step_fn = build_train_step(model.train_forward, opt,
                               grad_accum=args.grad_accum)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import parse_mesh_arg

        mesh = parse_mesh_arg(args.mesh)

    def make_state():
        params = mod.init_params(model.specs(), jax.random.PRNGKey(args.seed))
        return init_state(params, opt)

    def gen(step):
        if cfg.family == "encdec":
            from repro.data.synthetic import frames_batch

            return frames_batch(args.seed, step, args.batch, args.seq, cfg)
        if cfg.modality == "vlm":
            b = lm_batch(args.seed, step, args.batch, args.seq, cfg.vocab)
            b["image_mask"] = jnp.zeros((args.batch, args.seq), bool)
            b["image_embeds"] = jnp.zeros(
                (args.batch, args.seq, cfg.d_model), jnp.bfloat16
            )
            return b
        return lm_batch(args.seed, step, args.batch, args.seq, cfg.vocab)

    def make_data(start):
        return DataPipeline(gen, start_step=start, prefetch=2)

    jit_step = jax.jit(step_fn, donate_argnums=(0,))
    tbn_bits = ctx.ledger.report()
    print(f"arch={cfg.name} mode={cfg.tbn.mode} p={cfg.tbn.p} "
          f"params={mod.param_count(model.specs()):,} "
          f"stored_bits/param={tbn_bits.bits_per_param():.3f}")

    ckpt = CheckpointManager(
        args.ckpt_dir or f"/tmp/tbn_{cfg.name}",
        save_every=args.ckpt_every, max_to_keep=3,
    )
    history = []

    def hooks(step, state, metrics):
        if step % args.log_every == 0 or step == 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)

    rm = RecoveryManager(
        ckpt, make_state=make_state, make_data=make_data,
        watchdog=StepWatchdog(threshold=5.0),
    )

    def wrapped(state, batch):
        if mesh is not None:
            with axis_rules(mesh):
                return jit_step(state, batch)
        return jit_step(state, batch)

    t0 = time.time()
    final = rm.run(wrapped, args.steps, hooks=hooks)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s), final step={int(final.step)}")
    if history:
        print(f"loss: first={history[0][1]:.4f} last={history[-1][1]:.4f}")
    return final, history


if __name__ == "__main__":
    main()

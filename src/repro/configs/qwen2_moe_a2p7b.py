"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B: 4 shared + 60 routed top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H (GQA kv=16) expert
d_ff=1408 vocab=151936. Shared expert intermediate = 5632 = 4 x 1408
(modeled as n_shared=4 units). Qwen uses QKV bias.
"""
from repro.configs.base import ArchConfig, MoESpec
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,
    vocab=151_936,
    moe=MoESpec(n_experts=60, top_k=4, n_shared=4, d_ff_expert=1408),
    qkv_bias=True,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""Architecture config schema + input-shape cells (assigned pool)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.policy import TBNPolicy, tbn_policy


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense: bool = False      # moonlight/deepseek: layer 0 dense FFN


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    pattern: Tuple[str, ...] = ()  # hybrid block cycle, e.g. ("rec","rec","attn")
    window: Optional[int] = None   # sliding-window attention size
    qkv_bias: bool = False
    qk_norm: bool = False
    activation: str = "silu"
    gated_mlp: bool = True
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    dec_ratio: int = 4             # dec tokens = seq_len // dec_ratio (audio)
    modality: str = "text"         # text | audio | vlm
    # TBN policy (paper: lambda=150k for ImageNet-scale; alpha from W)
    tbn: TBNPolicy = dataclasses.field(
        default_factory=lambda: tbn_policy(
            p=4, min_size=150_000, alpha_source="W", alpha_mode="tile"
        )
    )
    # shape-cell capabilities
    supports_decode: bool = True
    subquadratic: bool = False     # may run long_500k
    remat: str = "full"            # full | dots | none
    attn_chunk: int = 1024         # chunked-attention query block
    # Roofline-only: unroll layer stacks instead of lax.scan so XLA's
    # cost_analysis (which visits a while body once) counts every layer.
    force_unroll: bool = False
    # Per-arch sharding recipe (picked from the dry-run memory sweeps —
    # EXPERIMENTS.md §Dry-run):
    #   attn_act  "heads": q/k/v sharded on the head axes where divisible
    #             (seq replicated inside the block) — best when n_heads
    #             divides the model axis.
    #             "seq": q/k/v sequence-sharded over the model axis
    #             (flash-row-parallel) — required when head counts do not
    #             divide the mesh (qwen1.5: 40H, starcoder2: 36H).
    #   fsdp_weights  gather effective weights over the data axis at use
    #             (ZeRO-3); stops GSPMD resolving 2D-sharded-weight x
    #             seq-sharded-activation contractions by replicating batch.
    attn_act: str = "heads"
    fsdp_weights: bool = False
    # Per-arch logical->mesh rule overrides ((key, value) pairs merged over
    # distributed.sharding.DEFAULT_RULES). The MoE recipe maps act_batch
    # over ALL axes (pure ZeRO-3 DP: weights stay 2D-sharded and gather at
    # use) — for d_model<=2048 experts, TP's per-layer (T, d) activation
    # all-reduces cost ~4x more than the weight gathers (§Perf).
    rules_override: Tuple[Tuple[str, object], ...] = ()
    # KV cache dtype for serving ("bf16" | "int8"); int8 halves the decode
    # working set — required for the MHA-heavy 32B config at 32k x 128.
    kv_dtype: str = "bf16"
    # Microbatch gradient accumulation for the train shape (memory lever:
    # activations scale with batch/grad_accum; roofline terms are scaled
    # back up by the dry-run).
    grad_accum: int = 1

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 2 if not self.pattern else len(self.pattern)),
            d_model=min(self.d_model, 64),
            n_heads=min(self.n_heads, 4),
            n_kv=min(self.n_kv, 2),
            head_dim=16,
            d_ff=min(self.d_ff, 128),
            vocab=min(self.vocab, 512),
            moe=None
            if self.moe is None
            else dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=min(self.moe.d_ff_expert or 64, 64),
            ),
            ssm=None
            if self.ssm is None
            else dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=8),
            enc_layers=min(self.enc_layers, 2),
            dec_layers=min(self.dec_layers, 2),
            window=None if self.window is None else min(self.window, 8),
            tbn=dataclasses.replace(self.tbn, min_size=1024),
            attn_chunk=64,
        )


# ---------------------------------------------------------------------------
# Input-shape cells (shared by all LM-family archs)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — DESIGN.md §Arch-applicability skips."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "SKIP: encoder-only, no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "SKIP: full-attention (needs sub-quadratic)"
    return True, ""

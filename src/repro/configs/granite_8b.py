"""granite-8b — IBM Granite code model, llama-style dense decoder.

[arXiv:2405.04324] 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14_336,
    vocab=49_152,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""chameleon-34b — early-fusion VLM decoder with VQ image tokens.

[arXiv:2405.09818] 48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536,
QK-norm. Early fusion: image positions carry precomputed patch/VQ
embeddings supplied by input_specs() (modality frontend stubbed per
assignment); text positions use the shared 65536-entry table.
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_ff=22_016,
    vocab=65_536,
    qk_norm=True,
    # heads-sharded attention (64H divides); microbatch x2 for the 8192-wide
    # residual stream (EXPERIMENTS.md §Dry-run memory sweeps).
    attn_act="heads",
    grad_accum=2,
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    modality="vlm",
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

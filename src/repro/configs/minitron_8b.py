"""minitron-8b — pruned Nemotron-4: squared-ReLU MLP, 256k vocab.

[arXiv:2407.14679] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=16_384,
    vocab=256_000,
    activation="relu2",
    gated_mlp=False,
    norm="layernorm",
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""moonshot-v1-16b-a3b — Moonlight/Kimi MoE, 64 routed experts top-6.

[hf:moonshotai/Moonlight-16B-A3B] 48L d_model=2048 16H (GQA kv=16)
expert d_ff=1408, vocab=163840. DeepSeek-V3-style extras from the HF config:
2 shared experts, first layer dense FFN (d_ff 8*1408=11264). Assignment
pins GQA kv=16 (not MLA) — we follow the assignment.
"""
from repro.configs.base import ArchConfig, MoESpec
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_ff=1408,                      # expert/shared unit width (assignment)
    vocab=163_840,
    moe=MoESpec(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                first_dense=True),
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    kv_dtype="int8",            # 47-layer 32k x 128 cache, halved
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone.

[arXiv:2308.11596] 24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.
Backbone only (per assignment): the speech frontend is a stub —
input_specs() provides precomputed frame embeddings (B, S, d_model).
24 encoder + 24 decoder layers; decoder text length = seq_len // dec_ratio.
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,                    # 24 enc + 24 dec
    enc_layers=24,
    dec_layers=24,
    dec_ratio=4,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256_206,
    activation="relu",
    gated_mlp=False,
    norm="layernorm",
    rope_theta=0.0,                 # learned/sinusoidal family; no rope
    modality="audio",
    tbn=tbn_policy(p=4, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""recurrentgemma-2b — Griffin hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427] 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000,
sliding window 2048, block cycle (rec, rec, attn). Sub-quadratic:
runs long_500k (RG-LRU state + bounded window cache).
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_ff=7680,
    vocab=256_000,
    pattern=("rec", "rec", "attn"),
    # 10 heads do not divide the 16-way model axis -> sequence-sharded
    # attention activations (EXPERIMENTS.md §Dry-run memory sweeps).
    attn_act="seq",
    window=2048,
    activation="gelu",
    gated_mlp=True,
    norm="rmsnorm",
    subquadratic=True,
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

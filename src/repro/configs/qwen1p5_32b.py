"""qwen1.5-32b — large dense decoder with QKV bias.

[hf:Qwen family] 64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv=40,
    d_ff=27_392,
    vocab=152_064,
    qkv_bias=True,
    # 40 heads do not divide the 16-way model axis -> sequence-sharded
    # attention; microbatch x2 + int8 KV for the 32k x 128 decode cache
    # (EXPERIMENTS.md §Dry-run memory sweeps).
    attn_act="seq",
    grad_accum=2,
    kv_dtype="int8",
    activation="silu",
    gated_mlp=True,
    norm="rmsnorm",
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""starcoder2-7b — GQA + RoPE code model, GELU MLP, LayerNorm.

[arXiv:2402.19173] 32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import ArchConfig
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv=4,
    d_ff=18_432,
    vocab=49_152,
    activation="gelu",
    gated_mlp=False,
    norm="layernorm",
    qkv_bias=True,
    # 36 heads do not divide the 16-way model axis -> sequence-sharded
    # attention + ZeRO-3 weight gathering; microbatch x2
    # (EXPERIMENTS.md §Dry-run memory sweeps).
    attn_act="seq",
    fsdp_weights=True,
    grad_accum=2,
    tbn=tbn_policy(p=8, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

"""Architecture registry: ``--arch <id>`` -> ArchConfig."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeCell, cell_applicable

_MODULES: Dict[str, str] = {
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2p7b",
    "granite-8b": "repro.configs.granite_8b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "minitron-8b": "repro.configs.minitron_8b",
    "qwen1.5-32b": "repro.configs.qwen1p5_32b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
    "chameleon-34b": "repro.configs.chameleon_34b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[key]).CONFIG


def build_model(cfg: ArchConfig, ctx=None):
    """Instantiate the right model family for a config."""
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecModel

        return EncDecModel(cfg, ctx)
    from repro.models.lm import DecoderLM

    return DecoderLM(cfg, ctx)


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ArchConfig",
    "ShapeCell",
    "build_model",
    "cell_applicable",
    "get_config",
]

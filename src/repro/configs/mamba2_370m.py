"""mamba2-370m — attention-free SSD (state-space duality).

[arXiv:2405.21060] 48L d_model=1024, ssm_state=128, vocab=50280.
Sub-quadratic: runs the long_500k decode cell (O(1) state per step).
"""
from repro.configs.base import ArchConfig, SSMSpec
from repro.core.policy import tbn_policy

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50_280,
    ssm=SSMSpec(d_state=128, head_dim=64, expand=2, n_groups=1, chunk=256),
    norm="rmsnorm",
    subquadratic=True,
    tbn=tbn_policy(p=4, min_size=150_000, alpha_source="W", alpha_mode="tile"),
)

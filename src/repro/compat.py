"""Version-compat shims over APIs that moved between jax releases.

The repo targets current jax spellings; these wrappers keep the same call
sites running on the older installed jax:

  * ``shard_map`` graduated from ``jax.experimental.shard_map`` (kwarg
    ``check_rep``) to top-level ``jax.shard_map`` (kwarg ``check_vma``).
  * ``jax.make_mesh`` grew an ``axis_types=`` parameter (and
    ``jax.sharding.AxisType``) only in newer releases.
  * Pallas' ``pltpu.TPUCompilerParams`` was renamed ``pltpu.CompilerParams``.
  * ``Compiled.cost_analysis()`` returned a one-element list of dicts before
    returning the dict directly.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, kwarg is check_vma
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # older jax: experimental module, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` with the new-style signature on any supported jax."""
    if check_vma is not None:
        kwargs[_CHECK_KW] = check_vma
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under either of its names."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a flat dict on any jax."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))

"""Gradient compression for the data-parallel reduction.

Two codecs + an explicit shard_map DP step builder that uses them:

  * EF-sign (1 bit/coordinate + one scalar): sign of (grad + error
    feedback), scaled by the mean magnitude; the residual stays in the
    per-worker error accumulator, which makes the method convergent
    (Karimireddy et al., "Error Feedback Fixes SignSGD").
  * int8 (8 bits/coordinate + one scalar per tensor): symmetric linear
    quantization of the local gradient before the ring reduction.

Integration contract: GSPMD's automatic gradient reduction is exact and
uncompressed; compression NEEDS the per-shard local gradients, so the
compressed path runs data-parallelism explicitly under shard_map
(``build_dp_train_step``). On the production mesh this composes as
hierarchical DP: the paper-faithful exact path in-pod, compressed ring
across the "pod" axis where links are scarce (DESIGN.md §5). TBN makes the
*parameter* side of that story free: packed tiles are what elastic rejoins
broadcast (repro.serve.weights).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


# ---------------------------------------------------------------------------
# codecs (pure per-worker math; reduction = psum of decoded payloads)
# ---------------------------------------------------------------------------
def ef_sign_encode(g: jax.Array, err: jax.Array):
    """-> (decoded payload to reduce, new error state).

    payload = sign(g + err) * mean|g + err|  (1 bit + 1 scalar on the wire)
    """
    c = g + err
    scale = jnp.mean(jnp.abs(c))
    payload = jnp.sign(c) * scale
    return payload, c - payload


def int8_encode(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """-> (int8 codes, f32 scale). Wire cost: 8 bits + 1 scalar."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def wire_bits(kind: str, n: int) -> int:
    """Per-worker bytes on the wire for an n-element gradient."""
    return {"none": 32 * n, "int8": 8 * n + 32, "ef_sign": n + 32}[kind]


# ---------------------------------------------------------------------------
# explicit-DP train step with compressed reduction
# ---------------------------------------------------------------------------
class CompressionState(NamedTuple):
    """Error-feedback accumulators (zeros for int8/none)."""

    error: Any

    @staticmethod
    def init(params, kind: str) -> "CompressionState":
        if kind == "ef_sign":
            z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        else:
            z = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
        return CompressionState(error=z)


def compressed_psum_mean(grads, err_tree, *, kind: str, axis: str):
    """Per-shard compress -> psum -> mean, inside shard_map.

    Returns (reduced grads, new error tree). ``kind`` in
    {"none", "int8", "ef_sign"}.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        if kind == "none":
            return jax.lax.psum(g, axis) / n, e
        if kind == "int8":
            q, s = int8_encode(g)
            dec = int8_decode(q, s)
            return jax.lax.psum(dec, axis) / n, e
        if kind == "ef_sign":
            payload, new_e = ef_sign_encode(g, e)
            return jax.lax.psum(payload, axis) / n, new_e
        raise ValueError(kind)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    return red, new_err


def build_dp_train_step(
    loss_fn: Callable,
    optimizer,
    mesh: Mesh,
    *,
    compression: str = "ef_sign",
    dp_axis: str = "data",
    clip_norm: Optional[float] = 1.0,
):
    """Explicit data-parallel train step under shard_map.

    Params/opt state are replicated across ``dp_axis``; each shard computes
    local grads on its batch slice, the reduction goes through the chosen
    codec, and every shard applies the identical update. The returned step
    takes and returns a (TrainState, CompressionState) pair.

    This is the integration point for the compressed cross-pod reduction:
    on the (pod, data, model) mesh call it with dp_axis="pod" around a
    step whose inner GSPMD reduction covers "data" only.
    """
    from repro.optim import clip_by_global_norm
    from repro.train.step import TrainState

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: TrainState, comp: CompressionState, batch):
        (loss, aux), grads = grad_fn(state.params, batch)
        grads, new_err = compressed_psum_mean(
            grads, comp.error, kind=compression, axis=dp_axis
        )
        loss = jax.lax.pmean(loss, dp_axis)
        gnorm = jnp.zeros(())
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params
        )
        metrics = {"loss": loss, "grad_norm": gnorm}
        return (
            TrainState(new_params, new_opt, state.step + 1),
            CompressionState(error=new_err),
            metrics,
        )

    rep = P()
    batch_spec = {"x": P(dp_axis), "y": P(dp_axis)}
    return jax.jit(
        shard_map(
            step,
            mesh=mesh,
            in_specs=(rep, rep, batch_spec),
            out_specs=(rep, rep, rep),
            check_vma=False,
        )
    )

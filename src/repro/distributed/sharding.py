"""Logical-axis sharding: one place that maps layer semantics to the mesh.

Layers annotate weights with *logical* axis names ("heads", "embed", ...)
and wrap hot activations in ``logical_constraint``. The launcher activates a
rule set for the current mesh; outside a rule context everything is a no-op
(single-device tests/benchmarks never touch device APIs).

Default rules (DESIGN.md §5):

  weights   heads/mlp/vocab/experts -> "model"   (tensor/expert parallel)
            embed                   -> "data"    (FSDP/ZeRO-3 master shard)
  acts      act_batch -> ("pod","data")          (data parallel)
            act_heads/act_mlp/act_vocab -> "model"
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, AxisVal] = {
    # weight axes
    "heads": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "embed": "data",
    "kv": None,
    "conv_spatial": None,
    "layers": None,
    "stage": None,
    # unique-row axis of a row-packed serve tile: the r = n_out/p rows of
    # one tile shard over the model axis (r/TP rows per device), so HBM per
    # device holds q/TP tile bits. The kernels run per-shard under
    # shard_map (kernels/ops.py); alphas stay replicated — each shard's
    # rows appear in ALL p replica blocks of the output, so every shard
    # needs every alpha, and p floats are not worth slicing (DESIGN.md §5).
    "tile_rows": "model",
    # activation axes
    "act_batch": ("pod", "data"),
    "act_seq": None,
    # residual-stream sequence axis: sharded over "model" BETWEEN blocks
    # (Megatron-style sequence parallelism). GSPMD all-gathers at the QKV /
    # FFN entry and reduce-scatters after the output projection; the scan
    # carry saved for backward is 1/TP the size, which is what lets the
    # 4k-seq train cells fit HBM (EXPERIMENTS.md §Dry-run).
    "act_res_seq": "model",
    # MoE dispatch-group axes. "act_tok": the dispatch/combine domain —
    # groups shard over EVERY mesh axis (all index ops are group-local).
    # "act_cap": the expert-einsum domain — groups keep only the DP axes
    # so "act_experts" can take the model axis. The act_tok <-> act_cap
    # resharding GSPMD inserts is exactly the EP all-to-all.
    "act_tok": ("pod", "data", "model"),
    "act_cap": ("pod", "data"),
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",
}


class _Active(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Dict[str, AxisVal]] = None


_ACTIVE = _Active()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, AxisVal]] = None):
    """Activate logical->mesh rules (and the mesh) for a region."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    # Drop axes the mesh does not have (e.g. "pod" on the single-pod mesh).
    names = set(mesh.axis_names)

    def _filter(v: AxisVal) -> AxisVal:
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in names else None
        kept = tuple(a for a in v if a in names)
        return kept if kept else None

    filtered = {k: _filter(v) for k, v in rules.items()}
    prev = (_ACTIVE.mesh, _ACTIVE.rules)
    _ACTIVE.mesh, _ACTIVE.rules = mesh, filtered
    try:
        with mesh:
            yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE.mesh


def _rule_axes(rule_name: str) -> Tuple[Optional[Mesh], Tuple[str, ...]]:
    """Active mesh + the rule's axes (normalized, filtered to the mesh)."""
    mesh, rules = _ACTIVE.mesh, _ACTIVE.rules
    if mesh is None or rules is None:
        return None, ()
    ax = rules.get(rule_name)
    if ax is None:
        return mesh, ()
    if isinstance(ax, str):
        ax = (ax,)
    return mesh, tuple(a for a in ax if a in mesh.axis_names)


def _dividing_prefix(mesh: Mesh, axes: Tuple[str, ...], dim: int):
    """Longest prefix of ``axes`` whose extent divides ``dim`` — the same
    degradation rule ``_divisible_spec`` applies to param placement, so
    trace-time decisions in the serve kernels can never disagree with
    where the params were actually placed."""
    for k in range(len(axes), 0, -1):
        cand = axes[:k]
        if dim % _mesh_extent(mesh, cand) == 0:
            return cand
    return ()


def tile_sharding(n_rows: int) -> Optional[Tuple[Mesh, Tuple[str, ...], int]]:
    """(mesh, axes, extent) to shard a tile's ``n_rows`` unique rows, or None.

    None means tile-row sharding is off: no active rules, the
    ``tile_rows`` rule maps to no mesh axis, or the longest
    dim-dividing prefix of its axes has extent 1 (including the
    TP-does-not-divide-r fallback). The serve kernels consult this at
    trace time to choose between the shard_map tensor-parallel path and
    the single-device path (kernels/ops.py)."""
    mesh, axes = _rule_axes("tile_rows")
    if mesh is None or not axes:
        return None
    chosen = _dividing_prefix(mesh, axes, n_rows)
    extent = _mesh_extent(mesh, chosen)
    if extent <= 1:
        return None
    return mesh, chosen, extent


def batch_shard_axes(exclude: Sequence[str], dim: int) -> Tuple[str, ...]:
    """Axes to shard a batch-like dim of size ``dim`` inside the serve
    shard_map wrappers: the longest dividing prefix of the ``act_batch``
    rule minus ``exclude`` — keeps activations data-parallel inside the
    tensor-parallel region instead of forcing replication."""
    mesh, axes = _rule_axes("act_batch")
    if mesh is None:
        return ()
    axes = tuple(a for a in axes if a not in exclude)
    return _dividing_prefix(mesh, axes, dim)


def spec_from_logical(logical: Sequence[Optional[str]]) -> P:
    rules = _ACTIVE.rules or {}
    return P(*(rules.get(name) if name else None for name in logical))


def _mesh_extent(mesh: Mesh, axes: AxisVal) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _divisible_spec(mesh: Mesh, shape, spec_axes) -> P:
    """Sanitize a spec: drop non-dividing axes and duplicate mesh axes.

    * Ragged dims (e.g. vocab=50280 over model=16) stay replicated instead
      of failing the pjit divisibility check.
    * A mesh axis may shard at most one positional dim; the FIRST logical
      dim that claims it wins (stacked MoE banks map both "experts" and
      "mlp" to "model"; square (d,d) weights map "embed" twice).
    """
    used: set = set()
    out = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            out.append(None)
            continue
        parts = (ax,) if isinstance(ax, str) else tuple(ax)
        parts = tuple(a for a in parts if a not in used)
        # longest prefix of the axis tuple that evenly divides the dim —
        # a (pod, data, model) batch rule degrades to (pod, data) for a
        # 32-sample prefill instead of replicating outright. Shared with
        # the serve kernels' trace-time decisions (tile_sharding /
        # batch_shard_axes) so placement and shard_map can never disagree.
        cand = _dividing_prefix(mesh, parts, dim)
        if not cand:
            out.append(None)
            continue
        used.update(cand)
        out.append(cand if len(cand) > 1 else cand[0])
    return P(*out)


def logical_constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the active rules; no-op outside."""
    if _ACTIVE.mesh is None or _ACTIVE.rules is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"{len(logical)} axes for rank-{x.ndim} array")
    spec = spec_from_logical(logical)
    spec = _divisible_spec(_ACTIVE.mesh, x.shape, tuple(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACTIVE.mesh, spec)
    )


def named_sharding(mesh: Mesh, *axes: AxisVal) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def param_shardings(mesh: Mesh, logical_tree, rules=None, abstract_tree=None):
    """Map a tree of logical-axis tuples to NamedShardings for jit.

    When ``abstract_tree`` (matching ShapeDtypeStructs) is given, mesh axes
    that do not evenly divide their dimension are dropped (replicated) so
    ragged dims — 50280-row vocab over a 16-way model axis — never fail the
    pjit divisibility check.
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    names = set(mesh.axis_names)

    def _resolve(logical):
        spec = []
        for name in logical:
            v = rules.get(name) if name else None
            if isinstance(v, str) and v not in names:
                v = None
            if isinstance(v, tuple):
                v = tuple(a for a in v if a in names) or None
            spec.append(v)
        return spec

    is_leaf = lambda x: isinstance(x, tuple)
    if abstract_tree is None:
        return jax.tree.map(
            lambda lg: NamedSharding(mesh, P(*_resolve(lg))),
            logical_tree,
            is_leaf=is_leaf,
        )
    return jax.tree.map(
        lambda lg, ab: NamedSharding(
            mesh, _divisible_spec(mesh, ab.shape, _resolve(lg))
        ),
        logical_tree,
        abstract_tree,
        is_leaf=is_leaf,
    )

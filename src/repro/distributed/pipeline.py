"""GPipe pipeline parallelism via shard_map + collective_permute.

Each device along the "stage" mesh axis holds one contiguous slice of
layers. Microbatches stream through: at tick t, stage s computes
microbatch (t - s) and hands its activation to stage s+1 with a
collective_permute (differentiable — its transpose is the reverse
permute, so jax.grad gives the 1F1B-equivalent backward schedule for
free; remat inside the stage keeps the bubble's live set small).

Schedule (classic GPipe): M microbatches, S stages, M + S - 1 ticks,
bubble fraction (S-1)/(M+S-1).

This is the depth-scaling option for 1000+ node deployments where the
(data, model) in-pod mesh is exhausted: stages map onto the "pod" axis so
the only cross-pod traffic is one (microbatch, d_model) activation per
tick (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,          # per-device slice (leading stage dim consumed)
    x: jax.Array,               # (M, mb, ...) microbatched input
    *,
    axis: str = "stage",
) -> jax.Array:
    """Run the GPipe schedule inside shard_map (one stage per device).

    stage_fn(params, x_mb) -> y_mb applies THIS device's layers.
    x carries all M microbatches; stage 0 feeds them in order. Returns the
    final-stage outputs in microbatch order (replicated layout handled by
    the caller's out_specs).
    """
    s_idx = jax.lax.axis_index(axis)
    n_stages = jax.lax.psum(1, axis)
    m = x.shape[0]
    mb_shape = x.shape[1:]
    n_ticks = m + n_stages - 1

    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(carry, t):
        buf, outs = carry                       # buf: activation entering us
        # stage 0 ingests microbatch t (others use the permuted buffer)
        x_in = jnp.where(
            s_idx == 0,
            jax.lax.dynamic_index_in_dim(
                x, jnp.clip(t, 0, m - 1), 0, keepdims=False
            ),
            buf,
        )
        y = jax.checkpoint(stage_fn)(stage_params, x_in)
        # last stage records microbatch (t - S + 1) when it is valid
        out_slot = t - (n_stages - 1)
        is_last = jnp.logical_and(s_idx == n_stages - 1, out_slot >= 0)
        outs = jnp.where(
            is_last,
            jax.lax.dynamic_update_index_in_dim(
                outs, y, jnp.clip(out_slot, 0, m - 1), 0
            ),
            outs,
        )
        # hand activations downstream
        buf = jax.lax.ppermute(y, axis, perm)
        return (buf, outs), None

    buf0 = jnp.zeros(mb_shape, x.dtype)
    outs0 = jnp.zeros((m,) + mb_shape, x.dtype)
    (buf, outs), _ = jax.lax.scan(
        tick, (buf0, outs0), jnp.arange(n_ticks)
    )
    # only the last stage holds real outputs; broadcast them to all stages
    # (psum of a masked buffer == select from last stage)
    mask = (s_idx == n_stages - 1).astype(outs.dtype)
    return jax.lax.psum(outs * mask, axis)


def build_gpipe_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    mesh: Mesh,
    *,
    axis: str = "stage",
    params_spec: Any,
):
    """shard_map-wrapped GPipe apply: (stacked stage params, (M, mb, ...) x)
    -> (M, mb, ...) y, with per-stage params sharded along ``axis``."""

    def apply(stacked_params, x):
        local = jax.tree.map(lambda v: v[0], stacked_params)  # our stage slice
        return pipeline_forward(stage_fn, local, x, axis=axis)

    return shard_map(
        apply,
        mesh=mesh,
        in_specs=(params_spec, P()),
        out_specs=P(),
        check_vma=False,
    )


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)

from repro.distributed.sharding import (
    DEFAULT_RULES,
    axis_rules,
    logical_constraint,
    param_shardings,
)

__all__ = [
    "DEFAULT_RULES",
    "axis_rules",
    "logical_constraint",
    "param_shardings",
]

"""TPU v5e hardware constants (per assignment)."""
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
PEAK_OPS_INT8 = 394e12        # int8 MAC-op/s per chip (2x the bf16 MXU
# rate — the integer compute paths' MACs; XNOR word ops are charged at
# this rate too after the 32-bits-per-word conversion in
# roofline.analysis.integer_dense_ops)
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
HBM_BYTES = 16 * 2**30        # 16 GiB per chip

"""Roofline terms from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips x peak)      [cost_analysis is per-device
  memory     = HLO_bytes / (chips x HBM bw)     post-SPMD, so the division by
  collective = coll_bytes / (chips x link bw)   chips is already done]

collective bytes come from parsing the optimized (partitioned) HLO text:
we sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, with a 2x ring factor for
all-reduce (reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-op-kind transferred bytes (per device) from partitioned HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _type_bytes(m.group("type"))
        factor = 2.0 if op == "all-reduce" else 1.0
        out[op] = out.get(op, 0.0) + factor * nbytes
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float                  # per-device float ops
    bytes_hbm: float              # per-device
    bytes_coll: float             # per-device
    coll_breakdown: Dict[str, float]
    int_ops: float = 0.0          # per-device INTEGER-domain ops (int8
    # MACs / XNOR-popcount bit positions) — HLO cost_analysis reports
    # integer dots and bitwise work as zero FLOPs, so the integer
    # compute paths would otherwise look free; callers attach the
    # analytic count (``integer_dense_ops``) via ``analyze_compiled``'s
    # int_ops argument or construct the terms directly

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_int(self) -> float:
        return self.int_ops / hw.PEAK_OPS_INT8

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_coll / hw.ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "int": self.t_int,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_int, self.t_memory,
                   self.t_collective)

    def as_dict(self) -> dict:
        return dict(
            flops=self.flops,
            int_ops=self.int_ops,
            bytes_hbm=self.bytes_hbm,
            bytes_coll=self.bytes_coll,
            t_compute=self.t_compute,
            t_int=self.t_int,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            dominant=self.dominant,
            coll_breakdown=self.coll_breakdown,
        )


def integer_dense_ops(
    m: int, n_in: int, r: int, compute_path: str = "xnor"
) -> float:
    """Analytic integer-op count for one tiled dense apply (u = x . T^T).

    HLO cost_analysis counts these as zero FLOPs, so the dry-run/roofline
    needs the analytic number:

    * ``int8``: 2 * m * n_in * r — one int8 MAC per (row, bit) pair,
      MAC = multiply + add.
    * ``xnor``: each of the m*r outputs reads ceil(n_in/32) packed words
      at ~2 word ops each (XOR + popcount); one 32-lane word op covers
      32 bit positions, so the count is normalized to MAC-equivalents at
      the int8 rate: 2 * m * r * ceil(n_in/32).

    ``float`` contributes nothing here (its MACs already land in HLO
    flops).
    """
    if compute_path == "int8":
        return 2.0 * m * n_in * r
    if compute_path == "xnor":
        return 2.0 * m * r * ((n_in + 31) // 32)
    if compute_path == "float":
        return 0.0
    raise ValueError(f"unknown compute_path {compute_path!r}")


def analyze_compiled(compiled, int_ops: float = 0.0) -> RooflineTerms:
    """Roofline terms from a compiled artifact.

    ``int_ops`` attaches the analytic integer-op count (see
    ``integer_dense_ops``) for programs using the integer compute paths
    — cost_analysis reports those ops as zero FLOPs.
    """
    from repro.compat import cost_analysis_dict

    cost = cost_analysis_dict(compiled)
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = collective_bytes(text)
    return RooflineTerms(
        flops=flops,
        bytes_hbm=nbytes,
        bytes_coll=sum(coll.values()),
        coll_breakdown=coll,
        int_ops=int_ops,
    )


def combine_extrapolated(
    base: RooflineTerms, delta: RooflineTerms, extra_trips: int
) -> RooflineTerms:
    """total = base + extra_trips * delta  (scan trip-count correction)."""
    add = lambda a, b: a + extra_trips * b
    coll = dict(base.coll_breakdown)
    for k, v in delta.coll_breakdown.items():
        coll[k] = coll.get(k, 0.0) + extra_trips * v
    return RooflineTerms(
        flops=add(base.flops, delta.flops),
        bytes_hbm=add(base.bytes_hbm, delta.bytes_hbm),
        bytes_coll=add(base.bytes_coll, delta.bytes_coll),
        coll_breakdown=coll,
        int_ops=add(base.int_ops, delta.int_ops),
    )


def subtract(a: RooflineTerms, b: RooflineTerms) -> RooflineTerms:
    coll = {k: max(0.0, v - b.coll_breakdown.get(k, 0.0))
            for k, v in a.coll_breakdown.items()}
    return RooflineTerms(
        flops=max(0.0, a.flops - b.flops),
        bytes_hbm=max(0.0, a.bytes_hbm - b.bytes_hbm),
        bytes_coll=max(0.0, a.bytes_coll - b.bytes_coll),
        coll_breakdown=coll,
        int_ops=max(0.0, a.int_ops - b.int_ops),
    )


def model_flops(cfg, cell, n_params_active: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference forward), global."""
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_params_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_params_active * tokens
    tokens = cell.global_batch * 1
    return 2.0 * n_params_active * tokens

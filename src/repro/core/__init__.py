"""TBN core: the paper's primary contribution as composable JAX modules."""
from repro.core.bits import BitsReport, LayerLedger, LayerRecord
from repro.core.collapse import collapsed_chain_reference, fold_consumer_weight
from repro.core.packing import (
    pack_bits,
    pack_bits_np,
    pack_conv_tile,
    packed_len,
    storage_bytes,
    unpack_bits,
    unpack_conv_tile,
)
from repro.core.policy import (
    BWNN,
    FP32,
    TBN,
    TBNPolicy,
    bwnn_policy,
    fp32_policy,
    tbn_policy,
)
from repro.core.tiling import (
    ConvTilePlan,
    TileSpec,
    aggregate,
    compute_alpha,
    construct_binary,
    conv_tile_bank,
    expand_alpha,
    export_tile,
    fold_inputs_reference,
    plan_conv_tiling,
    plan_tiling,
    reconstruct_from_tile,
    tile_as_matrix,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)

__all__ = [
    "BitsReport", "LayerLedger", "LayerRecord",
    "collapsed_chain_reference", "fold_consumer_weight",
    "pack_bits", "pack_bits_np", "pack_conv_tile", "packed_len",
    "storage_bytes", "unpack_bits", "unpack_conv_tile",
    "BWNN", "FP32", "TBN", "TBNPolicy", "bwnn_policy", "fp32_policy", "tbn_policy",
    "ConvTilePlan", "TileSpec", "aggregate", "compute_alpha", "construct_binary",
    "conv_tile_bank", "expand_alpha", "export_tile", "fold_inputs_reference",
    "plan_conv_tiling", "plan_tiling", "reconstruct_from_tile",
    "tile_as_matrix", "tile_vector", "tiled_matmul_reference", "tiled_weight",
]

"""TBN core: the paper's primary contribution as composable JAX modules."""
from repro.core.bits import BitsReport, LayerLedger, LayerRecord
from repro.core.collapse import collapsed_chain_reference, fold_consumer_weight
from repro.core.packing import (
    pack_bits,
    pack_bits_np,
    packed_len,
    storage_bytes,
    unpack_bits,
)
from repro.core.policy import (
    BWNN,
    FP32,
    TBN,
    TBNPolicy,
    bwnn_policy,
    fp32_policy,
    tbn_policy,
)
from repro.core.tiling import (
    TileSpec,
    aggregate,
    compute_alpha,
    construct_binary,
    expand_alpha,
    export_tile,
    fold_inputs_reference,
    plan_tiling,
    reconstruct_from_tile,
    tile_as_matrix,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)

__all__ = [
    "BitsReport", "LayerLedger", "LayerRecord",
    "collapsed_chain_reference", "fold_consumer_weight",
    "pack_bits", "pack_bits_np", "packed_len", "storage_bytes", "unpack_bits",
    "BWNN", "FP32", "TBN", "TBNPolicy", "bwnn_policy", "fp32_policy", "tbn_policy",
    "TileSpec", "aggregate", "compute_alpha", "construct_binary", "expand_alpha",
    "export_tile", "fold_inputs_reference", "plan_tiling", "reconstruct_from_tile",
    "tile_as_matrix", "tile_vector", "tiled_matmul_reference", "tiled_weight",
]

"""TBN application policy — which layers get tiled, and how.

Mirrors the paper's three hyperparameters (Section 3):
  1. lambda  — minimum layer size N for tiling (default 64k; 150k for
               ImageNet-scale models; 32k for the time-series models).
  2. alpha source — W (shared with the tile master) or a separate tensor A.
  3. alpha mode — one scalar per layer (Eq. 7) or one per tile (Eq. 9).

The policy is carried in every model config so the same architecture can be
instantiated full-precision (p=1), BWNN (binary per-weight) or TBN_p.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.tiling import AlphaMode, AlphaSource, SteMode, TileSpec, plan_tiling

# Quantization regimes for a whole model.
FP32 = "fp32"      # standard full-precision layers
BWNN = "bwnn"      # binary weight per parameter (1 bit) + alpha, XNOR-style
TBN = "tbn"        # tiled binary (sub-bit)


@dataclasses.dataclass(frozen=True)
class TBNPolicy:
    """Model-wide TBN hyperparameters."""

    mode: str = TBN                      # fp32 | bwnn | tbn
    p: int = 4                           # tile compression factor
    min_size: int = 64_000               # lambda
    alpha_mode: AlphaMode = "tile"       # "layer" | "tile"
    alpha_source: AlphaSource = "A"      # "W" | "A"
    ste: SteMode = "identity"
    require_aligned: bool = True         # TPU fast-path alignment (DESIGN §7.1)
    # Layers the paper never quantizes regardless of size:
    skip_embeddings: bool = True
    skip_norms: bool = True
    skip_final_head: bool = False        # LM head is FC — tiled when >= lambda

    def spec_for(
        self, shape: Sequence[int], *, kind: str = "dense"
    ) -> Optional[TileSpec]:
        """TileSpec for a weight, or None if the layer stays per-weight.

        kind in {"dense", "conv", "embedding", "norm", "head"}.
        """
        if self.mode != TBN:
            return None
        if kind == "embedding" and self.skip_embeddings:
            return None
        if kind == "norm" and self.skip_norms:
            return None
        if kind == "head" and self.skip_final_head:
            return None
        return plan_tiling(
            shape,
            p=self.p,
            min_size=self.min_size,
            alpha_mode=self.alpha_mode,
            alpha_source=self.alpha_source,
            ste=self.ste,
            require_aligned=self.require_aligned,
        )

    def binarize(self, kind: str = "dense") -> bool:
        """Whether a non-tiled layer is binarized (BWNN baseline)."""
        if self.mode == FP32:
            return False
        if kind in ("embedding", "norm"):
            return False
        return True


def fp32_policy() -> TBNPolicy:
    return TBNPolicy(mode=FP32, p=1)


def bwnn_policy(alpha_mode: AlphaMode = "layer") -> TBNPolicy:
    return TBNPolicy(mode=BWNN, p=1, alpha_mode=alpha_mode)


def tbn_policy(p: int = 4, **kw) -> TBNPolicy:
    return TBNPolicy(mode=TBN, p=p, **kw)

"""Bit-width / parameter / bit-ops accounting (paper Tables 1-5).

Accounting policy (matches the paper's):
  * universe = binarizable parameters only (conv + fully-connected weights;
    biases, norm scales and embeddings are excluded — "We do not consider
    bias parameters").
  * full-precision row: 32 bits per parameter in the universe.
  * BWNN row: 1 bit per parameter (+ 32 per alpha scalar).
  * TBN_p row: q bits + 32 * n_alpha per tiled layer; un-tiled binarizable
    layers (below lambda) contribute 1 bit per parameter.
  * "savings" column = bits(BWNN) / bits(TBN) — the blue numbers of Table 1.

Bit-ops (Table 2): one MAC against a binary weight = 1 bit-op. Tiled layers
with aligned tiles execute only 1/p of their MACs (replicated output
channels / rows are computed once and broadcast).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.policy import TBNPolicy
from repro.core.tiling import TileSpec


@dataclasses.dataclass
class LayerRecord:
    """One quantizable layer's accounting entry."""

    name: str
    kind: str                      # dense | conv | embedding | norm | head
    shape: Tuple[int, ...]
    spec: Optional[TileSpec]       # None => not tiled
    binarized: bool                # BWNN'd when not tiled
    macs: int = 0                  # multiply-accumulates per forward pass

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))

    def stored_bits(self) -> int:
        if self.spec is not None:
            return self.spec.stored_bits
        if self.binarized:
            return self.n + 32  # + one XNOR-style layer alpha
        return 32 * self.n

    def bitops(self) -> float:
        if self.spec is not None and self.spec.aligned_rows:
            return self.macs / self.spec.p
        return float(self.macs)


@dataclasses.dataclass
class BitsReport:
    layers: List[LayerRecord]

    @property
    def universe_params(self) -> int:
        """Binarizable parameter count (the paper's #Params denominator)."""
        return sum(r.n for r in self.layers if r.kind in ("dense", "conv", "head"))

    def total_bits(self) -> int:
        return sum(r.stored_bits() for r in self.layers if r.kind in ("dense", "conv", "head"))

    def mbit(self) -> float:
        return self.total_bits() / 1e6

    def bits_per_param(self) -> float:
        u = self.universe_params
        return self.total_bits() / u if u else 0.0

    def savings_vs_binary(self) -> float:
        """The paper's blue 'savings' factor: 1-bit model bits / our bits."""
        u = self.universe_params
        return u / self.total_bits() if self.total_bits() else 0.0

    def total_bitops(self) -> float:
        return sum(r.bitops() for r in self.layers if r.kind in ("dense", "conv", "head"))

    def rows(self) -> List[dict]:
        return [
            dict(
                name=r.name,
                kind=r.kind,
                shape=list(r.shape),
                params=r.n,
                tiled=r.spec is not None,
                p=(r.spec.p if r.spec else 1),
                q=(r.spec.q if r.spec else None),
                stored_bits=r.stored_bits(),
                macs=r.macs,
                bitops=r.bitops(),
            )
            for r in self.layers
        ]

    def summary(self, name: str = "") -> dict:
        return dict(
            model=name,
            universe_params=self.universe_params,
            mbit=round(self.mbit(), 3),
            bits_per_param=round(self.bits_per_param(), 4),
            savings_vs_binary=round(self.savings_vs_binary(), 2),
            gbitops=round(self.total_bitops() / 1e9, 4),
        )


class LayerLedger:
    """Collected while a model instantiates its layers under a TBNPolicy."""

    def __init__(self, policy: TBNPolicy):
        self.policy = policy
        self.records: List[LayerRecord] = []

    def note(
        self,
        name: str,
        shape: Tuple[int, ...],
        *,
        kind: str = "dense",
        spec: Optional[TileSpec] = None,
        macs: int = 0,
    ) -> None:
        self.records.append(
            LayerRecord(
                name=name,
                kind=kind,
                shape=tuple(int(d) for d in shape),
                spec=spec,
                binarized=self.policy.binarize(kind) and spec is None,
                macs=int(macs),
            )
        )

    def report(self) -> BitsReport:
        return BitsReport(list(self.records))

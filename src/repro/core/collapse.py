"""Beyond-paper: algebraic collapse of tile replication through ReLU MLPs.

alpha scalars are L1 means, hence non-negative, so for any positive-
homogeneous activation phi (ReLU, leaky-ReLU, identity):

    phi(kron(alpha, u)) = kron(alpha, phi(u))

and a subsequent (dense, possibly tiled) layer W2 absorbs the replication
through its contraction:

    W2 @ kron(alpha, u) = (sum_i alpha_i * W2[:, i*r:(i+1)*r]) @ u

So a chain  x -> TiledDense(W1) -> relu -> Dense/TiledDense(W2) -> ...
never needs the p-replicated activations: each layer passes the *unique*
r-dim activation forward and the consumer pre-folds alpha into its own
weight columns once at load time. End-to-end this removes the p× FLOP and
activation-memory overhead that the paper's kernel only removes for weight
*storage*. (See DESIGN.md §2.)

Only the last tiled layer before a non-homogeneous op (softmax head, norm,
GELU) must materialize the replication.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.tiling import TileSpec


def fold_consumer_weight(
    w2: jax.Array, alpha: jax.Array, producer_spec: TileSpec
) -> jax.Array:
    """Pre-fold a consumer weight (n_out2, n_out1) across the producer's tiles.

    Returns (n_out2, r) where r = n_out1 / p:   w2_folded = sum_i alpha_i * W2[:, blk_i].
    Works for alpha_mode "layer" (scalar broadcast) and "tile".
    """
    p = producer_spec.p
    r = producer_spec.rows_per_tile
    n_out2 = w2.shape[0]
    blocks = w2.reshape(n_out2, p, r)
    if producer_spec.alpha_mode == "layer":
        return alpha.reshape(()) * blocks.sum(axis=1)
    return jnp.einsum("opr,p->or", blocks, alpha)


def collapsed_chain_reference(
    x: jax.Array,
    t1: jax.Array,
    alpha1: jax.Array,
    spec1: TileSpec,
    w2: jax.Array,
) -> jax.Array:
    """Oracle: relu(x @ W1_hat^T) @ W2^T computed without replication."""
    n_in = spec1.n // spec1.shape[0]
    r = spec1.rows_per_tile
    tm = t1.reshape(r, n_in)
    u = jax.nn.relu(jnp.einsum("...k,rk->...r", x, tm))  # unique activations
    w2f = fold_consumer_weight(w2, alpha1, spec1)         # (n_out2, r)
    return jnp.einsum("...r,or->...o", u, w2f)

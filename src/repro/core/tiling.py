"""Core Tiled Bit Network (TBN) transform — Gorbett et al., CIKM 2024.

A layer weight tensor ``W`` with ``N`` elements is compressed by a factor
``p`` (``N = p * q``):

  1. reshape  ``W -> W* in R^{p x q}``            (Eq. 1)
  2. aggregate ``s = sum_i W*[i, :]  in R^q``      (Eq. 2)
  3. binarize ``t = sign(s) in {-1,+1}^q``         (Eq. 3, straight-through)
  4. tile     ``b = 1_p (x) t``, reshape to the original layer shape (Eq. 4-5)
  5. scale by ``alpha`` — one per layer (Eq. 7) or one per tile (Eq. 9),
     computed from ``|W|_1`` or from an auxiliary trained tensor ``A``.

After training only ``t`` (q bits) and the alpha scalars are stored.

Everything in this module is pure JAX and differentiable (via the STE);
the Pallas kernels in ``repro.kernels`` implement the same math for the
TPU fast path and are validated against these functions.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

AlphaMode = Literal["layer", "tile"]
AlphaSource = Literal["W", "A"]
SteMode = Literal["identity", "autodiff"]


# --------------------------------------------------------------------------
# Tile planning
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TileSpec:
    """Static description of how one weight tensor is tiled.

    Attributes:
      shape:      original weight tensor shape (row-major flattening order).
      p:          number of tile replicas (compression factor).
      q:          tile length in elements (``N = p * q``).
      aligned_rows: if the leading dim is divisible by ``p`` the tile covers
                  ``shape[0] // p`` complete leading rows/filters — the
                  structured case the TPU kernels exploit.
      alpha_mode: "layer" (Eq. 7) or "tile" (Eq. 9).
      alpha_source: "W" (reuse the master weight) or "A" (separate tensor).
      ste:        "identity" (paper Eq. 6: dL/dW := dL/dB elementwise) or
                  "autodiff" (STE on sign only; aggregation/tiling are
                  differentiated exactly).
    """

    shape: Tuple[int, ...]
    p: int
    q: int
    aligned_rows: bool
    alpha_mode: AlphaMode = "tile"
    alpha_source: AlphaSource = "A"
    ste: SteMode = "identity"

    @property
    def n(self) -> int:
        return int(np.prod(self.shape))

    @property
    def rows_per_tile(self) -> int:
        """Leading rows covered by one tile (aligned case only)."""
        if not self.aligned_rows:
            raise ValueError("rows_per_tile is only defined for aligned tiling")
        return self.shape[0] // self.p

    @property
    def n_alpha(self) -> int:
        return self.p if self.alpha_mode == "tile" else 1

    @property
    def stored_bits(self) -> int:
        """Bits stored at inference: q tile bits + fp32 alpha scalars."""
        return self.q + 32 * self.n_alpha

    @property
    def bits_per_param(self) -> float:
        return self.stored_bits / self.n


def plan_tiling(
    shape: Sequence[int],
    *,
    p: int,
    min_size: int = 64_000,
    alpha_mode: AlphaMode = "tile",
    alpha_source: AlphaSource = "A",
    ste: SteMode = "identity",
    require_aligned: bool = False,
) -> Optional[TileSpec]:
    """Decide whether/how to tile a weight of ``shape``.

    Returns ``None`` when the layer stays binary-per-weight (BWNN): too small
    (the paper's lambda policy), ``p <= 1``, or ``p`` does not divide ``N``.

    When ``p`` does not divide the leading dim but does divide ``N`` the
    tiling is still legal (paper only requires ``p | N``) but unaligned —
    the fast TPU kernel refuses it unless ``require_aligned=False``.
    """
    shape = tuple(int(d) for d in shape)
    n = int(np.prod(shape))
    if p <= 1 or n < min_size:
        return None
    if n % p != 0:
        # Fall back to the largest divisor of N that is <= p (keeps the
        # config usable instead of silently skipping the layer).
        cand = [d for d in range(p, 1, -1) if n % d == 0]
        if not cand:
            return None
        p = cand[0]
    aligned = shape[0] % p == 0
    if require_aligned and not aligned:
        return None
    return TileSpec(
        shape=shape,
        p=p,
        q=n // p,
        aligned_rows=aligned,
        alpha_mode=alpha_mode,
        alpha_source=alpha_source,
        ste=ste,
    )


# --------------------------------------------------------------------------
# Straight-through binarization
# --------------------------------------------------------------------------
def _sign_pm1(x: jax.Array) -> jax.Array:
    """Paper Eq. 3: +1 where x > 0 else -1 (zero maps to -1)."""
    return jnp.where(x > 0, 1.0, -1.0).astype(x.dtype)


@jax.custom_vjp
def _ste_sign(x: jax.Array) -> jax.Array:
    return _sign_pm1(x)


def _ste_sign_fwd(x):
    return _sign_pm1(x), None


def _ste_sign_bwd(_, g):
    return (g,)


_ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


def aggregate(w: jax.Array, spec: TileSpec) -> jax.Array:
    """Eq. 1-2: reshape to (p, q) and sum over the replica axis -> s (q,)."""
    return w.reshape(spec.p, spec.q).sum(axis=0)


def tile_vector(w: jax.Array, spec: TileSpec) -> jax.Array:
    """Eq. 3: the learnable binary tile t in {-1,+1}^q (no gradient path)."""
    return _sign_pm1(aggregate(w, spec))


def _construct_binary_impl(w: jax.Array, spec: TileSpec) -> jax.Array:
    s = aggregate(w, spec)
    t = _ste_sign(s)
    # Eq. 4-5: b = 1_p (x) t, reshaped back to the tensor shape.
    return jnp.broadcast_to(t[None, :], (spec.p, spec.q)).reshape(spec.shape)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _construct_binary_identity(w: jax.Array, spec: TileSpec) -> jax.Array:
    return _construct_binary_impl(w, spec)


def _cbi_fwd_full(w, spec):
    # No residuals needed: the backward pass is an elementwise identity.
    return _construct_binary_impl(w, spec), None


def _cbi_bwd(spec, _, g):
    # Paper Eq. 6: dy/dW ~= dy/dB — the gradient is passed through the
    # whole threshold/tile/reshape pipeline unchanged, elementwise.
    return (g.reshape(spec.shape),)


_construct_binary_identity.defvjp(_cbi_fwd_full, _cbi_bwd)


def construct_binary(w: jax.Array, spec: TileSpec) -> jax.Array:
    """Full-shape binary tensor B (±1) from master weight W, with STE.

    ``spec.ste == "identity"`` reproduces the paper's customized autograd
    module (backward passes gradients through unchanged). ``"autodiff"``
    applies the STE to the sign only and differentiates the aggregation and
    tiling exactly (each master element then receives the *summed* gradient
    of all replicas of its tile slot).
    """
    if w.shape != spec.shape:
        raise ValueError(f"weight shape {w.shape} != spec shape {spec.shape}")
    if spec.ste == "identity":
        return _construct_binary_identity(w, spec)
    return _construct_binary_impl(w, spec)


# --------------------------------------------------------------------------
# Alpha scalars
# --------------------------------------------------------------------------
def compute_alpha(src: jax.Array, spec: TileSpec) -> jax.Array:
    """Optimal XNOR-style scaling (Eq. 7 / Eq. 9).

    Eq. 9's ``(q x p)`` reshape is a typo in the paper — Figure 4 and
    Algorithm 1 make clear each alpha_i belongs to the i-th *contiguous*
    tile of the flattened tensor, so we reduce the (p, q) reshape along q.

    Returns shape (1,) for mode "layer" or (p,) for mode "tile".
    Differentiable (the |.|_1 mean); gradients flow to the source tensor.
    """
    if spec.alpha_mode == "layer":
        return jnp.mean(jnp.abs(src)).reshape(1)
    return jnp.mean(jnp.abs(src.reshape(spec.p, spec.q)), axis=1)


def expand_alpha(alpha: jax.Array, spec: TileSpec) -> jax.Array:
    """Broadcast alpha scalars over the full tensor shape."""
    if spec.alpha_mode == "layer":
        col = alpha.reshape(1, 1)
    else:
        col = alpha[:, None]
    return jnp.broadcast_to(col, (spec.p, spec.q)).reshape(spec.shape)


def tiled_weight(
    w: jax.Array,
    spec: TileSpec,
    a: Optional[jax.Array] = None,
    dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """The effective training-time weight  B_hat = alpha ⊙ B  (full shape).

    ``a`` must be given when ``spec.alpha_source == "A"``.
    This is the paper-faithful forward; the fused Pallas construction kernel
    (`repro.kernels.tile_construct`) computes the same (t, alpha) without
    materializing B_hat in HBM.
    """
    b = construct_binary(w, spec)
    src = a if spec.alpha_source == "A" else w
    if src is None:
        raise ValueError("alpha_source='A' requires the auxiliary tensor A")
    alpha = compute_alpha(src, spec)
    bhat = b * expand_alpha(alpha, spec)
    if dtype is not None:
        bhat = bhat.astype(dtype)
    return bhat


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def _construct_rows_identity(w: jax.Array, p: int) -> jax.Array:
    """Row-aligned binary construction by AXIS sums (no flat reshape).

    For aligned tiling (p | n_out) this is bit-identical to
    ``construct_binary`` but expressed as a sum over a real tensor axis —
    under GSPMD the aggregation becomes a cheap partial-sum all-reduce of
    the (p-fold smaller) tile instead of an all-gather of the full weight.
    The tile is the ONLY thing that crosses the network: a beyond-paper
    "communicate tiles, not weights" optimization (EXPERIMENTS.md §Perf).
    Supports leading batch dims (expert banks: (E, n_out, n_in))."""
    *lead, R, D = w.shape
    r = R // p
    s = w.reshape(*lead, p, r, D).sum(axis=-3)
    t = _sign_pm1(s)
    b = jnp.broadcast_to(
        t[..., None, :, :], (*lead, p, r, D)
    )
    return b.reshape(*lead, R, D)


def _cri_fwd(w, p):
    return _construct_rows_identity(w, p), None


def _cri_bwd(p, _, g):
    return (g,)    # paper Eq. 6: identity straight-through


_construct_rows_identity.defvjp(_cri_fwd, _cri_bwd)


def tiled_weight_rows(
    w: jax.Array,
    spec: TileSpec,
    a: Optional[jax.Array] = None,
    dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """``tiled_weight`` for row-aligned specs via axis ops only (see
    ``_construct_rows_identity``). Handles leading batch dims; exact-match
    oracle: tests/test_property.py::test_rows_equals_flat."""
    if not spec.aligned_rows:
        raise ValueError("tiled_weight_rows needs row-aligned tiling")
    *lead, R, D = w.shape
    p, r = spec.p, spec.rows_per_tile
    b = _construct_rows_identity(w, p)
    src = a if (spec.alpha_source == "A" and a is not None) else w
    if spec.alpha_mode == "layer":
        alpha = jnp.mean(jnp.abs(src), axis=(-1, -2), keepdims=True)
        bhat = b * alpha
    else:
        alpha = jnp.mean(
            jnp.abs(src.reshape(*lead, p, r, D)), axis=(-1, -2)
        )  # (*lead, p)
        bhat = (
            b.reshape(*lead, p, r, D) * alpha[..., None, None]
        ).reshape(*lead, R, D)
    if dtype is not None:
        bhat = bhat.astype(dtype)
    return bhat


# --------------------------------------------------------------------------
# Conv tiling plan — how the flat (p, q) tiling lands on an OIHW weight
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ConvTilePlan:
    """Structured view of an aligned tiling of an OIHW conv weight.

    For ``W (c_out, c_in, kh, kw)`` with ``p | c_out`` the flat row-major
    (p, q) tiling covers ``r = c_out / p`` *complete* filters per tile
    (q = r * c_in * kh * kw), so replica ``a`` of the tile is filters
    ``a*r .. (a+1)*r - 1``. That is the structure the tiled conv inference
    kernel exploits: it computes ``u = conv(x, T)`` against the r-filter
    tile bank once and broadcasts over the p replicas with per-tile alpha —
    exactly the conv analogue of ``tiled_matmul_reference``.

    The kernel consumes the tile in "conv layout": per kernel position
    (i, j), the (r, c_in) cross-section packed along channels into int32
    lanes — shape ``(kh*kw, r, ceil(c_in/32))`` (see
    ``repro.core.packing.pack_conv_tile``).
    """

    spec: TileSpec

    def __post_init__(self):
        if len(self.spec.shape) != 4:
            raise ValueError(f"conv plan needs a 4-D weight, got {self.spec.shape}")
        if not self.spec.aligned_rows:
            raise ValueError("conv plan needs p | c_out (aligned tiling)")

    @property
    def c_out(self) -> int:
        return self.spec.shape[0]

    @property
    def c_in(self) -> int:
        return self.spec.shape[1]

    @property
    def kernel(self) -> Tuple[int, int]:
        return (self.spec.shape[2], self.spec.shape[3])

    @property
    def r(self) -> int:
        """Filters covered by one tile."""
        return self.spec.rows_per_tile

    @property
    def kk(self) -> int:
        """Patch length: elements of one filter (= im2col contraction dim)."""
        return self.spec.n // self.spec.shape[0]

    @property
    def positions(self) -> int:
        return self.spec.shape[2] * self.spec.shape[3]

    def packed_shape(self) -> Tuple[int, int, int]:
        """Shipped conv-layout tile shape: (kh*kw, r, ceil(c_in/32)) int32."""
        from repro.core.packing import packed_len

        return (self.positions, self.r, packed_len(self.c_in))


def plan_conv_tiling(spec: Optional[TileSpec]) -> Optional[ConvTilePlan]:
    """ConvTilePlan for a conv TileSpec, or None when the fast path does not
    apply (no tiling / not 4-D / unaligned — the layer then falls back to
    dense-weight reconstruction at serve time)."""
    if spec is None or len(spec.shape) != 4 or not spec.aligned_rows:
        return None
    return ConvTilePlan(spec=spec)


def conv_tile_bank(t: jax.Array, plan: ConvTilePlan, dtype=jnp.float32) -> jax.Array:
    """View the flat tile t (q,) as an r-filter OIHW bank (r, c_in, kh, kw).

    This is the p-fold-smaller conv kernel the tiled inference path runs;
    the effective dense weight is its block replication with per-tile alpha.
    """
    kh, kw = plan.kernel
    return t.reshape(plan.r, plan.c_in, kh, kw).astype(dtype)


# --------------------------------------------------------------------------
# Inference-form parameters (what actually ships)
# --------------------------------------------------------------------------
def export_tile(
    w: jax.Array, spec: TileSpec, a: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """(tile t ∈ ±1 (q,), alpha (n_alpha,)) — the stored representation."""
    t = tile_vector(w, spec)
    src = a if spec.alpha_source == "A" else w
    alpha = compute_alpha(jax.lax.stop_gradient(src), spec)
    return jax.lax.stop_gradient(t), alpha


def reconstruct_from_tile(
    t: jax.Array, alpha: jax.Array, spec: TileSpec, dtype=jnp.float32
) -> jax.Array:
    """Rebuild the dense effective weight from (t, alpha) — reference path."""
    b = jnp.broadcast_to(t[None, :], (spec.p, spec.q)).reshape(spec.shape)
    return (b * expand_alpha(alpha, spec)).astype(dtype)


# --------------------------------------------------------------------------
# Structured (aligned) fast-math helpers — the TPU-native formulation
# --------------------------------------------------------------------------
def tile_as_matrix(t: jax.Array, spec: TileSpec) -> jax.Array:
    """View the q-bit tile as an (r, trailing) matrix of ±1 (aligned case).

    For a dense weight stored (n_out, n_in) with p | n_out, the effective
    weight is the block-row replication of this matrix with per-block alpha:
        W_hat = kron(alpha, T)   (alpha as a (p,1) column when mode="tile")
    """
    if len(spec.shape) < 2:
        raise ValueError("tile_as_matrix needs a >=2-D weight")
    if not spec.aligned_rows:
        raise ValueError("unaligned tiling cannot be viewed as a row block")
    r = spec.rows_per_tile
    trailing = spec.n // spec.shape[0]
    return t.reshape(r, trailing)


def tiled_matmul_reference(
    x: jax.Array, t: jax.Array, alpha: jax.Array, spec: TileSpec
) -> jax.Array:
    """y = x @ W_hat^T computed the tile-reuse way (aligned dense layers).

    x: (..., n_in); weight logical shape (n_out, n_in); tile covers
    r = n_out/p rows. Computes u = x @ T^T once (p-fold fewer FLOPs) and
    broadcasts with per-tile alpha:  y[..., i*r:(i+1)*r] = alpha_i * u.

    This is the oracle for ``repro.kernels.tiled_matmul``.
    """
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    if x.shape[-1] != n_in:
        raise ValueError(f"x trailing dim {x.shape[-1]} != n_in {n_in}")
    r = spec.rows_per_tile
    tm = t.reshape(r, n_in)  # one tile, as r complete weight rows
    u = jnp.einsum("...k,rk->...r", x, tm)  # (..., r)
    if spec.alpha_mode == "layer":
        y = jnp.broadcast_to(
            u[..., None, :], (*u.shape[:-1], spec.p, r)
        ) * alpha.reshape(1)
    else:
        y = u[..., None, :] * alpha.reshape(
            (1,) * (u.ndim - 1) + (spec.p, 1)
        )
        y = jnp.broadcast_to(y, (*u.shape[:-1], spec.p, r))
    return y.reshape(*x.shape[:-1], n_out)


def fold_inputs_reference(
    x: jax.Array, t: jax.Array, alpha: jax.Array, spec: TileSpec
) -> jax.Array:
    """y = x @ W_hat for weights stored (n_in, n_out) with p | n_in.

    The replication then lies along the *contraction* dim, so the p blocks
    of x can be pre-combined:  y = (sum_i alpha_i * x[..., i*r:(i+1)*r]) @ T.
    p-fold fewer matmul FLOPs with NO output replication — used by the
    beyond-paper "input-folded" serving variant.
    """
    n_in = spec.shape[0]
    r = spec.rows_per_tile
    n_out = spec.n // n_in
    xb = x.reshape(*x.shape[:-1], spec.p, r)
    if spec.alpha_mode == "layer":
        folded = alpha.reshape(1) * xb.sum(axis=-2)
    else:
        folded = jnp.einsum("...pr,p->...r", xb, alpha)
    tm = t.reshape(r, n_out)
    return jnp.einsum("...r,rn->...n", folded, tm)

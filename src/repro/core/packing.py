"""Bit packing for tile vectors.

Tiles are ±1 vectors of length q; on disk / in HBM they live as int32 lanes
(TPU's native 32-bit word — int32 loads vectorize cleanly into VREGs, and
the Pallas kernel unpacks 32 bits per lane with shift/and on the VPU).

Bit order: bit j of word i encodes element ``i*32 + j`` (little-endian
within the word). +1 -> bit 1, -1 -> bit 0. q is padded to a multiple of 32
with zero bits (consumers slice back to q).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

LANE_BITS = 32


def packed_len(q: int) -> int:
    return (q + LANE_BITS - 1) // LANE_BITS


def pack_bits(t: jax.Array) -> jax.Array:
    """±1 (or {0,1}) vector (q,) -> int32 (ceil(q/32),)."""
    q = t.shape[-1]
    bits = (t > 0).astype(jnp.uint32)
    pad = packed_len(q) * LANE_BITS - q
    if pad:
        bits = jnp.concatenate([bits, jnp.zeros(t.shape[:-1] + (pad,), jnp.uint32)], axis=-1)
    words = bits.reshape(*t.shape[:-1], packed_len(q), LANE_BITS)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    packed = (words << shifts).sum(axis=-1, dtype=jnp.uint32)
    return packed.astype(jnp.int32)


def unpack_bits(packed: jax.Array, q: int, dtype=jnp.float32) -> jax.Array:
    """int32 (ceil(q/32),) -> ±1 vector (q,) of ``dtype``."""
    w = packed.astype(jnp.uint32)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits = (w[..., :, None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(*packed.shape[:-1], packed.shape[-1] * LANE_BITS)[..., :q]
    return (flat.astype(jnp.int8) * 2 - 1).astype(dtype)


def pack_tile_matrix(tm: jax.Array) -> jax.Array:
    """(r, n) ±1 tile matrix -> (r, ceil(n/32)) int32, packed per row.

    Row-wise packing keeps each weight row's bits contiguous so the matmul
    kernel can unpack a (block_r, block_k) weight block from
    (block_r, block_k/32) lanes without crossing rows.
    """
    return pack_bits(tm)


def unpack_tile_matrix(packed: jax.Array, n: int, dtype=jnp.float32) -> jax.Array:
    return unpack_bits(packed, n, dtype)


def pack_conv_tile(t: jax.Array, r: int, c_in: int, kh: int, kw: int) -> jax.Array:
    """Flat conv tile (q,) ±1 -> (kh*kw, r, ceil(c_in/32)) int32 ("conv layout").

    q = r * c_in * kh * kw, flat in OIHW row-major order (r filters). The
    tiled conv kernel contracts one (i, j) kernel position per grid step, so
    the shipped layout groups each position's (r, c_in) cross-section and
    packs it along channels — the kernel unpacks a (block_r, c_in) ±1 block
    from int32 lanes without crossing kernel positions. Rows are padded to
    whole words with zero bits (consumers pad activations with zero
    channels, so the -1 values those bits unpack to contribute nothing).
    """
    bank = t.reshape(r, c_in, kh, kw)
    by_pos = bank.transpose(2, 3, 0, 1).reshape(kh * kw, r, c_in)
    return pack_bits(by_pos)


def unpack_conv_tile(
    packed: jax.Array, r: int, c_in: int, kh: int, kw: int, dtype=jnp.float32
) -> jax.Array:
    """(kh*kw, r, ceil(c_in/32)) int32 -> OIHW tile bank (r, c_in, kh, kw) ±1."""
    by_pos = unpack_bits(packed, c_in, dtype=dtype)  # (kh*kw, r, c_in)
    return by_pos.reshape(kh, kw, r, c_in).transpose(2, 3, 0, 1)


def storage_bytes(q: int, n_alpha: int) -> int:
    """Exact shipped bytes for one tiled layer (tile lanes + fp32 alphas)."""
    return packed_len(q) * 4 + 4 * n_alpha


def pack_bits_np(t: np.ndarray) -> np.ndarray:
    """NumPy twin of pack_bits (checkpoint export path, no device needed)."""
    q = t.shape[-1]
    bits = (t > 0).astype(np.uint32)
    pad = packed_len(q) * LANE_BITS - q
    if pad:
        bits = np.concatenate([bits, np.zeros(t.shape[:-1] + (pad,), np.uint32)], axis=-1)
    words = bits.reshape(*t.shape[:-1], packed_len(q), LANE_BITS)
    shifts = np.arange(LANE_BITS, dtype=np.uint32)
    return (words << shifts).sum(axis=-1, dtype=np.uint32).astype(np.int32)

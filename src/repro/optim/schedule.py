"""LR schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_with_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return sched

"""SGD + momentum + weight decay (the paper's CNN training recipe)."""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


class SGDState(NamedTuple):
    step: jax.Array
    momentum: dict


def sgd_momentum(
    lr: Callable | float, momentum: float = 0.9, weight_decay: float = 0.0
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        )

    def update(grads, state: SGDState, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m = momentum * m + g
            return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.momentum)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (
            treedef.unflatten([o[0] for o in out]),
            SGDState(step=step, momentum=treedef.unflatten([o[1] for o in out])),
        )

    return Optimizer(init=init, update=update)

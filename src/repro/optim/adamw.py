"""AdamW with pytree state. State shards exactly like the params
(same logical axes), giving ZeRO-style sharded moments under FSDP rules."""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(
    lr: Callable[[jax.Array], jax.Array] | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, update=update)

"""Optimizers + schedules (self-contained; no optax on this box)."""
from repro.optim.adamw import adamw
from repro.optim.sgd import sgd_momentum
from repro.optim.schedule import constant, cosine_with_warmup
from repro.optim.clip import clip_by_global_norm

__all__ = [
    "adamw",
    "sgd_momentum",
    "constant",
    "cosine_with_warmup",
    "clip_by_global_norm",
]

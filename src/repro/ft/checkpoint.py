"""Sharded npz+json checkpoints with async save and elastic restore.

Layout (one directory per step, atomic via tmp-dir rename):

    <root>/step_00000420/
        manifest.json      tree structure, per-leaf shape/dtype, metadata
        arrays.npz         one entry per leaf, keyed by "/"-joined path

Restore is *mesh-agnostic*: leaves come back as host numpy and are placed
with ``place(tree, shardings)`` onto whatever mesh the restarted job has —
the elastic path (fewer/more chips than the writer) is just a different
shardings tree. A leaf whose stored shape matches is device_put with the
new sharding; GSPMD handles the re-slice.

Async save copies to host synchronously (cheap; off-device transfer is the
only step that must see consistent values) and does the serialization +
fsync on a background thread so the train loop never blocks on disk.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
_SEP = "/"


# ---------------------------------------------------------------------------
# pytree <-> flat dict-of-arrays
# ---------------------------------------------------------------------------
def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    out = []
    for path, leaf in leaves:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
            else:
                keys.append(str(p))
        out.append((_SEP.join(keys), leaf))
    return out


def _tree_def(tree):
    return jax.tree_util.tree_structure(tree)


def tree_from_flat(treedef, flat: Dict[str, np.ndarray]):
    """Rebuild a pytree given its treedef and the path->array dict."""
    paths = [k for k, _ in _flatten_with_paths(jax.tree_util.tree_unflatten(
        treedef, list(range(treedef.num_leaves))))]
    # map leaf order -> path names by flattening an index tree
    leaves = [flat[p] for p in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------
def save_checkpoint(
    root: os.PathLike,
    step: int,
    tree,
    *,
    metadata: Optional[Dict[str, Any]] = None,
) -> pathlib.Path:
    """Synchronous atomic save. Returns the final checkpoint directory."""
    root = pathlib.Path(root)
    root.mkdir(parents=True, exist_ok=True)
    flat = _flatten_with_paths(tree)
    host = [(k, np.asarray(jax.device_get(v))) for k, v in flat]
    return _write(root, step, host, metadata or {})


def _write(root: pathlib.Path, step: int, host, metadata) -> pathlib.Path:
    final = root / f"step_{step:08d}"
    tmp = pathlib.Path(
        tempfile.mkdtemp(prefix=f".tmp_step_{step:08d}_", dir=root)
    )
    try:
        manifest = {
            "step": int(step),
            "format": 1,
            "leaves": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in host
            },
            "metadata": metadata,
        }
        np.savez(tmp / "arrays.npz", **{k: v for k, v in host})
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def available_steps(root: os.PathLike) -> List[int]:
    root = pathlib.Path(root)
    if not root.exists():
        return []
    out = []
    for d in root.iterdir():
        m = _STEP_RE.match(d.name)
        if m and (d / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(root: os.PathLike) -> Optional[int]:
    steps = available_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(
    root: os.PathLike, step: Optional[int] = None
) -> Tuple[int, Dict[str, np.ndarray], Dict[str, Any]]:
    """-> (step, path->array dict, metadata). Raises if nothing to restore."""
    root = pathlib.Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    with np.load(d / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    for k, info in manifest["leaves"].items():
        got = flat[k]
        if list(got.shape) != info["shape"]:
            raise ValueError(
                f"leaf {k}: stored shape {list(got.shape)} != manifest {info['shape']}"
            )
    return int(manifest["step"]), flat, manifest.get("metadata", {})


def restore_into(template, root: os.PathLike, step: Optional[int] = None):
    """Restore into the structure of ``template`` (shapes must match)."""
    step, flat, _ = restore_checkpoint(root, step)
    paths = [k for k, _ in _flatten_with_paths(template)]
    missing = [p for p in paths if p not in flat]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
    leaves = [flat[p] for p in paths]
    treedef = _tree_def(template)
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


def place(tree, shardings):
    """device_put every leaf with its (possibly new-mesh) sharding."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings
    )


# ---------------------------------------------------------------------------
# manager: async save, retention, restore-latest
# ---------------------------------------------------------------------------
class CheckpointManager:
    """Save-every-N with bounded retention and an async writer thread.

    The device->host copy happens on the caller's thread (values must be
    consistent with the step being saved); npz serialization and directory
    swap happen on the writer thread. ``wait()`` drains pending writes —
    call it before reading ``latest_step`` in tests and at shutdown.
    """

    def __init__(
        self,
        root: os.PathLike,
        *,
        save_every: int = 100,
        max_to_keep: int = 3,
        async_save: bool = True,
    ):
        self.root = pathlib.Path(root)
        self.save_every = save_every
        self.max_to_keep = max_to_keep
        self.async_save = async_save
        self._pending: List[threading.Thread] = []
        self._lock = threading.Lock()
        self._errors: List[BaseException] = []

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree, *, metadata=None, force: bool = False):
        if not force and not self.should_save(step):
            return None
        self.root.mkdir(parents=True, exist_ok=True)
        host = [
            (k, np.asarray(jax.device_get(v)))
            for k, v in _flatten_with_paths(tree)
        ]
        meta = dict(metadata or {})
        if not self.async_save:
            _write(self.root, step, host, meta)
            self._gc()
            return step

        def _job():
            try:
                _write(self.root, step, host, meta)
                self._gc()
            except BaseException as e:  # surfaced by wait()
                with self._lock:
                    self._errors.append(e)

        t = threading.Thread(target=_job, daemon=True)
        with self._lock:
            self._pending = [p for p in self._pending if p.is_alive()]
            self._pending.append(t)
        t.start()
        return step

    def wait(self):
        with self._lock:
            pending = list(self._pending)
        for t in pending:
            t.join()
        with self._lock:
            self._pending.clear()
            if self._errors:
                err = self._errors[0]
                self._errors.clear()
                raise err

    def _gc(self):
        steps = available_steps(self.root)
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.root)

    def restore_into(self, template, step: Optional[int] = None):
        return restore_into(template, self.root, step)

from repro.ft.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ft.recovery import RecoveryManager, elastic_restore
from repro.ft.watchdog import HeartbeatTable, StepWatchdog

__all__ = [
    "CheckpointManager",
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "RecoveryManager",
    "elastic_restore",
    "StepWatchdog",
    "HeartbeatTable",
]

"""Straggler / hang detection.

Two pure-python primitives (no device state, unit-testable):

  StepWatchdog    — per-step wall times on this host; flags a step as a
                    straggler when it exceeds ``threshold x`` the rolling
                    median, and as a *hang* when a deadline passes with no
                    completion (checked from any thread via ``check``).
  HeartbeatTable  — host-id -> last-heartbeat bookkeeping for the launcher;
                    ``stragglers(now)`` returns hosts silent for more than
                    ``timeout`` seconds (the coordinator evicts them and
                    triggers an elastic restart, see ft.recovery).

At 1000+ node scale the heartbeat stream is what actually exists (per-host
step barriers are too expensive); the watchdog gives per-host early signal
so slow HBM/ICI links surface before they gate the collective.
"""
from __future__ import annotations

import statistics
import threading
import time
from typing import Dict, List, Optional, Tuple


class StepWatchdog:
    def __init__(
        self,
        *,
        window: int = 32,
        threshold: float = 3.0,
        hang_timeout_s: float = 600.0,
        clock=time.monotonic,
    ):
        self.window = window
        self.threshold = threshold
        self.hang_timeout_s = hang_timeout_s
        self._clock = clock
        self._durations: List[float] = []
        self._started_at: Optional[float] = None
        self._lock = threading.Lock()
        self.straggler_steps: List[Tuple[int, float, float]] = []
        self._step = 0

    def start_step(self):
        with self._lock:
            self._started_at = self._clock()

    def end_step(self) -> Tuple[float, bool]:
        """-> (duration, was_straggler)."""
        with self._lock:
            assert self._started_at is not None, "end_step without start_step"
            dur = self._clock() - self._started_at
            self._started_at = None
            med = (
                statistics.median(self._durations)
                if self._durations
                else None
            )
            slow = med is not None and dur > self.threshold * med
            if slow:
                self.straggler_steps.append((self._step, dur, med))
            self._durations.append(dur)
            if len(self._durations) > self.window:
                self._durations.pop(0)
            self._step += 1
            return dur, slow

    def check(self) -> Optional[float]:
        """If a step has been running past the hang deadline, return its
        age in seconds (else None). Safe from a monitor thread."""
        with self._lock:
            if self._started_at is None:
                return None
            age = self._clock() - self._started_at
            return age if age > self.hang_timeout_s else None

    @property
    def median(self) -> Optional[float]:
        with self._lock:
            return statistics.median(self._durations) if self._durations else None


class HeartbeatTable:
    def __init__(self, *, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._lock = threading.Lock()

    def beat(self, host: str, at: Optional[float] = None):
        with self._lock:
            self._last[host] = self._clock() if at is None else at

    def stragglers(self, now: Optional[float] = None) -> List[str]:
        now = self._clock() if now is None else now
        with self._lock:
            return sorted(
                h for h, t in self._last.items() if now - t > self.timeout_s
            )

    def evict(self, host: str):
        with self._lock:
            self._last.pop(host, None)

    @property
    def hosts(self) -> List[str]:
        with self._lock:
            return sorted(self._last)

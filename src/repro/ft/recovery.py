"""Recovery manager: checkpoint/restart with elastic mesh resharding.

The contract with the train loop:

    rm = RecoveryManager(ckpt, make_state=..., make_data=..., max_restarts=3)
    final_state = rm.run(step_fn, num_steps)

* ``make_state()`` builds a fresh TrainState (used on cold start).
* ``make_data(start_step)`` rebuilds the deterministic data iterator at an
  arbitrary step (repro.data.DataPipeline is (seed, step)-addressed, so a
  restart replays the exact stream).
* On any exception from ``step_fn`` the manager restores the latest
  checkpoint, rebuilds the iterator at that step, and resumes — up to
  ``max_restarts`` times. jax device errors and injected test faults take
  the same path.

``elastic_restore`` is the cross-mesh path: a checkpoint written on one
mesh is placed onto a *different* mesh (scale-down after eviction, or
scale-up after repair) by pairing host arrays with the new shardings.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional


from repro.ft.checkpoint import CheckpointManager, place, restore_into
from repro.ft.watchdog import StepWatchdog

log = logging.getLogger("repro.ft")


def elastic_restore(
    root,
    template,
    shardings,
    *,
    step: Optional[int] = None,
):
    """Restore a checkpoint onto (possibly) a different mesh.

    template: pytree of ShapeDtypeStructs/arrays matching what was saved.
    shardings: matching pytree of NamedShardings on the *new* mesh.
    -> (step, placed state)
    """
    step, host_tree = restore_into(template, root, step)
    return step, place(host_tree, shardings)


class RecoveryManager:
    def __init__(
        self,
        ckpt: CheckpointManager,
        *,
        make_state: Callable[[], Any],
        make_data: Callable[[int], Iterator],
        max_restarts: int = 3,
        watchdog: Optional[StepWatchdog] = None,
        shardings: Any = None,
        on_restart: Optional[Callable[[int, BaseException], None]] = None,
    ):
        self.ckpt = ckpt
        self.make_state = make_state
        self.make_data = make_data
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StepWatchdog()
        self.shardings = shardings
        self.on_restart = on_restart
        self.restarts = 0
        self.metrics_log: list = []

    # ------------------------------------------------------------------
    def _bootstrap(self):
        """Fresh state or latest checkpoint."""
        state = self.make_state()
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, state
        step, restored = self.ckpt.restore_into(state, latest)
        if self.shardings is not None:
            restored = place(restored, self.shardings)
        log.info("restored checkpoint at step %d", step)
        return step, restored

    def run(
        self,
        step_fn: Callable[[Any, Dict], Any],
        num_steps: int,
        *,
        hooks: Optional[Callable[[int, Any, Dict], None]] = None,
    ):
        """Run to ``num_steps`` global steps with restart-on-failure."""
        while True:
            try:
                return self._run_once(step_fn, num_steps, hooks)
            except KeyboardInterrupt:
                raise
            except BaseException as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    log.error("max restarts exceeded (%d)", self.max_restarts)
                    raise
                if self.on_restart is not None:
                    self.on_restart(self.restarts, e)
                log.warning(
                    "step failed (%s: %s); restart %d/%d from latest checkpoint",
                    type(e).__name__, e, self.restarts, self.max_restarts,
                )
                self.ckpt.wait()

    def _run_once(self, step_fn, num_steps, hooks):
        start_step, state = self._bootstrap()
        data = self.make_data(start_step)
        step = start_step
        try:
            for batch in data:
                if step >= num_steps:
                    break
                self.watchdog.start_step()
                state, metrics = step_fn(state, batch)
                dur, slow = self.watchdog.end_step()
                if slow:
                    log.warning("straggler step %d: %.3fs (median %.3fs)",
                                step, dur, self.watchdog.median)
                step += 1
                self.metrics_log.append((step, metrics))
                if hooks is not None:
                    hooks(step, state, metrics)
                self.ckpt.save(step, state, metadata={"wall": time.time()})
        finally:
            # always stop the prefetch thread — a restart would otherwise
            # leak one live producer per attempt, and a leaked thread inside
            # a jax call aborts the process at interpreter shutdown
            close = getattr(data, "close", None)
            if close is not None:
                close()
        self.ckpt.save(step, state, metadata={"wall": time.time()}, force=True)
        self.ckpt.wait()
        return state

"""Encoder-decoder backbone (seamless-m4t family).

Encoder consumes precomputed frame embeddings (speech frontend is a stub
per the assignment); decoder is causal with cross-attention to the encoder
memory. Both stacks are lax.scan'd segments with TBN-tileable projections.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.attention import Attention
from repro.nn.context import ModelContext
from repro.nn.embeddings import Embedding
from repro.nn.ffn import MLP
from repro.nn.linear import Dense
from repro.nn.norms import LayerNorm, RMSNorm


def _norm(cfg, ctx, name):
    cls = RMSNorm if cfg.norm == "rmsnorm" else LayerNorm
    return cls(cfg.d_model, ctx, name=name)


@dataclasses.dataclass
class EncBlock:
    cfg: ArchConfig
    ctx: ModelContext
    name: str = "enc"

    def __post_init__(self):
        cfg, c, d = self.cfg, self.ctx, self.cfg.d_model
        self.norm1 = _norm(cfg, c, f"{self.name}.norm1")
        self.attn = Attention(d, cfg.n_heads, cfg.n_kv, c, head_dim=cfg.head_dim,
                              name=f"{self.name}.attn", causal=False,
                              rope=cfg.rope_theta > 0, q_chunk=cfg.attn_chunk,
                              act_mode=cfg.attn_act)
        self.norm2 = _norm(cfg, c, f"{self.name}.norm2")
        self.ffn = MLP(d, cfg.d_ff, c, name=f"{self.name}.mlp",
                       gated=cfg.gated_mlp, activation=cfg.activation)

    def specs(self):
        return {"norm1": self.norm1.specs(), "attn": self.attn.specs(),
                "norm2": self.norm2.specs(), "ffn": self.ffn.specs()}

    def __call__(self, params, x, valid=None):
        # ``valid`` (B, S) masks padded frame columns in the serving path:
        # padded KEYS are excluded from every row's softmax (NEG_INF ->
        # exact-0 weight), so valid rows match an unpadded encode
        # byte-for-byte; padded QUERY rows produce garbage that position-
        # wise downstream ops never mix into valid rows.
        x = x + self.attn(params["attn"], self.norm1(params["norm1"], x),
                          kv_valid=valid)
        x = x + self.ffn(params["ffn"], self.norm2(params["norm2"], x))
        return logical_constraint(x, "act_batch", "act_res_seq", "act_embed")


@dataclasses.dataclass
class DecBlock:
    cfg: ArchConfig
    ctx: ModelContext
    name: str = "dec"

    def __post_init__(self):
        cfg, c, d = self.cfg, self.ctx, self.cfg.d_model
        self.norm1 = _norm(cfg, c, f"{self.name}.norm1")
        self.self_attn = Attention(d, cfg.n_heads, cfg.n_kv, c,
                                   head_dim=cfg.head_dim,
                                   name=f"{self.name}.self_attn", causal=True,
                                   rope=cfg.rope_theta > 0, q_chunk=cfg.attn_chunk,
                                   act_mode=cfg.attn_act)
        self.norm2 = _norm(cfg, c, f"{self.name}.norm2")
        self.cross_attn = Attention(d, cfg.n_heads, cfg.n_kv, c,
                                    head_dim=cfg.head_dim,
                                    name=f"{self.name}.cross_attn",
                                    causal=False, cross=True, rope=False,
                                    q_chunk=cfg.attn_chunk,
                                    act_mode=cfg.attn_act)
        self.norm3 = _norm(cfg, c, f"{self.name}.norm3")
        self.ffn = MLP(d, cfg.d_ff, c, name=f"{self.name}.mlp",
                       gated=cfg.gated_mlp, activation=cfg.activation)

    def specs(self):
        return {"norm1": self.norm1.specs(), "self_attn": self.self_attn.specs(),
                "norm2": self.norm2.specs(), "cross_attn": self.cross_attn.specs(),
                "norm3": self.norm3.specs(), "ffn": self.ffn.specs()}

    def __call__(self, params, x, memory):
        x = x + self.self_attn(params["self_attn"], self.norm1(params["norm1"], x))
        x = x + self.cross_attn(params["cross_attn"],
                                self.norm2(params["norm2"], x), kv_src=memory)
        x = x + self.ffn(params["ffn"], self.norm3(params["norm3"], x))
        return logical_constraint(x, "act_batch", "act_res_seq", "act_embed")

    def init_cache(self, batch, max_len, dtype):
        hd = self.self_attn.hd
        return {
            "k": jnp.zeros((batch, max_len, self.cfg.n_kv, hd), dtype),
            "v": jnp.zeros((batch, max_len, self.cfg.n_kv, hd), dtype),
            # cross K/V computed once at prefill
            "ck": None,
            "cv": None,
        }

    def decode_step(self, params, x, cache, lengths):
        import math as _math

        from repro.nn.attention import _attend_core

        h = self.norm1(params["norm1"], x)
        h, ck_, cv_ = self.self_attn.decode_step(
            params["self_attn"], h, cache["k"], cache["v"], lengths)
        x = x + h
        # cross attention against precomputed memory K/V
        mixer = self.cross_attn
        b = x.shape[0]
        h = self.norm2(params["norm2"], x)
        q = mixer.wq(params["cross_attn"]["wq"], h).reshape(
            b, 1, mixer.n_heads, mixer.hd)
        mask = jnp.ones((b, 1, cache["ck"].shape[1]), bool)
        out = _attend_core(mixer._group(q), cache["ck"], cache["cv"], mask,
                           1.0 / _math.sqrt(mixer.hd))
        h = mixer.wo(params["cross_attn"]["wo"],
                     out.reshape(b, 1, mixer.n_heads * mixer.hd))
        x = x + h
        x = x + self.ffn(params["ffn"], self.norm3(params["norm3"], x))
        return x, {**cache, "k": ck_, "v": cv_}


class EncDecModel:
    """seamless-m4t backbone: frame embeddings -> encoder -> text decoder."""

    def __init__(self, cfg: ArchConfig, ctx: Optional[ModelContext] = None):
        self.cfg = cfg
        self.ctx = ctx or ModelContext(policy=cfg.tbn)
        c = self.ctx
        d = cfg.d_model
        self.frame_proj = Dense(d, d, c, name="frame_proj",
                                logical=("embed", "embed"))
        self.embed = Embedding(cfg.vocab, d, c, name="dec_embed")
        self.enc_block = EncBlock(cfg, c)
        self.dec_block = DecBlock(cfg, c)
        self.enc_norm = _norm(cfg, c, "enc_norm")
        self.dec_norm = _norm(cfg, c, "dec_norm")
        self.head = Dense(d, cfg.vocab, c, name="lm_head", kind="head",
                          logical=("vocab", "embed"))

    def specs(self) -> mod.SpecTree:
        return {
            "frame_proj": self.frame_proj.specs(),
            "embed": self.embed.specs(),
            "enc": mod.stack_specs(self.enc_block.specs(), self.cfg.enc_layers),
            "dec": mod.stack_specs(self.dec_block.specs(), self.cfg.dec_layers),
            "enc_norm": self.enc_norm.specs(),
            "dec_norm": self.dec_norm.specs(),
            "head": self.head.specs(),
        }

    def init(self, key):
        return mod.init_params(self.specs(), key)

    def abstract(self):
        return mod.abstract_params(self.specs())

    def logical(self):
        return mod.logical_axes(self.specs())

    def _remat(self, f):
        if self.cfg.remat == "none":
            return f
        return jax.checkpoint(f)

    def encode(self, params, frames):
        """frames: (B, S_enc, d_model) precomputed frontend embeddings."""
        x = self.frame_proj(params["frame_proj"], frames)
        x = logical_constraint(x, "act_batch", "act_seq", "act_embed")

        if self.cfg.force_unroll:
            for j in range(self.cfg.enc_layers):
                pl = jax.tree.map(lambda v: v[j], params["enc"])
                x = self.enc_block(pl, x)
            return self.enc_norm(params["enc_norm"], x)

        def body(h, pl):
            return self._remat(lambda h, pl: (self.enc_block(pl, h), None))(h, pl)

        x, _ = jax.lax.scan(body, x, params["enc"])
        return self.enc_norm(params["enc_norm"], x)

    def decode(self, params, tokens, memory):
        x = self.embed(params["embed"], tokens)
        x = logical_constraint(x, "act_batch", "act_seq", "act_embed")

        if self.cfg.force_unroll:
            for j in range(self.cfg.dec_layers):
                pl = jax.tree.map(lambda v: v[j], params["dec"])
                x = self.dec_block(pl, x, memory)
            return self.dec_norm(params["dec_norm"], x)

        def body(h, pl):
            return self._remat(
                lambda h, pl: (self.dec_block(pl, h, memory), None)
            )(h, pl)

        x, _ = jax.lax.scan(body, x, params["dec"])
        return self.dec_norm(params["dec_norm"], x)

    def train_forward(self, params, batch) -> Tuple[jax.Array, Dict]:
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        s = tokens.shape[1]
        h = self.decode(params, tokens, memory)
        # full-seq logits + masked roll (keeps S divisible for SP sharding);
        # CE is batch-chunked + remat'd — the 256206-entry vocab does not
        # shard over 16 (odd), so unchunked (B, S, V) f32 logits would
        # replicate at 16 GB/device.
        targets = jnp.roll(tokens, -1, axis=1)
        valid = (jnp.arange(s) < s - 1).astype(jnp.float32)[None, :]
        mask = jnp.broadcast_to(valid, tokens.shape)
        b = tokens.shape[0]
        # 32-divisible sub-batches: see DecoderLM._ce_sum
        nb = b // 32 if (b % 32 == 0 and s * self.cfg.vocab >= 2**26) else 1

        def chunk_sum(hc, tc, mc):
            # re-pin batch sharding inside the chunk loop (see DecoderLM)
            hc = logical_constraint(hc, "act_batch", None, None)
            tc = logical_constraint(tc, "act_batch", None)
            mc = logical_constraint(mc, "act_batch", None)
            logits = self.head(params["head"], hc)
            logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(
                logits.astype(jnp.float32), tc[..., None], axis=-1)[..., 0]
            return jnp.sum((logz - gold) * mc)

        if nb == 1:
            nll = chunk_sum(h, targets, mask)
        else:
            resh = lambda z: z.reshape(nb, b // nb, *z.shape[1:])
            body = jax.checkpoint(
                lambda acc, inp: (acc + chunk_sum(*inp), None)
            )
            nll, _ = jax.lax.scan(
                body, jnp.zeros((), jnp.float32),
                (resh(h), resh(targets), resh(mask)),
            )
        ce = nll / jnp.maximum(mask.sum(), 1.0)
        return ce, {"ce": ce, "aux": jnp.zeros(())}

    # ---------------- serving ----------------
    def prefill(self, params, batch, max_len: int):
        """Encode frames + run decoder prompt; build self+cross caches."""
        memory = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self.embed(params["embed"], tokens)

        def body(h, pl):
            h2 = self.dec_block(pl, h, memory)
            # capture self-attn KV of the prompt + cross KV of the memory
            blk = self.dec_block
            hh = blk.norm1(pl["norm1"], h)
            _, (k, v) = blk.self_attn.prefill(pl["self_attn"], hh)
            t = memory.shape[1]
            ck = blk.cross_attn.wk(pl["cross_attn"]["wk"], memory).reshape(
                b, t, blk.cross_attn.n_kv, blk.cross_attn.hd)
            cv = blk.cross_attn.wv(pl["cross_attn"]["wv"], memory).reshape(
                b, t, blk.cross_attn.n_kv, blk.cross_attn.hd)
            pad = max_len - s
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return h2, {"k": k, "v": v, "ck": ck, "cv": cv}

        if self.cfg.force_unroll:
            per_layer = []
            for j in range(self.cfg.dec_layers):
                pl = jax.tree.map(lambda v: v[j], params["dec"])
                x, cl = body(x, pl)      # (h2, this layer's caches)
                per_layer.append(cl)
            caches = jax.tree.map(lambda *ls: jnp.stack(ls), *per_layer)
        else:
            x, caches = jax.lax.scan(body, x, params["dec"])
        h = self.dec_norm(params["dec_norm"], x[:, -1:])
        logits = self.head(params["head"], h)
        return logits[:, 0], caches, jnp.full((b,), s, jnp.int32)

    def decode_step(self, params, tokens, caches, lengths, page_table=None,
                    active=None, cross_page_table=None, enc_lens=None):
        """One-token decode. ``page_table is None`` is the DENSE reference
        path (stacked per-slot rows from :meth:`prefill`) — the parity
        wall the paged engine path below is measured against, byte for
        byte. With ``page_table`` both cache families live in pool form:
        self-attention K/V scatter/gather through ``page_table`` exactly
        like DecoderLM, and cross-attention K/V are READ-ONLY pool pages
        written once by :meth:`write_cross`, viewed through
        ``cross_page_table`` and masked by ``enc_lens``."""
        if page_table is None:
            return self._decode_step_dense(params, tokens, caches, lengths)
        x = self.embed(params["embed"], tokens)
        x, caches = self._walk_dec_paged(
            params, x, caches,
            lambda blk, pl, h, kl, vl, xk, xv: self._paged_layer(
                blk, pl, h, kl, vl, xk, xv, cross_page_table, enc_lens,
                lambda a: blk.self_attn.decode_step(
                    pl["self_attn"], a, kl, vl, lengths,
                    page_table=page_table, active=active,
                ),
            ),
        )
        h = self.dec_norm(params["dec_norm"], x)
        logits = self.head(params["head"], h)
        return logits[:, 0], caches, lengths + 1

    def _decode_step_dense(self, params, tokens, caches, lengths):
        x = self.embed(params["embed"], tokens)

        def body(h, xs):
            pl, cl = xs
            cl = jax.lax.optimization_barrier(cl)   # see lm.py decode_step
            h2, c2 = self.dec_block.decode_step(pl, h, cl, lengths)
            return h2, c2

        if self.cfg.force_unroll:
            per_layer = []
            for j in range(self.cfg.dec_layers):
                pl = jax.tree.map(lambda v: v[j], params["dec"])
                cl = jax.tree.map(lambda v: v[j], caches)
                x, c2 = body(x, (pl, cl))
                per_layer.append(c2)
            caches = jax.tree.map(lambda *ls: jnp.stack(ls), *per_layer)
        else:
            x, caches = jax.lax.scan(body, x, (params["dec"], caches))
        h = self.dec_norm(params["dec_norm"], x)
        logits = self.head(params["head"], h)
        return logits[:, 0], caches, lengths + 1

    # ------------------------------------------------------------------
    # ServableModel protocol (DESIGN.md §6.5): paged serving under the
    # shared BatchedEngine. The dense prefill/decode_step above stay
    # untouched as the parity reference.
    # ------------------------------------------------------------------
    has_full_attn = True        # decoder self-attention pages its K/V
    has_recurrent_state = False
    has_cross_attn = True       # engine stands up ENCODE phase + x-pool

    def cache_families(self):
        from repro.serve.servable import CacheFamily

        return (
            CacheFamily("self_attn", paged=True),
            CacheFamily("cross_attn", paged=True, read_only=True),
        )

    def init_caches(self, batch, max_len, dtype=jnp.bfloat16,
                    page_tokens=None, n_pages=None, cross_pages=None):
        """Pool-form decode caches: BOTH families are pages, addressed
        through separate tables — there is no dense ``(n_slots, T)`` row
        anywhere (the acceptance criterion for cross-attention K/V)."""
        if page_tokens is None:
            raise ValueError(
                "EncDecModel serves paged-only: pass page_tokens/n_pages/"
                "cross_pages (the dense reference path builds its caches "
                "via prefill, not init_caches)")
        L = self.cfg.dec_layers
        kv, hd = self.cfg.n_kv, self.dec_block.self_attn.hd
        z = lambda p: jnp.zeros((L, p, page_tokens, kv, hd), dtype)
        return {
            "self": {"k": z(n_pages), "v": z(n_pages)},
            "cross": {"k": z(cross_pages), "v": z(cross_pages)},
        }

    def encode_serve(self, params, frames, valid):
        """Fixed-shape encoder pass for the engine's ENCODE phase:
        ``frames`` (1, enc_tokens, d) zero-padded, ``valid`` (1,
        enc_tokens) marking real frames. Rows < the request's frame count
        are byte-identical to the unpadded :meth:`encode` (masked keys
        underflow to exact-0 softmax weight; everything else is
        position-wise)."""
        x = self.frame_proj(params["frame_proj"], frames)
        x = logical_constraint(x, "act_batch", "act_seq", "act_embed")
        if self.cfg.force_unroll:
            for j in range(self.cfg.enc_layers):
                pl = jax.tree.map(lambda v: v[j], params["enc"])
                x = self.enc_block(pl, x, valid=valid)
            return self.enc_norm(params["enc_norm"], x)

        def body(h, pl):
            return self.enc_block(pl, h, valid=valid), None

        x, _ = jax.lax.scan(body, x, params["enc"])
        return self.enc_norm(params["enc_norm"], x)

    def write_cross(self, params, memory, caches, xptab, positions, valid):
        """Project encoder ``memory`` (1, enc_tokens, d) to per-decoder-
        layer cross K/V and scatter into the cross pool through the
        admitted slot's page-table row ``xptab`` (1, x_npp). Runs ONCE per
        request at the end of its ENCODE phase; nothing writes these pages
        again until release."""
        from repro.nn.attention import scatter_pages

        blk = self.dec_block

        def per_layer(pl, ck_pool, cv_pool):
            k, v = blk.cross_attn.cross_kv(pl["cross_attn"], memory)
            ck_pool = scatter_pages(ck_pool, xptab, positions, k, valid)
            cv_pool = scatter_pages(cv_pool, xptab, positions, v, valid)
            return ck_pool, cv_pool

        xs = (params["dec"], caches["cross"]["k"], caches["cross"]["v"])
        if self.cfg.force_unroll:
            cks, cvs = [], []
            for j in range(self.cfg.dec_layers):
                a, b, c = (jax.tree.map(lambda v: v[j], t) for t in xs)
                ck, cv = per_layer(a, b, c)
                cks.append(ck)
                cvs.append(cv)
            ck, cv = jnp.stack(cks), jnp.stack(cvs)
        else:
            def body(_, layer_xs):
                return None, per_layer(*layer_xs)

            _, (ck, cv) = jax.lax.scan(body, None, xs)
        return {**caches, "cross": {"k": ck, "v": cv}}

    def _paged_layer(self, blk, pl, h, kl, vl, xk_l, xv_l, xptab, enc_lens,
                     self_step):
        """One decoder layer against pool caches: self-attn (via
        ``self_step``, which closes over decode vs extend), read-only
        cross-attend, FFN — same residual order as DecBlock.__call__."""
        a = blk.norm1(pl["norm1"], h)
        a, kl, vl = self_step(a)
        h = h + a
        a = blk.norm2(pl["norm2"], h)
        h = h + blk.cross_attn.cross_attend(
            pl["cross_attn"], a, xk_l, xv_l, enc_lens, page_table=xptab,
        )
        h = h + blk.ffn(pl["ffn"], blk.norm3(pl["norm3"], h))
        return h, kl, vl

    def _walk_dec_paged(self, params, x, caches, step_fn):
        """Decoder layer loop for the paged tick: the stacked SELF pool
        rides in the scan CARRY with dynamic_update at the live layer (one
        buffer, no xs->ys double-buffering — see lm.py._walk_segments);
        the read-only CROSS pool rides as scan xs."""
        ks, vs = caches["self"]["k"], caches["self"]["v"]
        xks, xvs = caches["cross"]["k"], caches["cross"]["v"]

        def run_layer(pl, h, kl, vl, xk_l, xv_l):
            return step_fn(self.dec_block, pl, h, kl, vl, xk_l, xv_l)

        if self.cfg.force_unroll:
            nk, nv = [], []
            for j in range(self.cfg.dec_layers):
                pick = lambda v: v[j]
                x, kl, vl = run_layer(
                    jax.tree.map(pick, params["dec"]), x,
                    ks[j], vs[j], xks[j], xvs[j],
                )
                nk.append(kl)
                nv.append(vl)
            ks, vs = jnp.stack(nk), jnp.stack(nv)
        else:
            def body(carry, layer_xs):
                h, kfull, vfull, idx = carry
                pl, xk_l, xv_l = layer_xs
                kl = jax.lax.dynamic_index_in_dim(kfull, idx, 0,
                                                  keepdims=False)
                vl = jax.lax.dynamic_index_in_dim(vfull, idx, 0,
                                                  keepdims=False)
                # barrier: stop LICM materializing converted copies of the
                # whole stacked pool (see lm.py._walk_segments)
                kl, vl = jax.lax.optimization_barrier((kl, vl))
                h, kl, vl = run_layer(pl, h, kl, vl, xk_l, xv_l)
                kfull = jax.lax.dynamic_update_index_in_dim(
                    kfull, kl.astype(kfull.dtype), idx, 0)
                vfull = jax.lax.dynamic_update_index_in_dim(
                    vfull, vl.astype(vfull.dtype), idx, 0)
                return (h, kfull, vfull, idx + 1), None

            (x, ks, vs, _), _ = jax.lax.scan(
                body, (x, ks, vs, jnp.int32(0)),
                (params["dec"], xks, xvs),
            )
        return x, {"self": {"k": ks, "v": vs},
                   "cross": {"k": xks, "v": xvs}}

    def extend(self, params, tokens, caches, lengths, n_new,
               page_table=None, cross_page_table=None, enc_lens=None):
        """Chunked-prefill step over the paged caches (same column
        semantics as DecoderLM.extend: padding columns never write and a
        slot's logits come from its last valid column)."""
        if page_table is None:
            raise ValueError("EncDecModel.extend is paged-only")
        b, c = tokens.shape
        positions = lengths[:, None] + jnp.arange(c)[None, :]
        valid = jnp.arange(c)[None, :] < n_new[:, None]
        x = self.embed(params["embed"], tokens)
        x, caches = self._walk_dec_paged(
            params, x, caches,
            lambda blk, pl, h, kl, vl, xk, xv: self._paged_layer(
                blk, pl, h, kl, vl, xk, xv, cross_page_table, enc_lens,
                lambda a: blk.self_attn.extend(
                    pl["self_attn"], a, kl, vl, positions, valid,
                    page_table=page_table,
                ),
            ),
        )
        idx = jnp.clip(n_new - 1, 0, c - 1)
        h_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        h = self.dec_norm(params["dec_norm"], h_last)
        logits = self.head(params["head"], h)
        return logits[:, 0], caches, lengths + n_new

    # ---- per-slot cache walkers: everything is paged, so these are ----
    # ---- passthroughs (the page tables carry all per-slot state)  ----
    def merge_caches(self, old, new, keep, paged=False):
        # pool writes were already confined in-kernel (active / valid
        # masks drop inactive slots' scatters); nothing to select per-slot
        return new

    def reset_slot_caches(self, caches, slot, paged=False):
        return caches           # stale pool rows are position-masked

    def snapshot_slot_caches(self, caches, slot):
        return None             # no non-paged family to pin

    def restore_slot_caches(self, caches, slot, snaps):
        return caches

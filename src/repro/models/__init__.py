from repro.models.encdec import EncDecModel
from repro.models.lm import DecoderLM
from repro.models.paper import build_paper_model

__all__ = ["DecoderLM", "EncDecModel", "build_paper_model"]

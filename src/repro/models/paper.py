"""The paper's own evaluation architectures, on the TBN substrate.

Exact layer shapes (the bit/param accounting in Tables 1-7 depends only on
them) + runnable forward/train paths for the synthetic-data validation at
reduced scale. Every Conv2D/Dense consults the model's TBNPolicy, so a
single ``policy=`` switch produces the FP32 / BWNN / TBN_p variants the
paper compares.

Families:  ResNet-18/34/50, VGG-Small     (Table 1/2)
           PointNet (cls / part / sem)    (Table 3)
           ViT, Swin-lite                 (Table 4)
           TS-Transformer encoder         (Table 5)
           MCU-MLP 784-128-10             (Table 6, Algorithm 1)
           MLPMixer, ConvMixer            (Fig. 6/7)
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.nn import module as mod
from repro.nn.context import ModelContext
from repro.nn.linear import Conv2D, Dense
from repro.nn.norms import LayerNorm


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ChannelNorm:
    """LayerNorm over the channel axis (BN stand-in; never quantized)."""

    dim: int
    ctx: ModelContext
    name: str = "cnorm"

    def __post_init__(self):
        self.ln = LayerNorm(self.dim, self.ctx, name=self.name)

    def specs(self):
        return self.ln.specs()

    def __call__(self, params, x):
        return self.ln(params, x)


class _Seq:
    """Name->module container with dict specs/params."""

    def __init__(self):
        self._mods = {}

    def add(self, name, m):
        self._mods[name] = m
        return m

    def specs(self):
        return {k: m.specs() for k, m in self._mods.items()}

    def __getitem__(self, k):
        return self._mods[k]

    def items(self):
        return self._mods.items()


# ---------------------------------------------------------------------------
# ResNet / VGG (Table 1, 2)
# ---------------------------------------------------------------------------
class ResNet:
    """CIFAR-style (3x3 stem) or ImageNet-style (7x7 stem) ResNet."""

    CFG = {
        18: ("basic", (2, 2, 2, 2)),
        34: ("basic", (3, 4, 6, 3)),
        50: ("bottleneck", (3, 4, 6, 3)),
    }

    def __init__(self, depth: int, ctx: ModelContext, *, classes=10,
                 imagenet=False, width=64):
        self.ctx = ctx
        self.classes = classes
        self.imagenet = imagenet
        kind, blocks = self.CFG[depth]
        self.kind = kind
        self.expansion = 4 if kind == "bottleneck" else 1
        m = self.m = _Seq()
        res = 224 if imagenet else 32
        if imagenet:
            m.add("stem", Conv2D(3, width, (7, 7), ctx, stride=(2, 2),
                                 name="stem"))
            res //= 4  # stride-2 conv + pool
        else:
            m.add("stem", Conv2D(3, width, (3, 3), ctx, name="stem"))
        m.add("stem_norm", ChannelNorm(width, ctx, name="stem_norm"))
        c_in = width
        self.block_names: List[Tuple[str, int, int, int]] = []
        for stage, n in enumerate(blocks):
            c_mid = width * (2 ** stage)
            stride = 1 if stage == 0 else 2
            for b in range(n):
                s = stride if b == 0 else 1
                name = f"s{stage}b{b}"
                self._add_block(name, c_in, c_mid, s)
                c_in = c_mid * self.expansion
                self.block_names.append((name, c_mid, s, c_in))
        m.add("head", Dense(c_in, classes, ctx, name="head", kind="head",
                            logical=(None, None)))

    def _add_block(self, name, c_in, c_mid, stride):
        ctx, m = self.ctx, self.m
        if self.kind == "basic":
            m.add(f"{name}.c1", Conv2D(c_in, c_mid, (3, 3), ctx,
                                       stride=(stride, stride), name=f"{name}.c1"))
            m.add(f"{name}.n1", ChannelNorm(c_mid, ctx))
            m.add(f"{name}.c2", Conv2D(c_mid, c_mid, (3, 3), ctx, name=f"{name}.c2"))
            m.add(f"{name}.n2", ChannelNorm(c_mid, ctx))
            c_out = c_mid
        else:
            m.add(f"{name}.c1", Conv2D(c_in, c_mid, (1, 1), ctx, name=f"{name}.c1"))
            m.add(f"{name}.n1", ChannelNorm(c_mid, ctx))
            m.add(f"{name}.c2", Conv2D(c_mid, c_mid, (3, 3), ctx,
                                       stride=(stride, stride), name=f"{name}.c2"))
            m.add(f"{name}.n2", ChannelNorm(c_mid, ctx))
            m.add(f"{name}.c3", Conv2D(c_mid, c_mid * 4, (1, 1), ctx, name=f"{name}.c3"))
            m.add(f"{name}.n3", ChannelNorm(c_mid * 4, ctx))
            c_out = c_mid * 4
        if stride != 1 or c_in != c_out:
            m.add(f"{name}.down", Conv2D(c_in, c_out, (1, 1), ctx,
                                         stride=(stride, stride), name=f"{name}.down"))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        m = self.m
        h = m["stem"](params["stem"], x)
        if self.imagenet:
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
        h = jax.nn.relu(m["stem_norm"](params["stem_norm"], h))
        for name, c_mid, stride, c_out in self.block_names:
            idn = h
            if self.kind == "basic":
                h2 = jax.nn.relu(m[f"{name}.n1"](params[f"{name}.n1"],
                                 m[f"{name}.c1"](params[f"{name}.c1"], h)))
                h2 = m[f"{name}.n2"](params[f"{name}.n2"],
                                     m[f"{name}.c2"](params[f"{name}.c2"], h2))
            else:
                h2 = jax.nn.relu(m[f"{name}.n1"](params[f"{name}.n1"],
                                 m[f"{name}.c1"](params[f"{name}.c1"], h)))
                h2 = jax.nn.relu(m[f"{name}.n2"](params[f"{name}.n2"],
                                 m[f"{name}.c2"](params[f"{name}.c2"], h2)))
                h2 = m[f"{name}.n3"](params[f"{name}.n3"],
                                     m[f"{name}.c3"](params[f"{name}.c3"], h2))
            if f"{name}.down" in params:
                idn = m[f"{name}.down"](params[f"{name}.down"], idn)
            h = jax.nn.relu(idn + h2)
        h = jnp.mean(h, axis=(1, 2))
        return self.m["head"](params["head"], h)


class VGGSmall:
    """The binary-nets VGG-Small: 6 convs (128..512) + classifier."""

    def __init__(self, ctx: ModelContext, classes=10):
        self.ctx = ctx
        m = self.m = _Seq()
        chans = [(3, 128), (128, 128), (128, 256), (256, 256),
                 (256, 512), (512, 512)]
        for i, (ci, co) in enumerate(chans):
            m.add(f"c{i}", Conv2D(ci, co, (3, 3), ctx, name=f"c{i}"))
            m.add(f"n{i}", ChannelNorm(co, ctx))
        m.add("head", Dense(512 * 4 * 4, classes, ctx, name="head",
                            kind="head", logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        h = x
        for i in range(6):
            h = self.m[f"c{i}"](params[f"c{i}"], h)
            h = jax.nn.relu(self.m[f"n{i}"](params[f"n{i}"], h))
            if i % 2 == 1:  # pool after every pair: 32->16->8->4
                h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                          (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(h.shape[0], -1)
        return self.m["head"](params["head"], h)


# ---------------------------------------------------------------------------
# ViT / Swin-lite / Mixer family (Table 4, Fig. 6)
# ---------------------------------------------------------------------------
class ViT:
    def __init__(self, ctx: ModelContext, *, dim=512, depth=6, heads=8,
                 mlp_dim=512, patch=4, img=32, classes=10):
        self.ctx, self.dim, self.depth, self.heads = ctx, dim, depth, heads
        self.patch, self.img = patch, img
        n_tokens = (img // patch) ** 2
        m = self.m = _Seq()
        m.add("embed", Dense(patch * patch * 3, dim, ctx, name="embed",
                             logical=(None, None)))
        self.pos = mod.ParamSpec((n_tokens, dim), jnp.float32, (None, None),
                                 mod.normal(0.02))
        for i in range(depth):
            m.add(f"l{i}.qkv", Dense(dim, 3 * dim, ctx, name=f"l{i}.qkv",
                                     logical=(None, None)))
            m.add(f"l{i}.proj", Dense(dim, dim, ctx, name=f"l{i}.proj",
                                      logical=(None, None)))
            m.add(f"l{i}.n1", ChannelNorm(dim, ctx))
            m.add(f"l{i}.fc1", Dense(dim, mlp_dim, ctx, name=f"l{i}.fc1",
                                     logical=(None, None)))
            m.add(f"l{i}.fc2", Dense(mlp_dim, dim, ctx, name=f"l{i}.fc2",
                                     logical=(None, None)))
            m.add(f"l{i}.n2", ChannelNorm(dim, ctx))
        m.add("head", Dense(dim, classes, ctx, name="head", kind="head",
                            logical=(None, None)))

    def specs(self):
        out = self.m.specs()
        out["pos"] = self.pos
        return out

    def __call__(self, params, x):
        b = x.shape[0]
        p, img = self.patch, self.img
        n = img // p
        x = x.reshape(b, n, p, n, p, 3).transpose(0, 1, 3, 2, 4, 5)
        x = x.reshape(b, n * n, p * p * 3)
        h = self.m["embed"](params["embed"], x) + params["pos"]
        hd = self.dim // self.heads
        for i in range(self.depth):
            z = self.m[f"l{i}.n1"](params[f"l{i}.n1"], h)
            qkv = self.m[f"l{i}.qkv"](params[f"l{i}.qkv"], z)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            rs = lambda t: t.reshape(b, -1, self.heads, hd)
            att = jnp.einsum("bqhd,bkhd->bhqk", rs(q), rs(k)) / math.sqrt(hd)
            att = jax.nn.softmax(att, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", att, rs(v)).reshape(b, -1, self.dim)
            h = h + self.m[f"l{i}.proj"](params[f"l{i}.proj"], o)
            z = self.m[f"l{i}.n2"](params[f"l{i}.n2"], h)
            z = jax.nn.gelu(self.m[f"l{i}.fc1"](params[f"l{i}.fc1"], z))
            h = h + self.m[f"l{i}.fc2"](params[f"l{i}.fc2"], z)
        return self.m["head"](params["head"], jnp.mean(h, axis=1))


class SwinLite:
    """Hierarchical transformer (patch-merging stages, full attention
    within stage) — swin-t parameter profile without window bookkeeping."""

    def __init__(self, ctx: ModelContext, *, img=32, classes=10,
                 dims=(96, 192, 384, 768), depths=(2, 2, 6, 2), patch=2):
        self.ctx, self.img, self.patch = ctx, img, patch
        self.dims, self.depths = dims, depths
        m = self.m = _Seq()
        m.add("embed", Dense(patch * patch * 3, dims[0], ctx, name="embed",
                             logical=(None, None)))
        for s, (d, n) in enumerate(zip(dims, depths)):
            for b in range(n):
                pre = f"s{s}b{b}"
                m.add(f"{pre}.qkv", Dense(d, 3 * d, ctx, name=f"{pre}.qkv",
                                          logical=(None, None)))
                m.add(f"{pre}.proj", Dense(d, d, ctx, name=f"{pre}.proj",
                                           logical=(None, None)))
                m.add(f"{pre}.n1", ChannelNorm(d, ctx))
                m.add(f"{pre}.fc1", Dense(d, 4 * d, ctx, name=f"{pre}.fc1",
                                          logical=(None, None)))
                m.add(f"{pre}.fc2", Dense(4 * d, d, ctx, name=f"{pre}.fc2",
                                          logical=(None, None)))
                m.add(f"{pre}.n2", ChannelNorm(d, ctx))
            if s + 1 < len(dims):
                m.add(f"merge{s}", Dense(4 * d, dims[s + 1], ctx,
                                         name=f"merge{s}", logical=(None, None)))
        m.add("head", Dense(dims[-1], classes, ctx, name="head", kind="head",
                            logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        b = x.shape[0]
        p = self.patch
        n = self.img // p
        x = x.reshape(b, n, p, n, p, 3).transpose(0, 1, 3, 2, 4, 5)
        h = self.m["embed"](params["embed"],
                            x.reshape(b, n * n, p * p * 3))
        side = n
        for s, (d, nblk) in enumerate(zip(self.dims, self.depths)):
            heads = max(1, d // 32)
            hd = d // heads
            for blk in range(nblk):
                pre = f"s{s}b{blk}"
                z = self.m[f"{pre}.n1"](params[f"{pre}.n1"], h)
                qkv = self.m[f"{pre}.qkv"](params[f"{pre}.qkv"], z)
                q, k, v = jnp.split(qkv, 3, axis=-1)
                rs = lambda t: t.reshape(b, -1, heads, hd)
                att = jax.nn.softmax(
                    jnp.einsum("bqhd,bkhd->bhqk", rs(q), rs(k)) / math.sqrt(hd),
                    axis=-1)
                o = jnp.einsum("bhqk,bkhd->bqhd", att, rs(v)).reshape(b, -1, d)
                h = h + self.m[f"{pre}.proj"](params[f"{pre}.proj"], o)
                z = self.m[f"{pre}.n2"](params[f"{pre}.n2"], h)
                z = jax.nn.gelu(self.m[f"{pre}.fc1"](params[f"{pre}.fc1"], z))
                h = h + self.m[f"{pre}.fc2"](params[f"{pre}.fc2"], z)
            if s + 1 < len(self.dims):
                h = h.reshape(b, side // 2, 2, side // 2, 2, d)
                h = h.transpose(0, 1, 3, 2, 4, 5).reshape(
                    b, (side // 2) ** 2, 4 * d)
                h = self.m[f"merge{s}"](params[f"merge{s}"], h)
                side //= 2
        return self.m["head"](params["head"], jnp.mean(h, axis=1))


class MLPMixer:
    def __init__(self, ctx: ModelContext, *, dim=512, depth=6, patch=4,
                 img=32, classes=10, token_hidden=256, chan_hidden=256):
        self.ctx, self.dim, self.depth = ctx, dim, depth
        self.patch, self.img = patch, img
        n_tok = (img // patch) ** 2
        self.n_tok = n_tok
        m = self.m = _Seq()
        m.add("embed", Dense(patch * patch * 3, dim, ctx, name="embed",
                             logical=(None, None)))
        for i in range(depth):
            m.add(f"l{i}.t1", Dense(n_tok, token_hidden, ctx, name=f"l{i}.t1",
                                    logical=(None, None)))
            m.add(f"l{i}.t2", Dense(token_hidden, n_tok, ctx, name=f"l{i}.t2",
                                    logical=(None, None)))
            m.add(f"l{i}.c1", Dense(dim, chan_hidden, ctx, name=f"l{i}.c1",
                                    logical=(None, None)))
            m.add(f"l{i}.c2", Dense(chan_hidden, dim, ctx, name=f"l{i}.c2",
                                    logical=(None, None)))
            m.add(f"l{i}.n1", ChannelNorm(dim, ctx))
            m.add(f"l{i}.n2", ChannelNorm(dim, ctx))
        m.add("head", Dense(dim, classes, ctx, name="head", kind="head",
                            logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        b = x.shape[0]
        p, img = self.patch, self.img
        n = img // p
        x = x.reshape(b, n, p, n, p, 3).transpose(0, 1, 3, 2, 4, 5)
        h = self.m["embed"](params["embed"], x.reshape(b, n * n, p * p * 3))
        for i in range(self.depth):
            z = self.m[f"l{i}.n1"](params[f"l{i}.n1"], h).swapaxes(1, 2)
            z = jax.nn.gelu(self.m[f"l{i}.t1"](params[f"l{i}.t1"], z))
            z = self.m[f"l{i}.t2"](params[f"l{i}.t2"], z).swapaxes(1, 2)
            h = h + z
            z = self.m[f"l{i}.n2"](params[f"l{i}.n2"], h)
            z = jax.nn.gelu(self.m[f"l{i}.c1"](params[f"l{i}.c1"], z))
            h = h + self.m[f"l{i}.c2"](params[f"l{i}.c2"], z)
        return self.m["head"](params["head"], jnp.mean(h, axis=1))


class ConvMixer:
    def __init__(self, ctx: ModelContext, *, dim=256, depth=16, kernel=8,
                 patch=1, img=32, classes=10):
        self.ctx, self.dim, self.depth = ctx, dim, depth
        self.kernel, self.patch, self.img = kernel, patch, img
        m = self.m = _Seq()
        m.add("embed", Conv2D(3, dim, (patch, patch), ctx,
                              stride=(patch, patch), name="embed"))
        for i in range(depth):
            # depthwise: modeled as grouped conv = dim separate (1,k,k);
            # stored as (dim, 1, k, k) — same param count as the paper
            m.add(f"l{i}.dw", Conv2D(1, dim, (kernel, kernel), ctx,
                                     name=f"l{i}.dw"))
            m.add(f"l{i}.pw", Conv2D(dim, dim, (1, 1), ctx, name=f"l{i}.pw"))
            m.add(f"l{i}.n1", ChannelNorm(dim, ctx))
            m.add(f"l{i}.n2", ChannelNorm(dim, ctx))
        m.add("head", Dense(dim, classes, ctx, name="head", kind="head",
                            logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        h = jax.nn.gelu(self.m["embed"](params["embed"], x))
        for i in range(self.depth):
            w = params[f"l{i}.dw"]["w"]  # (dim,1,k,k) depthwise
            dw = self.m[f"l{i}.dw"]
            weff = w
            if dw.spec is not None:
                from repro.core.tiling import tiled_weight
                weff = tiled_weight(w, dw.spec, a=params[f"l{i}.dw"].get("a"),
                                    dtype=h.dtype).reshape(w.shape)
            z = jax.lax.conv_general_dilated(
                h, weff.astype(h.dtype), (1, 1), "SAME",
                feature_group_count=self.dim,
                dimension_numbers=("NHWC", "OIHW", "NHWC"))
            h = h + jax.nn.gelu(self.m[f"l{i}.n1"](params[f"l{i}.n1"], z))
            z = self.m[f"l{i}.pw"](params[f"l{i}.pw"], h)
            h = jax.nn.gelu(self.m[f"l{i}.n2"](params[f"l{i}.n2"], z))
        return self.m["head"](params["head"], jnp.mean(h, axis=(1, 2)))


# ---------------------------------------------------------------------------
# PointNet (Table 3)
# ---------------------------------------------------------------------------
class TNet:
    """PointNet spatial/feature transform regressor (k x k matrix)."""

    def __init__(self, ctx: ModelContext, k: int, name: str):
        self.k, self.name = k, name
        m = self.m = _Seq()
        for i, w in enumerate((64, 128, 1024)):
            m.add(f"mlp{i}", Dense(k if i == 0 else (64, 128)[i - 1], w, ctx,
                                   name=f"{name}.mlp{i}", logical=(None, None)))
            m.add(f"n{i}", ChannelNorm(w, ctx))
        m.add("fc1", Dense(1024, 512, ctx, name=f"{name}.fc1",
                           logical=(None, None)))
        m.add("fc2", Dense(512, 256, ctx, name=f"{name}.fc2",
                           logical=(None, None)))
        m.add("out", Dense(256, k * k, ctx, name=f"{name}.out", kind="head",
                           logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        h = x
        for i in range(3):
            h = self.m[f"mlp{i}"](params[f"mlp{i}"], h)
            h = jax.nn.relu(self.m[f"n{i}"](params[f"n{i}"], h))
        g = jnp.max(h, axis=1)
        g = jax.nn.relu(self.m["fc1"](params["fc1"], g))
        g = jax.nn.relu(self.m["fc2"](params["fc2"], g))
        mat = self.m["out"](params["out"], g).reshape(-1, self.k, self.k)
        return mat + jnp.eye(self.k)[None]


class PointNet:
    """Unified PointNet (with input/feature T-Nets): shared per-point MLPs
    + global max pool.

    task: "cls" (k classes), "part" (per-point part logits, global+local
    concat), "sem" (per-point semantic logits).
    """

    def __init__(self, ctx: ModelContext, *, task="cls", classes=40,
                 widths=(64, 64, 64, 128, 1024)):
        self.ctx, self.task, self.classes = ctx, task, classes
        self.widths = widths
        m = self.m = _Seq()
        m.add("tnet1", TNet(ctx, 3, "tnet1"))
        m.add("tnet2", TNet(ctx, widths[1], "tnet2"))
        c_in = 3
        for i, w in enumerate(widths):
            m.add(f"mlp{i}", Dense(c_in, w, ctx, name=f"mlp{i}",
                                   logical=(None, None)))
            m.add(f"n{i}", ChannelNorm(w, ctx))
            c_in = w
        g = widths[-1]
        if task == "cls":
            m.add("fc1", Dense(g, 512, ctx, name="fc1", logical=(None, None)))
            m.add("fc2", Dense(512, 256, ctx, name="fc2", logical=(None, None)))
            m.add("head", Dense(256, classes, ctx, name="head", kind="head",
                                logical=(None, None)))
        else:
            # segmentation: concat(global, point feature) -> per-point MLP
            seg_in = g + widths[2]
            seg_w = (512, 256, 128) if task == "part" else (256, 128)
            c = seg_in
            self.seg_w = seg_w
            for i, w in enumerate(seg_w):
                m.add(f"seg{i}", Dense(c, w, ctx, name=f"seg{i}",
                                       logical=(None, None)))
                m.add(f"sn{i}", ChannelNorm(w, ctx))
                c = w
            m.add("head", Dense(c, classes, ctx, name="head", kind="head",
                                logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, pts):
        """pts (B, N, 3) -> logits: cls (B, k) | seg (B, N, k)."""
        t1 = self.m["tnet1"](params["tnet1"], pts)
        h = jnp.einsum("bnk,bkj->bnj", pts, t1)
        feats = None
        for i in range(len(self.widths)):
            h = self.m[f"mlp{i}"](params[f"mlp{i}"], h)
            h = jax.nn.relu(self.m[f"n{i}"](params[f"n{i}"], h))
            if i == 1:  # feature transform after the 64-wide stage
                t2 = self.m["tnet2"](params["tnet2"], h)
                h = jnp.einsum("bnk,bkj->bnj", h, t2)
            if i == 2:
                feats = h
        g = jnp.max(h, axis=1)                       # (B, g)
        if self.task == "cls":
            z = jax.nn.relu(self.m["fc1"](params["fc1"], g))
            z = jax.nn.relu(self.m["fc2"](params["fc2"], z))
            return self.m["head"](params["head"], z)
        n = pts.shape[1]
        z = jnp.concatenate(
            [feats, jnp.broadcast_to(g[:, None, :], (g.shape[0], n, g.shape[1]))],
            axis=-1)
        for i in range(len(self.seg_w)):
            z = self.m[f"seg{i}"](params[f"seg{i}"], z)
            z = jax.nn.relu(self.m[f"sn{i}"](params[f"sn{i}"], z))
        return self.m["head"](params["head"], z)


# ---------------------------------------------------------------------------
# Time-series Transformer encoder (Table 5)
# ---------------------------------------------------------------------------
class TSTransformer:
    def __init__(self, ctx: ModelContext, *, features=321, dim=512, depth=3,
                 heads=8, d_ff=512, horizon=1):
        self.ctx, self.dim, self.depth, self.heads = ctx, dim, depth, heads
        self.features, self.horizon = features, horizon
        m = self.m = _Seq()
        m.add("embed", Dense(features, dim, ctx, name="embed",
                             logical=(None, None)))
        for i in range(depth):
            m.add(f"l{i}.qkv", Dense(dim, 3 * dim, ctx, name=f"l{i}.qkv",
                                     logical=(None, None)))
            m.add(f"l{i}.proj", Dense(dim, dim, ctx, name=f"l{i}.proj",
                                      logical=(None, None)))
            m.add(f"l{i}.fc1", Dense(dim, d_ff, ctx, name=f"l{i}.fc1",
                                     logical=(None, None)))
            m.add(f"l{i}.fc2", Dense(d_ff, dim, ctx, name=f"l{i}.fc2",
                                     logical=(None, None)))
            m.add(f"l{i}.n1", ChannelNorm(dim, ctx))
            m.add(f"l{i}.n2", ChannelNorm(dim, ctx))
        m.add("head", Dense(dim, features * horizon, ctx, name="head",
                            kind="head", logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        """x (B, L, F) -> next-step forecast (B, horizon, F)."""
        b, L, f = x.shape
        h = self.m["embed"](params["embed"], x)
        pos = jnp.arange(L)[None, :, None] / L
        h = h + pos.astype(h.dtype)
        hd = self.dim // self.heads
        for i in range(self.depth):
            z = self.m[f"l{i}.n1"](params[f"l{i}.n1"], h)
            qkv = self.m[f"l{i}.qkv"](params[f"l{i}.qkv"], z)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            rs = lambda t: t.reshape(b, -1, self.heads, hd)
            att = jax.nn.softmax(
                jnp.einsum("bqhd,bkhd->bhqk", rs(q), rs(k)) / math.sqrt(hd),
                axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", att, rs(v)).reshape(b, -1, self.dim)
            h = h + self.m[f"l{i}.proj"](params[f"l{i}.proj"], o)
            z = self.m[f"l{i}.n2"](params[f"l{i}.n2"], h)
            z = jax.nn.gelu(self.m[f"l{i}.fc1"](params[f"l{i}.fc1"], z))
            h = h + self.m[f"l{i}.fc2"](params[f"l{i}.fc2"], z)
        out = self.m["head"](params["head"], h[:, -1])
        return out.reshape(b, self.horizon, f)


# ---------------------------------------------------------------------------
# MCU MLP (Table 6 / Algorithm 1)
# ---------------------------------------------------------------------------
class MCUMLP:
    """784-128-10 MLP, hidden layer tiled (p=4, per-tile alphas)."""

    def __init__(self, ctx: ModelContext):
        self.ctx = ctx
        m = self.m = _Seq()
        m.add("fc1", Dense(784, 128, ctx, name="fc1", logical=(None, None)))
        m.add("head", Dense(128, 10, ctx, name="head", kind="head",
                            logical=(None, None)))

    def specs(self):
        return self.m.specs()

    def __call__(self, params, x):
        h = jax.nn.relu(self.m["fc1"](params["fc1"], x))
        return self.m["head"](params["head"], h)


# ---------------------------------------------------------------------------
# registry for the benchmarks
# ---------------------------------------------------------------------------
def build_paper_model(name: str, ctx: ModelContext, **kw):
    f = {
        "resnet18": lambda: ResNet(18, ctx, **kw),
        "resnet34": lambda: ResNet(34, ctx, **kw),
        "resnet50": lambda: ResNet(50, ctx, **kw),
        "vgg-small": lambda: VGGSmall(ctx, **kw),
        "vit": lambda: ViT(ctx, **kw),
        "swin-lite": lambda: SwinLite(ctx, **kw),
        "mlpmixer": lambda: MLPMixer(ctx, **kw),
        "convmixer": lambda: ConvMixer(ctx, **kw),
        "pointnet": lambda: PointNet(ctx, **kw),
        "ts-transformer": lambda: TSTransformer(ctx, **kw),
        "mcu-mlp": lambda: MCUMLP(ctx),
    }[name]
    return f()

"""Decoder LM covering the dense / MoE / SSM / hybrid / VLM families.

The layer stack is organised into *segments*: each segment is either a
lax.scan over N identical blocks (stacked params — keeps HLO size and
compile time independent of depth) or a single unrolled block (e.g. the
MoE first-dense layer, or the hybrid pattern remainder). Remat wraps each
scanned block.

One model object serves three entry points:
    train_forward(params, tokens, ...)    -> loss & metrics
    prefill(params, tokens, ...)          -> logits, caches
    decode_step(params, tokens, caches)   -> logits, caches
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import logical_constraint
from repro.nn import module as mod
from repro.nn.attention import Attention
from repro.nn.context import ModelContext
from repro.nn.embeddings import Embedding
from repro.nn.ffn import MLP
from repro.nn.linear import Dense
from repro.nn.moe import MoE
from repro.nn.norms import LayerNorm, RMSNorm
from repro.nn.rglru import RGLRUBlock
from repro.nn.ssm import Mamba2Block


def _norm(cfg: ArchConfig, ctx: ModelContext, dim: int, name: str):
    cls = RMSNorm if cfg.norm == "rmsnorm" else LayerNorm
    return cls(dim, ctx, name=name)


def _paged_attn(blk) -> bool:
    """Full-attention blocks page their K/V through the serving pool;
    windowed rings and recurrent state stay per-slot (they are bounded
    already and snapshot at prefix boundaries instead — DESIGN §6.2)."""
    return blk.kind == "attn" and not blk.cfg.window


def _map_block_cache(blk, fn, *subtrees):
    """Apply ``fn(leaf_block, *cache_subtrees)`` per leaf block, recursing
    through pattern super-blocks (whose cache is a {"b{i}": ...} dict) —
    the shared spine for every per-slot cache operation that must know
    which FAMILY a subtree belongs to (paged pool vs slot rows)."""
    if blk.kind == "pattern":
        return {
            f"b{i}": _map_block_cache(
                b, fn, *(t[f"b{i}"] for t in subtrees)
            )
            for i, b in enumerate(blk.blocks)
        }
    return fn(blk, *subtrees)


@dataclasses.dataclass
class Block:
    """One residual block: (attn|rec|ssm) + (mlp|moe), pre-norm."""

    cfg: ArchConfig
    ctx: ModelContext
    kind: str                       # "attn" | "rec" | "ssm"
    use_moe: bool
    name: str = "block"

    def __post_init__(self):
        cfg, ctx, d = self.cfg, self.ctx, self.cfg.d_model
        self.norm1 = _norm(cfg, ctx, d, f"{self.name}.norm1")
        if self.kind == "attn":
            self.mixer = Attention(
                d, cfg.n_heads, cfg.n_kv, ctx, head_dim=cfg.head_dim,
                name=f"{self.name}.attn", window=cfg.window,
                qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
                rope=cfg.rope_theta > 0, rope_theta=cfg.rope_theta or 10_000.0,
                q_chunk=cfg.attn_chunk, act_mode=cfg.attn_act,
            )
        elif self.kind == "rec":
            self.mixer = RGLRUBlock(d, ctx, name=f"{self.name}.rec")
        elif self.kind == "ssm":
            self.mixer = Mamba2Block(
                d, ctx, d_state=cfg.ssm.d_state, head_dim=cfg.ssm.head_dim,
                expand=cfg.ssm.expand, n_groups=cfg.ssm.n_groups,
                conv_width=cfg.ssm.conv_width, chunk=cfg.ssm.chunk,
                name=f"{self.name}.ssm",
            )
        else:
            raise ValueError(self.kind)
        self.has_ffn = self.kind != "ssm"   # mamba2 block is the whole layer
        if self.has_ffn:
            self.norm2 = _norm(cfg, ctx, d, f"{self.name}.norm2")
            if self.use_moe:
                m = cfg.moe
                self.ffn = MoE(
                    d, m.d_ff_expert or cfg.d_ff, m.n_experts, m.top_k, ctx,
                    n_shared=m.n_shared, name=f"{self.name}.moe",
                    gated=cfg.gated_mlp, activation=cfg.activation,
                )
            else:
                self.ffn = MLP(d, cfg.d_ff, ctx, name=f"{self.name}.mlp",
                               gated=cfg.gated_mlp, activation=cfg.activation)

    def specs(self) -> mod.SpecTree:
        out = {"norm1": self.norm1.specs(), "mixer": self.mixer.specs()}
        if self.has_ffn:
            out["norm2"] = self.norm2.specs()
            out["ffn"] = self.ffn.specs()
        return out

    def __call__(self, params, x, *, positions=None) -> Tuple[jax.Array, jax.Array]:
        aux = jnp.zeros((), jnp.float32)
        h = self.norm1(params["norm1"], x)
        if self.kind == "attn":
            h = self.mixer(params["mixer"], h, positions=positions)
        else:
            h = self.mixer(params["mixer"], h)
        x = x + h
        if self.has_ffn:
            h = self.norm2(params["norm2"], x)
            if self.use_moe:
                h, aux = self.ffn(params["ffn"], h)
            else:
                h = self.ffn(params["ffn"], h)
            x = x + h
        # sequence-parallel residual stream between blocks (see sharding.py)
        x = logical_constraint(x, "act_batch", "act_res_seq", "act_embed")
        return x, aux

    # ---------------- serving ----------------
    def init_cache(self, batch: int, max_len: int, dtype,
                   page_tokens: Optional[int] = None,
                   n_pages: Optional[int] = None):
        """Decode-cache allocation. With ``page_tokens``/``n_pages`` the
        full-attention K/V (and int8 scale) caches come up in POOL form —
        ``(n_pages, page_tokens, ...)`` pages addressed through the
        engine's page table — instead of dense ``(batch, max_len, ...)``
        slot rows. Windowed rings and recurrent state keep their per-slot
        layout either way."""
        if self.kind == "attn":
            hd = self.mixer.hd
            window = self.cfg.window
            kv = self.cfg.n_kv
            if page_tokens is not None and not window:
                lead = (n_pages, page_tokens)
            else:
                t = min(max_len, window) if window else max_len
                lead = (batch, t)
            if self.cfg.kv_dtype == "int8" and not window:
                return {
                    "k": jnp.zeros((*lead, kv, hd), jnp.int8),
                    "v": jnp.zeros((*lead, kv, hd), jnp.int8),
                    "ks": jnp.zeros((*lead, kv), jnp.float32),
                    "vs": jnp.zeros((*lead, kv), jnp.float32),
                }
            return {
                "k": jnp.zeros((*lead, kv, hd), dtype),
                "v": jnp.zeros((*lead, kv, hd), dtype),
            }
        return self.mixer.init_state(batch)

    def prefill(self, params, x, *, positions=None):
        h = self.norm1(params["norm1"], x)
        if self.kind == "attn":
            h, (k, v) = self.mixer.prefill(params["mixer"], h, positions)
            if self.cfg.kv_dtype == "int8" and not self.cfg.window:
                from repro.nn.attention import quantize_kv

                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                cache = {"k": kq, "v": vq, "ks": ks, "vs": vs}
            else:
                cache = {"k": k, "v": v}
        elif self.kind == "ssm":
            h, cache = self.mixer.forward_with_state(params["mixer"], h)
        else:  # rec: rerun scan, keep final state
            # full forward + final recurrent state via decode-equivalent scan
            h_out = self.mixer(params["mixer"], h)
            cache = self._rec_final_state(params["mixer"], h)
            h = h_out
        x = x + h
        if self.has_ffn:
            h = self.norm2(params["norm2"], x)
            if self.use_moe:
                h, _ = self.ffn(params["ffn"], h)
            else:
                h = self.ffn(params["ffn"], h)
            x = x + h
        x = logical_constraint(x, "act_batch", "act_res_seq", "act_embed")
        return x, cache

    def _rec_final_state(self, params, h):
        """RG-LRU final (h, conv window) after a prefill pass."""
        mixer: RGLRUBlock = self.mixer
        xin = mixer.in_x(params["in_x"], h)
        xi = mixer._conv(params, xin)
        a, b = mixer._gates(params, xi)
        from repro.nn.rglru import _lru_scan

        hstates = _lru_scan(a, b)
        tail = xin[:, -(mixer.conv_width - 1):, :]
        pad = mixer.conv_width - 1 - tail.shape[1]
        if pad > 0:
            tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
        return {"h": hstates[:, -1], "conv": tail}

    def decode_step(self, params, x, cache, *, lengths,
                    page_table=None, active=None):
        h = self.norm1(params["norm1"], x)
        if self.kind == "attn":
            window = self.cfg.window
            if window:
                # ring-buffer positions within the bounded window cache
                slot = lengths % cache["k"].shape[1]
                h, ck, cv = self._windowed_decode(params["mixer"], h, cache, lengths, slot)
                cache = {"k": ck, "v": cv}
            elif "ks" in cache:
                h, cache = self.mixer.decode_step_quant(
                    params["mixer"], h, cache, lengths,
                    page_table=page_table, active=active,
                )
            else:
                h, ck, cv = self.mixer.decode_step(
                    params["mixer"], h, cache["k"], cache["v"], lengths,
                    page_table=page_table, active=active,
                )
                cache = {"k": ck, "v": cv}
        else:
            h, cache = self.mixer.decode_step(params["mixer"], h, cache)
        x = x + h
        if self.has_ffn:
            h = self.norm2(params["norm2"], x)
            if self.use_moe:
                h, _ = self.ffn(params["ffn"], h)
            else:
                h = self.ffn(params["ffn"], h)
            x = x + h
        return x, cache

    def extend(self, params, x, cache, *, positions, valid, page_table=None):
        """Advance a (B, C) column block at per-slot offsets against the
        decode cache (chunked prefill). ``positions`` (B, C) are absolute
        token positions; ``valid`` (B, C) marks real columns — padding
        columns never write a cache row and never advance recurrent state,
        so a slot moves by exactly its count of valid columns (0 leaves it
        untouched up to dtype). ``page_table`` routes full-attention K/V
        writes through the paged pool.
        """
        h = self.norm1(params["norm1"], x)
        if self.kind == "attn":
            if self.cfg.window:
                h, cache = self._windowed_extend(
                    params["mixer"], h, cache, positions, valid
                )
            elif "ks" in cache:
                h, cache = self.mixer.extend_quant(
                    params["mixer"], h, cache, positions, valid,
                    page_table=page_table,
                )
            else:
                h, ck, cv = self.mixer.extend(
                    params["mixer"], h, cache["k"], cache["v"], positions,
                    valid, page_table=page_table,
                )
                cache = {"k": ck, "v": cv}
        else:
            h, cache = self.mixer.extend(params["mixer"], h, cache, valid)
        x = x + h
        if self.has_ffn:
            h = self.norm2(params["norm2"], x)
            if self.use_moe:
                h, _ = self.ffn(params["ffn"], h)
            else:
                h = self.ffn(params["ffn"], h)
            x = x + h
        return x, cache

    def _windowed_extend(self, params, x, cache, positions, valid):
        """Chunked prefill against the sliding-window ring cache.

        Writes cannot be applied before the attend here: a column's write
        EVICTS the ring entry ``t`` positions back, which earlier columns
        of the same chunk may still need (it is inside their window). So
        queries attend the concatenation [old ring ; this chunk's fresh
        K/V] — in-chunk keys come from the fresh tensors — and the ring is
        updated afterwards. Ring writes keep one winner per ring slot (the
        last valid column of each residue class, ``j >= n_new - t``);
        shadowed and padding columns are dropped via an out-of-bounds
        index, never an unordered duplicate scatter.
        """
        import math as _math

        from repro.nn.attention import _attend_core, make_mask

        mixer: Attention = self.mixer
        b, c, _ = x.shape
        t = cache["k"].shape[1]
        window = self.cfg.window or t + 1
        q, k, v = mixer._qkv(params, x, None, positions, positions)

        # old-ring key positions, from the PRE-chunk frontier: ring slot j
        # holds the largest written position p <= lengths-1 with p ≡ j (t)
        last = positions[:, :1] - 1                  # (B, 1) frontier - 1
        ring = jnp.arange(t)[None, :]
        k_pos_old = last - jnp.mod(last - ring, t)   # (B, T); < 0 if empty
        k_cat = jnp.concatenate([cache["k"], k.astype(cache["k"].dtype)], axis=1)
        v_cat = jnp.concatenate([cache["v"], v.astype(cache["v"].dtype)], axis=1)
        mask_ring = make_mask(
            positions, k_pos_old, causal=True, window=window,
            k_valid=k_pos_old >= 0,
        )
        mask_chunk = make_mask(
            positions, positions, causal=True, window=window,
            k_valid=valid,
        )
        mask = jnp.concatenate([mask_ring, mask_chunk], axis=-1)
        out = _attend_core(
            mixer._group(q), k_cat, v_cat, mask, 1.0 / _math.sqrt(mixer.hd)
        )
        y = mixer.wo(params["wo"], out.reshape(b, c, mixer.n_heads * mixer.hd))

        n_new = jnp.sum(valid, axis=1)
        win = valid & (jnp.arange(c)[None, :] >= (n_new[:, None] - t))
        bidx = jnp.arange(b)[:, None]
        widx = jnp.where(win, positions % t, t)      # t == OOB -> dropped
        ck = cache["k"].at[bidx, widx].set(k.astype(cache["k"].dtype), mode="drop")
        cv = cache["v"].at[bidx, widx].set(v.astype(cache["v"].dtype), mode="drop")
        return y, {"k": ck, "v": cv}

    def _windowed_decode(self, params, x, cache, lengths, slot):
        """Sliding-window decode against a ring-buffer cache of size t<=W.

        Invariant: ring slot j holds the KV of the largest absolute position
        p <= lengths with p ≡ j (mod t). Prefill establishes this via a roll
        (see _pad_cache); each decode step maintains it.
        """
        import math as _math

        from repro.nn.attention import _attend_core

        mixer: Attention = self.mixer
        b = x.shape[0]
        t = cache["k"].shape[1]
        positions = lengths[:, None]
        q, k, v = mixer._qkv(params, x, None, positions, positions)
        idx = jnp.arange(b)
        ck = cache["k"].at[idx, slot].set(k[:, 0])
        cv = cache["v"].at[idx, slot].set(v[:, 0])
        ring = jnp.arange(t)[None, :]
        k_pos = lengths[:, None] - jnp.mod(lengths[:, None] - ring, t)
        valid = (k_pos >= 0) & (
            lengths[:, None] - k_pos < (self.cfg.window or t + 1)
        )
        mask = valid[:, None, :]
        out = _attend_core(
            mixer._group(q), ck, cv, mask, 1.0 / _math.sqrt(mixer.hd)
        )
        y = mixer.wo(params["wo"], out.reshape(b, 1, mixer.n_heads * mixer.hd))
        return y, ck, cv


@dataclasses.dataclass
class Segment:
    """A scanned stack of identical blocks, or one unrolled block."""

    block: Block
    n: int
    scanned: bool
    name: str

    def specs(self) -> mod.SpecTree:
        s = self.block.specs()
        return mod.stack_specs(s, self.n) if self.scanned else s


class DecoderLM:
    def __init__(self, cfg: ArchConfig, ctx: Optional[ModelContext] = None):
        self.cfg = cfg
        self.ctx = ctx or ModelContext(policy=cfg.tbn)
        c = self.ctx
        self.embed = Embedding(cfg.vocab, cfg.d_model, c, name="embed")
        self.segments: List[Segment] = self._build_segments()
        self.final_norm = _norm(cfg, c, cfg.d_model, "final_norm")
        if not cfg.tie_embeddings:
            self.head = Dense(cfg.d_model, cfg.vocab, c, name="lm_head",
                              kind="head", logical=("vocab", "embed"))

    def _build_segments(self) -> List[Segment]:
        cfg, c = self.cfg, self.ctx
        segs: List[Segment] = []
        if cfg.family == "ssm":
            segs.append(Segment(
                Block(cfg, c, "ssm", False, name="ssm_block"),
                cfg.n_layers, True, "stack"))
        elif cfg.family == "hybrid":
            pat = cfg.pattern
            n_super = len(pat)
            full, rem = divmod(cfg.n_layers, n_super)
            segs.append(Segment(
                _PatternBlock(cfg, c, pat, name="hybrid"),
                full, True, "stack"))
            for i in range(rem):
                segs.append(Segment(
                    Block(cfg, c, pat[i], False, name=f"tail{i}"),
                    1, False, f"tail{i}"))
        elif cfg.family in ("moe",):
            n = cfg.n_layers
            if cfg.moe.first_dense:
                segs.append(Segment(
                    Block(cfg, c, "attn", False, name="dense0"),
                    1, False, "dense0"))
                n -= 1
            segs.append(Segment(
                Block(cfg, c, "attn", True, name="moe_block"),
                n, True, "stack"))
        else:  # dense / vlm
            segs.append(Segment(
                Block(cfg, c, "attn", False, name="block"),
                cfg.n_layers, True, "stack"))
        return segs

    # ------------------------------------------------------------------
    def specs(self) -> mod.SpecTree:
        out = {
            "embed": self.embed.specs(),
            "final_norm": self.final_norm.specs(),
        }
        for i, seg in enumerate(self.segments):
            out[f"seg{i}"] = seg.specs()
        if not self.cfg.tie_embeddings:
            out["head"] = self.head.specs()
        return out

    def init(self, key) -> dict:
        return mod.init_params(self.specs(), key)

    def abstract(self) -> dict:
        return mod.abstract_params(self.specs())

    def logical(self) -> dict:
        return mod.logical_axes(self.specs())

    # ------------------------------------------------------------------
    def _embed_inputs(self, params, batch) -> jax.Array:
        x = self.embed(params["embed"], batch["tokens"])
        if self.cfg.modality == "vlm" and "image_embeds" in batch:
            # early fusion: image positions carry precomputed VQ embeddings
            m = batch["image_mask"][..., None]
            x = jnp.where(m, batch["image_embeds"].astype(x.dtype), x)
        return logical_constraint(x, "act_batch", "act_res_seq", "act_embed")

    def _remat(self, f):
        if self.cfg.remat == "none":
            return f
        if self.cfg.remat == "dots":
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.checkpoint_dots
            )
        return jax.checkpoint(f)

    def backbone(self, params, x, *, positions=None) -> Tuple[jax.Array, jax.Array]:
        aux_total = jnp.zeros((), jnp.float32)
        for i, seg in enumerate(self.segments):
            p = params[f"seg{i}"]
            if not seg.scanned:
                x, aux = seg.block(p, x, positions=positions)
                aux_total += aux
            elif self.cfg.force_unroll:
                # roofline path: every layer appears once in the HLO so
                # cost_analysis counts it (a while body is visited once)
                for j in range(seg.n):
                    pl = jax.tree.map(lambda v: v[j], p)
                    x, aux = seg.block(pl, x, positions=positions)
                    aux_total += aux
            else:
                def body(carry, pl):
                    h, auxa = carry
                    h, aux = seg.block(pl, h, positions=positions)
                    return (h, auxa + aux), None

                body = self._remat(body)
                (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), p)
        return self.final_norm(params["final_norm"], x), aux_total

    def logits(self, params, h) -> jax.Array:
        if self.cfg.tie_embeddings:
            out = self.embed.attend(params["embed"], h)
        else:
            out = self.head(params["head"], h)
        return logical_constraint(out, "act_batch", "act_seq", "act_vocab")

    def train_forward(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        """Next-token CE loss. batch: tokens (B,S) [+ vlm extras]."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = self._embed_inputs(params, batch)
        h, aux = self.backbone(params, x, positions=positions)
        # Full-sequence logits (S stays divisible for the sequence-parallel
        # sharding); the shifted last position is masked out of the loss.
        targets = jnp.roll(tokens, -1, axis=1)
        valid = (jnp.arange(s) < s - 1).astype(jnp.float32)[None, :]
        mask = batch.get("loss_mask", jnp.ones_like(targets, jnp.float32)) * valid
        nll = self._ce_sum(params, h, targets, mask)
        ce = nll / jnp.maximum(mask.sum(), 1.0)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    def _ce_sum(self, params, h, targets, mask) -> jax.Array:
        """Summed token NLL. Batch-chunked + remat'd when large: the (B, S,
        V) f32 logits of a 150k-vocab model would otherwise be the single
        biggest training buffer (2.7-16 GB/device); chunking bounds it to
        one sub-batch and the backward recomputes per chunk."""
        b = h.shape[0]
        # chunk size stays a multiple of 32 so each sub-batch still shards
        # over the full (pod, data) DP extent of the 2-pod mesh
        nb = (
            b // 32
            if (b % 32 == 0 and h.shape[1] * self.cfg.vocab >= 2**26)
            else 1
        )
        if nb <= 1:
            return self._ce_sum_chunk(params, h, targets, mask)
        resh = lambda z: z.reshape(nb, b // nb, *z.shape[1:])

        def body(acc, inp):
            hc, tc, mc = inp
            return acc + self._ce_sum_chunk(params, hc, tc, mc), None

        body = jax.checkpoint(body)
        tot, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (resh(h), resh(targets), resh(mask)),
        )
        return tot

    def _ce_sum_chunk(self, params, h, targets, mask) -> jax.Array:
        # scan xs lose their sharding through the chunk loop on the 3-axis
        # mesh — re-pin batch here or the (chunk, S, V) f32 logits replicate
        h = logical_constraint(h, "act_batch", None, None)
        targets = logical_constraint(targets, "act_batch", None)
        mask = logical_constraint(mask, "act_batch", None)
        logits = self.logits(params, h)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), targets[..., None], axis=-1
        )[..., 0]
        return jnp.sum((logz - gold) * mask)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                    page_tokens: Optional[int] = None,
                    n_pages: Optional[int] = None):
        """Decode caches for ``batch`` slots. With ``page_tokens``/
        ``n_pages`` the full-attention families allocate POOL form (one
        page index space shared by every layer — the engine's single page
        table addresses them all); other families are per-slot either
        way."""
        caches = []
        for seg in self.segments:
            c = seg.block.init_cache(
                batch, max_len, dtype,
                page_tokens=page_tokens, n_pages=n_pages,
            )
            if seg.scanned:
                c = jax.tree.map(
                    lambda v: jnp.broadcast_to(v[None], (seg.n, *v.shape)), c
                )
            caches.append(c)
        return caches

    # ---- per-slot cache surgery (engine-side bookkeeping helpers) ----
    def _leaf_blocks(self):
        for seg in self.segments:
            stack = [seg.block]
            while stack:
                b = stack.pop()
                if b.kind == "pattern":
                    stack.extend(b.blocks)
                else:
                    yield b

    @property
    def has_full_attn(self) -> bool:
        """Any full-attention layer -> the engine stands up a page pool."""
        return any(_paged_attn(b) for b in self._leaf_blocks())

    @property
    def has_recurrent_state(self) -> bool:
        """Any cache family that cannot be paged (SSM / RG-LRU carries,
        windowed rings) -> prefix reuse needs boundary snapshots."""
        return any(not _paged_attn(b) for b in self._leaf_blocks())

    # decoder-only: no encoder memory, no read-only cross-attention pool
    has_cross_attn = False

    def cache_families(self):
        """ServableModel cache-family descriptors (DESIGN.md §6.5)."""
        from repro.serve.servable import CacheFamily

        fams = []
        if self.has_full_attn:
            fams.append(CacheFamily("self_attn", paged=True))
        if self.has_recurrent_state:
            fams.append(CacheFamily("recurrent", paged=False))
        return tuple(fams)

    def reset_slot_caches(self, caches, slot, paged: bool = False):
        """Zero one slot's rows across the per-slot cache families:
        recurrent/SSM state MUST restart from zeros (extend continues from
        the slot's carry), windowed rings are cleared for hygiene. Paged
        pool leaves are left alone — their pages are shared or about to be
        remapped, and stale rows are position-masked."""
        out = []
        for seg, c in zip(self.segments, caches):
            ax = 1 if seg.scanned else 0

            def per_block(blk, ct, ax=ax):
                if paged and _paged_attn(blk):
                    return ct
                return jax.tree.map(
                    lambda v: v.at[(slice(None),) * ax + (slot,)].set(
                        jnp.zeros((), v.dtype)
                    ),
                    ct,
                )

            out.append(_map_block_cache(seg.block, per_block, c))
        return out

    def snapshot_slot_caches(self, caches, slot):
        """One slot's NON-PAGED cache state as a standalone pytree — the
        prefix trie pins this at page boundaries. Full-attention entries
        are None (their prefix lives in pool pages); recurrent mixers own
        their slice semantics (ssm/rglru ``snapshot_state``); windowed
        rings copy the slot's ring rows."""
        snaps = []
        for seg, c in zip(self.segments, caches):
            ax = 1 if seg.scanned else 0

            def per_block(blk, ct, ax=ax):
                if blk.kind in ("rec", "ssm"):
                    return blk.mixer.snapshot_state(ct, slot, axis=ax)
                if blk.kind == "attn" and blk.cfg.window:
                    return mod.slice_slot_rows(ct, slot, ax)
                return None

            snaps.append(_map_block_cache(seg.block, per_block, c))
        return snaps

    def restore_slot_caches(self, caches, slot, snaps):
        """Map a pinned snapshot back into a slot (prefix-hit admission).
        None entries (full-attention families) pass through — the page
        table, not the pool contents, carries their prefix."""
        out = []
        for seg, c, s in zip(self.segments, caches, snaps):
            ax = 1 if seg.scanned else 0
            if s is None:
                out.append(c)
                continue

            def per_block(blk, ct, st, ax=ax):
                if st is None:
                    return ct
                if blk.kind in ("rec", "ssm"):
                    return blk.mixer.restore_state(ct, slot, st, axis=ax)
                return mod.set_slot_rows(ct, slot, st, ax)

            out.append(_map_block_cache(seg.block, per_block, c, s))
        return out

    def prefill(self, params, batch, max_len: int):
        """Run the prompt, return (last-position logits, caches, lengths)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = self._embed_inputs(params, batch)
        caches = []
        for i, seg in enumerate(self.segments):
            p = params[f"seg{i}"]
            if not seg.scanned:
                x, cache = seg.block.prefill(p, x, positions=positions)
            elif self.cfg.force_unroll:
                per_layer = []
                for j in range(seg.n):
                    pl = jax.tree.map(lambda v: v[j], p)
                    x, cl = seg.block.prefill(pl, x, positions=positions)
                    per_layer.append(cl)
                cache = jax.tree.map(lambda *ls: jnp.stack(ls), *per_layer)
            else:
                def body(h, pl):
                    h2, cache = seg.block.prefill(pl, h, positions=positions)
                    return h2, cache

                x, cache = jax.lax.scan(body, x, p)
            # pad attention caches out to max_len
            cache = self._pad_cache(seg, cache, max_len, prompt_len=s)
            caches.append(cache)
        h = self.final_norm(params["final_norm"], x[:, -1:])
        logits = self.logits(params, h)
        lengths = jnp.full((b,), s, jnp.int32)
        return logits[:, 0], caches, lengths

    def _pad_cache(self, seg, cache, max_len, prompt_len=None):
        """Grow attention caches to serving size; set up window ring order."""
        window = self.cfg.window
        t_axis = 2 if seg.scanned else 1

        def pad_kv(v):
            t = v.shape[t_axis]
            target = min(max_len, window) if window else max_len
            if t > target:  # window: keep last `target` entries...
                sl = [slice(None)] * v.ndim
                sl[t_axis] = slice(t - target, t)
                v = v[tuple(sl)]
                # ...and roll so slot j holds position p ≡ j (mod target)
                if prompt_len is not None:
                    v = jnp.roll(v, prompt_len % target, axis=t_axis)
            elif t < target:
                widths = [(0, 0)] * v.ndim
                widths[t_axis] = (0, target - t)
                v = jnp.pad(v, widths)
                if window and prompt_len is not None and t == prompt_len:
                    # short prompt in a ring cache: entries already at slots
                    # 0..t-1 == their positions mod target (t <= target).
                    pass
            return v

        def rec(c):
            if isinstance(c, dict) and "k" in c and "v" in c:
                # every leaf (k/v codes and ks/vs scales) has the time
                # axis at the same index, so one pad rule covers them all
                return {name: pad_kv(vv) for name, vv in c.items()}
            if isinstance(c, dict):
                return {k: rec(v) for k, v in c.items()}
            return c

        return rec(cache)

    def _walk_segments(self, params, x, caches, step_fn):
        """Shared serving segment loop for decode_step/extend.

        ``step_fn(block, layer_params, x, cache) -> (x, cache)`` is applied
        once per layer. For scanned segments the stacked cache rides in the
        CARRY and is updated with a dynamic_update_slice at the live layer
        index: while-loop carries alias in place, so the step holds ONE
        cache buffer. (As scan xs->ys the cache double-buffers — an extra
        10.7 GB/device for the 32B config at 32k x 128.)
        """
        new_caches = []
        for i, seg in enumerate(self.segments):
            p = params[f"seg{i}"]
            cache = caches[i]
            if not seg.scanned:
                x, cache = step_fn(seg.block, p, x, cache)
            elif self.cfg.force_unroll:
                per_layer = []
                for j in range(seg.n):
                    pl = jax.tree.map(lambda v: v[j], p)
                    cl = jax.tree.map(lambda v: v[j], cache)
                    x, c2 = step_fn(seg.block, pl, x, cl)
                    per_layer.append(c2)
                cache = jax.tree.map(lambda *ls: jnp.stack(ls), *per_layer)
            else:
                def body(carry, pl):
                    h, full, idx = carry
                    cl = jax.tree.map(
                        lambda v: jax.lax.dynamic_index_in_dim(
                            v, idx, 0, keepdims=False
                        ),
                        full,
                    )
                    # Barrier: stops XLA hoisting per-layer cache converts
                    # out of the loop (LICM would materialize an f32 copy
                    # of the ENTIRE stacked KV cache). int8 codes cannot
                    # be promoted, so only float cache leaves need it
                    # (§Perf iteration B3: neutral, kept for clarity).
                    needs_barrier = any(
                        jnp.issubdtype(v.dtype, jnp.floating)
                        for v in jax.tree_util.tree_leaves(cl)
                        if v.ndim >= 4
                    )
                    if needs_barrier:
                        cl = jax.lax.optimization_barrier(cl)
                    h2, c2 = step_fn(seg.block, pl, h, cl)
                    full = jax.tree.map(
                        lambda v, n: jax.lax.dynamic_update_index_in_dim(
                            v, n.astype(v.dtype), idx, 0
                        ),
                        full, c2,
                    )
                    return (h2, full, idx + 1), None

                (x, cache, _), _ = jax.lax.scan(
                    body, (x, cache, jnp.int32(0)), p
                )
            new_caches.append(cache)
        return x, new_caches

    def decode_step(self, params, tokens, caches, lengths,
                    page_table=None, active=None):
        """tokens: (B, 1) -> (logits (B, vocab), new caches).

        ``page_table`` (B, npp) routes full-attention K/V through the
        paged pool; ``active`` (B,) confines those pool writes to live
        decoding slots (per-slot families are confined by the engine's
        merge instead)."""
        x = self.embed(params["embed"], tokens)
        x, new_caches = self._walk_segments(
            params, x, caches,
            lambda blk, pl, h, cl: blk.decode_step(
                pl, h, cl, lengths=lengths,
                page_table=page_table, active=active,
            ),
        )
        h = self.final_norm(params["final_norm"], x)
        logits = self.logits(params, h)
        return logits[:, 0], new_caches, lengths + 1

    def extend(self, params, tokens, caches, lengths, n_new,
               page_table=None):
        """Chunked-prefill step: advance each slot by its next n_new[b]
        prompt tokens against the shared decode caches.

        tokens: (B, C) — column j of slot b carries the prompt token at
        absolute position lengths[b] + j; columns >= n_new[b] are padding
        (no cache write, no state advance, output discarded). Returns
        (logits at each slot's LAST VALID column (B, vocab), caches,
        lengths + n_new); a slot with n_new == 0 is untouched and its
        logits row is meaningless. ``page_table`` routes full-attention
        K/V through the paged pool.
        """
        b, c = tokens.shape
        positions = lengths[:, None] + jnp.arange(c)[None, :]
        valid = jnp.arange(c)[None, :] < n_new[:, None]
        x = self.embed(params["embed"], tokens)
        x, new_caches = self._walk_segments(
            params, x, caches,
            lambda blk, pl, h, cl: blk.extend(
                pl, h, cl, positions=positions, valid=valid,
                page_table=page_table,
            ),
        )
        idx = jnp.clip(n_new - 1, 0, c - 1)
        h_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)
        h = self.final_norm(params["final_norm"], h_last)
        logits = self.logits(params, h)
        return logits[:, 0], new_caches, lengths + n_new

    def merge_caches(self, old, new, keep, paged: bool = False):
        """Per-slot cache select: rows where ``keep`` (B,) is True take the
        new cache, others keep the old — the engine uses this to confine a
        batched decode step to its live-decoding slots (a prefilling
        neighbor's caches must not see the step's garbage writes).

        Paged pool leaves have no slot axis to select on; their writes
        were already confined in-kernel (the ``active`` mask drops an
        inactive slot's scatter), so with ``paged`` the full-attention
        families take the new pool wholesale."""
        merged = []
        for seg, o, n in zip(self.segments, old, new):
            ax = 1 if seg.scanned else 0

            def per_block(blk, ov_tree, nv_tree, ax=ax):
                if paged and _paged_attn(blk):
                    return nv_tree

                def sel(ov, nv, ax=ax):
                    shape = [1] * ov.ndim
                    shape[ax] = keep.shape[0]
                    return jnp.where(
                        keep.reshape(shape), nv.astype(ov.dtype), ov
                    )

                return jax.tree.map(sel, ov_tree, nv_tree)

            merged.append(_map_block_cache(seg.block, per_block, o, n))
        return merged


@dataclasses.dataclass
class _PatternBlock:
    """Super-block: the hybrid cycle (e.g. rec, rec, attn) as one unit."""

    cfg: ArchConfig
    ctx: ModelContext
    pattern: Tuple[str, ...]
    name: str = "pattern"

    def __post_init__(self):
        self.blocks = [
            Block(self.cfg, self.ctx, kind, False, name=f"{self.name}.{i}_{kind}")
            for i, kind in enumerate(self.pattern)
        ]
        self.kind = "pattern"

    def specs(self) -> mod.SpecTree:
        return {f"b{i}": b.specs() for i, b in enumerate(self.blocks)}

    def __call__(self, params, x, *, positions=None):
        aux = jnp.zeros((), jnp.float32)
        for i, b in enumerate(self.blocks):
            x, a = b(params[f"b{i}"], x, positions=positions)
            aux += a
        return x, aux

    def init_cache(self, batch, max_len, dtype, page_tokens=None,
                   n_pages=None):
        return {
            f"b{i}": b.init_cache(batch, max_len, dtype,
                                  page_tokens=page_tokens, n_pages=n_pages)
            for i, b in enumerate(self.blocks)
        }

    def _forward(self, method, params, x, cache, **kw):
        """THE single serving call site through the pattern: thread the
        residual stream through each sub-block's ``method`` and collect
        the per-sub-block cache subtrees under the ``b{i}`` keys every
        cache walker recurses on (``_map_block_cache``). ``cache=None``
        (prefill) means the sub-block builds its cache instead of
        consuming one."""
        out = {}
        for i, b in enumerate(self.blocks):
            args = (x,) if cache is None else (x, cache[f"b{i}"])
            x, out[f"b{i}"] = getattr(b, method)(params[f"b{i}"], *args, **kw)
        return x, out

    def prefill(self, params, x, *, positions=None):
        return self._forward("prefill", params, x, None, positions=positions)

    def decode_step(self, params, x, cache, *, lengths,
                    page_table=None, active=None):
        return self._forward("decode_step", params, x, cache,
                             lengths=lengths, page_table=page_table,
                             active=active)

    def extend(self, params, x, cache, *, positions, valid, page_table=None):
        return self._forward("extend", params, x, cache, positions=positions,
                             valid=valid, page_table=page_table)

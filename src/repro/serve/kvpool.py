"""Block-paged KV-cache pool: host-side page accounting for the engine.

The serving attention caches are no longer dense per-slot ``(max_len,)``
row blocks but a POOL of fixed-size pages (``page_tokens`` cache rows
each) shared by every full-attention layer: page id ``i`` addresses the
same physical page index in every layer's pool tensor, so one int32 page
table ``(n_slots, max_len // page_tokens)`` translates a slot's absolute
token positions for the whole stack (vLLM-style block tables, adapted to
fixed-shape XLA — the jitted tick gathers a per-slot contiguous view and
scatters new rows through the table; see ``nn.attention.gather_pages``).

This module is the HOST side only: a free-list allocator with per-page
refcounts. Copy-on-write degenerates to never-copy by construction —
only COMPLETE pages are ever shared (the prefix trie pins page-aligned
runs, and a slot admitted on a prefix hit starts writing at the page
boundary), so a shared page is read-only for its whole lifetime and
sharing is pure refcounting:

    * a slot mapping a page (its own fresh page, or a trie hit) holds
      one reference until retirement;
    * the prefix trie holds one reference per node it pins;
    * a page returns to the free list when the last reference drops.

Device tensors never move: mapping a cached prefix into a slot is O(1)
page-table bookkeeping, no K/V bytes are copied.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


class KVPool:
    """Refcounted free-list allocator over ``n_pages`` KV pages."""

    def __init__(self, n_pages: int, page_tokens: int,
                 family: str = "self_attn"):
        if n_pages <= 0:
            raise ValueError(f"n_pages must be positive: {n_pages}")
        if page_tokens <= 0:
            raise ValueError(f"page_tokens must be positive: {page_tokens}")
        self.n_pages = n_pages
        self.page_tokens = page_tokens
        # which ServableModel cache family this pool backs ("self_attn",
        # "cross_attn", ...) — labels stats()/diagnostics only, the
        # allocator itself is family-agnostic
        self.family = family
        self.refcounts = np.zeros((n_pages,), np.int64)
        # LIFO free list: a just-freed page is reused first, keeping the
        # working set of touched pages (and their cache lines) small
        self._free: List[int] = list(range(n_pages - 1, -1, -1))

    # ------------------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """Take a free page (refcount 1), or None when the pool is empty
        — the caller decides whether to evict or fail."""
        if not self._free:
            return None
        pid = self._free.pop()
        assert self.refcounts[pid] == 0, f"free page {pid} had references"
        self.refcounts[pid] = 1
        return pid

    def retain(self, pid: int) -> None:
        """Add a reference to a live page (prefix-hit mapping, trie pin)."""
        if self.refcounts[pid] <= 0:
            raise ValueError(f"retain of unreferenced page {pid}")
        self.refcounts[pid] += 1

    def release(self, pid: int) -> None:
        """Drop one reference; the page returns to the free list at zero."""
        if self.refcounts[pid] <= 0:
            raise ValueError(f"release of unreferenced page {pid}")
        self.refcounts[pid] -= 1
        if self.refcounts[pid] == 0:
            self._free.append(pid)

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def utilization(self) -> float:
        """Referenced fraction of the pool in [0, 1] — the pressure
        number the telemetry gauge (serve_pool_utilization) samples at
        scrape time."""
        return (self.n_pages - len(self._free)) / self.n_pages

    def check(self) -> None:
        """Invariants the property tests pin: refcounts never negative,
        free list and referenced pages exactly partition the pool."""
        assert np.all(self.refcounts >= 0)
        assert len(set(self._free)) == len(self._free)
        assert int(np.sum(self.refcounts > 0)) + len(self._free) == self.n_pages
        assert all(self.refcounts[p] == 0 for p in self._free)

from repro.serve.detok import DetokenizeWorker, PieceCodec, decode_all
from repro.serve.engine import (
    AdmissionQueueFull,
    BatchedEngine,
    Request,
    ServeConfig,
)
from repro.serve.kvpool import KVPool
from repro.serve.prefix import PrefixTrie
from repro.serve.sampling import SamplingParams, sample_logits
from repro.serve.server import EngineServer, ServerConfig, run_server
from repro.serve.weights import (
    export_serving_params,
    per_device_tile_bytes,
    serving_bytes,
    tile_serving_bytes,
)

__all__ = [
    "AdmissionQueueFull",
    "BatchedEngine",
    "DetokenizeWorker",
    "EngineServer",
    "KVPool",
    "PieceCodec",
    "PrefixTrie",
    "Request",
    "SamplingParams",
    "ServeConfig",
    "ServerConfig",
    "decode_all",
    "run_server",
    "sample_logits",
    "export_serving_params",
    "per_device_tile_bytes",
    "serving_bytes",
    "tile_serving_bytes",
]

from repro.serve.engine import BatchedEngine, Request, ServeConfig
from repro.serve.kvpool import KVPool
from repro.serve.prefix import PrefixTrie
from repro.serve.sampling import sample_logits
from repro.serve.weights import (
    export_serving_params,
    per_device_tile_bytes,
    serving_bytes,
    tile_serving_bytes,
)

__all__ = [
    "BatchedEngine",
    "KVPool",
    "PrefixTrie",
    "Request",
    "ServeConfig",
    "sample_logits",
    "export_serving_params",
    "per_device_tile_bytes",
    "serving_bytes",
    "tile_serving_bytes",
]

"""Host-side detokenization, OFF the tick loop.

The engine tick must never block on string work: per-token host-side
text assembly (piece lookup, whitespace merging, UTF-8 style buffering)
is pure Python and can easily cost more than a reduced model's jitted
decode step. The serving front-end therefore routes every emitted token
through a BACKLOG drained by one dedicated worker thread:

    engine tick (on_token) --> DetokenizeWorker.backlog --> codec -->
        emit(stream_id, event)   [worker thread]

Two pieces live here:

* ``PieceCodec`` — token ids -> text pieces. The repo trains on synthetic
  token streams, so there is no learned vocabulary; the codec maps ids
  through a caller-supplied piece table or a deterministic synthetic one
  (sentencepiece-flavored: pieces carry a leading ``▁`` word marker that
  renders as a space everywhere but stream start). It is STATEFUL per
  stream — the first piece of a stream strips its leading space — which
  is exactly the statefulness that makes mid-stream flush semantics
  worth testing.
* ``DetokenizeWorker`` — the backlog thread. ``close()`` enqueues a
  sentinel BEHIND everything already in the backlog and joins, so every
  token emitted before shutdown still gets detokenized and delivered:
  a server closing mid-stream flushes partial text instead of dropping
  it (the shutdown regression wall in tests/test_server.py).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Optional, Sequence

WORD_MARK = "▁"  # ▁ sentencepiece-style leading-space marker


class PieceCodec:
    """Token ids -> text pieces, with per-stream leading-space state.

    ``pieces[tid]`` supplies the piece table; ids outside the table (or
    with no table) fall back to the deterministic synthetic piece
    ``▁t<tid>`` so every id detokenizes to SOMETHING reproducible —
    serving must not crash on a vocabulary-edge token.
    """

    def __init__(self, pieces: Optional[Sequence[str]] = None):
        self.pieces = list(pieces) if pieces is not None else None

    def piece(self, tid: int) -> str:
        if self.pieces is not None and 0 <= tid < len(self.pieces):
            return self.pieces[tid]
        return f"{WORD_MARK}t{tid}"

    def new_stream(self) -> "StreamDetok":
        return StreamDetok(self)


class StreamDetok:
    """One stream's incremental decoder: feed token ids, get text deltas.

    The concatenation of every returned delta is byte-identical to
    ``decode_all`` over the same ids — chunking never changes the bytes,
    which is the property the SSE parity tests assert.
    """

    def __init__(self, codec: PieceCodec):
        self.codec = codec
        self._at_start = True
        self.text = ""          # everything decoded so far

    def feed(self, tid: int) -> str:
        piece = self.codec.piece(tid)
        if piece.startswith(WORD_MARK):
            piece = ("" if self._at_start else " ") + piece[len(WORD_MARK):]
        self._at_start = False
        self.text += piece
        return piece


def decode_all(codec: PieceCodec, ids: Sequence[int]) -> str:
    """Whole-sequence reference decoding (the non-streaming path)."""
    s = codec.new_stream()
    for t in ids:
        s.feed(int(t))
    return s.text


_SENTINEL = object()


class DetokenizeWorker:
    """The detokenize backlog thread.

    ``push(stream_id, token, final)`` is called from the engine tick
    thread (cheap: one queue put). The worker owns the per-stream codec
    state and calls ``emit(stream_id, event)`` — from the WORKER thread —
    with event dicts shaped for the SSE layer:

        {"token": int, "text": str, "index": int}            per token
        {"done": True, "finish_reason": str, "text": str,
         "n_tokens": int}                                    per finish

    ``close()`` drains before joining: the sentinel enqueues behind every
    pending token, so partial text reaches its stream even when the
    server shuts down mid-flight. Idempotent.
    """

    def __init__(self, emit: Callable[[object, dict], None],
                 codec: Optional[PieceCodec] = None):
        self.codec = codec or PieceCodec()
        self.emit = emit
        self.backlog: "queue.Queue[object]" = queue.Queue()
        self._streams: Dict[object, StreamDetok] = {}
        self._counts: Dict[object, int] = {}
        # high-water mark of the backlog, tracked at push (the producer
        # side): the worst tick-thread-to-text lag the process has seen —
        # the telemetry gauge reads it alongside the live ``depth``
        self.peak_depth = 0
        self._thread = threading.Thread(
            target=self._run, name="detokenize-backlog", daemon=True)
        self._closed = False
        self._thread.start()

    # ---- producer side (engine tick thread) ---------------------------
    def push(self, stream_id, token: int):
        self.backlog.put((stream_id, int(token)))
        d = self.backlog.qsize()
        if d > self.peak_depth:
            self.peak_depth = d

    def finish(self, stream_id, reason: str):
        self.backlog.put((stream_id, _SENTINEL, reason))

    @property
    def depth(self) -> int:
        return self.backlog.qsize()

    # ---- worker side --------------------------------------------------
    def _run(self):
        while True:
            item = self.backlog.get()
            if item is _SENTINEL:
                return
            if len(item) == 3:                       # stream finished
                sid, _, reason = item
                s = self._streams.pop(sid, None)
                n = self._counts.pop(sid, 0)
                self.emit(sid, {
                    "done": True, "finish_reason": reason,
                    "text": s.text if s is not None else "",
                    "n_tokens": n,
                })
                continue
            sid, tok = item
            s = self._streams.get(sid)
            if s is None:
                s = self._streams[sid] = self.codec.new_stream()
                self._counts[sid] = 0
            delta = s.feed(tok)
            idx = self._counts[sid]
            self._counts[sid] = idx + 1
            self.emit(sid, {"token": tok, "text": delta, "index": idx})

    def close(self, timeout: float = 10.0):
        """Flush the backlog, then stop and join the worker thread."""
        if self._closed:
            return
        self._closed = True
        self.backlog.put(_SENTINEL)
        self._thread.join(timeout)
        if self._thread.is_alive():                  # pragma: no cover
            raise RuntimeError(
                "detokenize worker failed to drain within "
                f"{timeout}s ({self.backlog.qsize()} backlogged)")

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

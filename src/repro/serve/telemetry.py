"""Zero-dependency serving telemetry: metrics, spans, trace events.

The serving stack's only runtime visibility used to be the flat counter
dict of ``BatchedEngine.stats()`` — fine for a drained batch, useless
against a live server where the question is "where did THIS request's
latency go" or "which tick phase regressed". This module is the
observation layer (DESIGN.md §6.6):

* :class:`MetricsRegistry` — process-local registry of counters, gauges
  and fixed-bucket log-spaced histograms, cheap enough to update from
  the tick thread (an ``observe`` is one bisect over ~40 precomputed
  edges + two adds) and rendered on demand in the Prometheus text
  exposition format by ``render()`` (the server's ``GET /metrics``).
* :class:`RequestSpan` — one request's lifecycle: submit → admit →
  first token → finish, with every wall-clock moment attributed to
  exactly one phase (``queue``, ``encode``, ``prefill``, ``decode``,
  ``parked``). Intervals are disjoint and cover [submit, finish], so
  ``sum(phases.values()) == wall`` up to float error — the invariant
  tests/test_telemetry.py pins (as ``<= wall``).
* :class:`TraceRing` — optional bounded ring of structured JSON-able
  trace events (submit/admit/preempt/resume/finish/retrace), drained to
  a ``--trace-log`` JSONL sink by the CLI.
* :class:`EngineTelemetry` — the standard serving metric families plus
  the span/ring plumbing, bound to one engine (and extended in place by
  the HTTP front-end with its request/stream metrics).

Telemetry is OBSERVATION ONLY: nothing here feeds back into scheduling
or sampling, and emitted tokens are byte-identical with it on or off
(the parity wall in tests/test_telemetry.py).

Threading model: each metric has ONE writer thread in practice (engine
metrics: the tick thread; HTTP metrics: the asyncio loop thread) and
any number of reader threads. Writes are single CPython bytecode-level
ops on ints/floats under the GIL; readers may see a value one update
stale, never a torn one. Label-child creation is the only cross-thread
mutation and takes the family lock.

Metric naming scheme (DESIGN.md §6.6): ``serve_<noun>[_<unit>]`` with
``_total`` for counters, ``_seconds`` for duration histograms, bare
nouns for gauges. Everything serving-side shares the ``serve_`` prefix
so one Prometheus match selects the whole subsystem.
"""
from __future__ import annotations

import bisect
import collections
import re
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def log_buckets(lo: float, hi: float, per_decade: int = 6) -> Tuple[float, ...]:
    """Log-spaced histogram edges: ``per_decade`` buckets per factor of
    10, spanning [lo, hi]. Fixed at construction so ``observe`` is one
    bisect — no dynamic resizing on the tick thread."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi: ({lo}, {hi})")
    if per_decade < 1:
        raise ValueError(f"per_decade must be >= 1: {per_decade}")
    growth = 10.0 ** (1.0 / per_decade)
    edges, e = [], lo
    while e < hi * (1 + 1e-9):
        # 4 significant digits: "0.0001468", not "0.0001467799267622069" —
        # the exposition (le="...") and dashboards stay readable, and at
        # any sane per_decade the rounded edges stay strictly increasing
        edges.append(float(f"{e:.4g}"))
        e *= growth
    return tuple(edges)


# default duration edges: 10µs .. 100s — wide enough for a µs-scale tick
# phase and a multi-second cold TTFT in one family
DURATION_BUCKETS = log_buckets(1e-5, 100.0, per_decade=6)


def _fmt(v) -> str:
    """Exposition value/edge formatting: ints stay ints, floats use
    repr (shortest round-trip — '1e-05', not '0.00001')."""
    f = float(v)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, v.replace("\\", r"\\").replace('"', r"\"")
                     .replace("\n", r"\n"))
        for k, v in labels
    )
    return "{" + inner + "}"


class Counter:
    """Monotonic counter. Optionally fn-backed (``fn`` returns the
    current value at scrape time — for pre-existing monotonic sources
    like ``PrefixTrie.evictions`` that should not be double-counted)."""

    __slots__ = ("labels", "value", "fn")

    def __init__(self, labels=(), fn: Optional[Callable[[], float]] = None):
        self.labels = labels
        self.value = 0
        self.fn = fn

    def inc(self, n=1):
        self.value += n

    def get(self):
        return self.fn() if self.fn is not None else self.value


class Gauge:
    """Point-in-time value; ``set`` for pushed values, ``fn`` for
    scrape-time sampling (pool utilization, queue depth — zero cost on
    the tick thread)."""

    __slots__ = ("labels", "value", "fn")

    def __init__(self, labels=(), fn: Optional[Callable[[], float]] = None):
        self.labels = labels
        self.value = 0.0
        self.fn = fn

    def set(self, v):
        self.value = v

    def get(self):
        return self.fn() if self.fn is not None else self.value


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` holds observations with
    ``v <= edges[i]`` (exclusive of lower edges), ``counts[-1]`` the
    +Inf overflow. Per-bucket (non-cumulative) storage keeps ``observe``
    one bisect + three adds; ``render`` cumulates."""

    __slots__ = ("labels", "edges", "counts", "sum", "count")

    def __init__(self, labels=(), edges: Sequence[float] = DURATION_BUCKETS):
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError(f"edges must be strictly increasing: {edges}")
        self.labels = labels
        self.edges = tuple(float(e) for e in edges)
        self.counts = [0] * (len(self.edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-interpolated quantile (the ``histogram_quantile``
        estimate): linear within the containing bucket, lower bound 0
        for the first bucket, the last finite edge for +Inf. None when
        empty. Accurate to one bucket width — the numpy-reference test
        bounds it by the edge growth factor."""
        if self.count == 0:
            return None
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target and c > 0:
                if i == len(self.edges):
                    return self.edges[-1]
                lo = self.edges[i - 1] if i > 0 else 0.0
                frac = (target - (cum - c)) / c
                return lo + frac * (self.edges[i] - lo)
        return self.edges[-1]  # pragma: no cover - cum==count>=target


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class _Family:
    """One metric name: its TYPE/HELP metadata plus one child per label
    value combination (a single unlabeled child when ``labels=()``)."""

    def __init__(self, name: str, help_: str, type_: str,
                 label_names: Tuple[str, ...], **child_kw):
        self.name = name
        self.help = help_
        self.type = type_
        self.label_names = label_names
        self._child_kw = child_kw
        self._children: Dict[Tuple[str, ...], object] = {}
        self._lock = threading.Lock()
        if not label_names:
            self._children[()] = _TYPES[type_](labels=(), **child_kw)

    def labels(self, **kv):
        """The child for one label-value combination, created on first
        use (under the family lock — the only cross-thread mutation)."""
        if set(kv) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.label_names)}")
        key = tuple(str(kv[k]) for k in self.label_names)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    child = _TYPES[self.type](
                        labels=tuple(zip(self.label_names, key)),
                        **self._child_kw)
                    self._children[key] = child
        return child

    # unlabeled families proxy the single child so call sites read
    # ``registry.counter(...).inc()`` without a labels() hop
    def _solo(self):
        if self.label_names:
            raise ValueError(f"{self.name} has labels {self.label_names}: "
                             f"use .labels(...)")
        return self._children[()]

    def inc(self, n=1):
        self._solo().inc(n)

    def set(self, v):
        self._solo().set(v)

    def observe(self, v):
        self._solo().observe(v)

    def get(self):
        return self._solo().get()

    def quantile(self, q):
        return self._solo().quantile(q)

    @property
    def children(self):
        with self._lock:
            return dict(self._children)


class MetricsRegistry:
    """Name -> family map with Prometheus text rendering.

    Registration is idempotent: re-registering an identical
    (name, type, labels) returns the existing family (a second
    front-end attaching to the same engine must not crash the server),
    while a conflicting re-registration raises — two meanings for one
    name is exactly the bug a registry exists to prevent.
    """

    def __init__(self):
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _register(self, name, help_, type_, labels, **child_kw) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labels = tuple(labels)
        for ln in labels:
            if not _LABEL_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.type != type_ or fam.label_names != labels:
                    raise ValueError(
                        f"metric {name} already registered as {fam.type}"
                        f"{fam.label_names}, not {type_}{labels}")
                # refresh fn bindings on re-registration: a new server
                # attaching to the engine re-points scrape callbacks at
                # its own live objects instead of a dead predecessor's
                fn = child_kw.get("fn")
                if fn is not None and not labels:
                    fam._children[()].fn = fn
                return fam
            fam = _Family(name, help_, type_, labels, **child_kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help_: str = "", labels=(),
                fn: Optional[Callable[[], float]] = None) -> _Family:
        kw = {"fn": fn} if fn is not None else {}
        return self._register(name, help_, "counter", labels, **kw)

    def gauge(self, name: str, help_: str = "", labels=(),
              fn: Optional[Callable[[], float]] = None) -> _Family:
        kw = {"fn": fn} if fn is not None else {}
        return self._register(name, help_, "gauge", labels, **kw)

    def histogram(self, name: str, help_: str = "", labels=(),
                  edges: Sequence[float] = DURATION_BUCKETS) -> _Family:
        return self._register(name, help_, "histogram", labels, edges=edges)

    def get(self, name: str) -> Optional[_Family]:
        with self._lock:
            return self._families.get(name)

    def value_of(self, name: str, **labels):
        """Scrape one child's current value (None if absent) — the
        periodic stats line reads the registry through this."""
        fam = self.get(name)
        if fam is None:
            return None
        key = tuple(str(labels[k]) for k in fam.label_names
                    if k in labels)
        if len(key) != len(fam.label_names):
            return None
        child = fam.children.get(key)
        if child is None:
            return None
        return child.count if fam.type == "histogram" else child.get()

    def render(self) -> str:
        """Prometheus text exposition (format version 0.0.4): HELP/TYPE
        per family, cumulative ``le`` buckets + ``_sum``/``_count`` per
        histogram child."""
        out: List[str] = []
        with self._lock:
            families = sorted(self._families.items())
        for name, fam in families:
            if fam.help:
                out.append(f"# HELP {name} {fam.help}")
            out.append(f"# TYPE {name} {fam.type}")
            for child in fam.children.values():
                base = dict(child.labels)
                if fam.type == "histogram":
                    cum = 0
                    for edge, c in zip(child.edges, child.counts):
                        cum += c
                        lab = _label_str(tuple(base.items())
                                         + (("le", _fmt(edge)),))
                        out.append(f"{name}_bucket{lab} {cum}")
                    cum += child.counts[-1]
                    lab = _label_str(tuple(base.items()) + (("le", "+Inf"),))
                    out.append(f"{name}_bucket{lab} {cum}")
                    ls = _label_str(tuple(base.items()))
                    out.append(f"{name}_sum{ls} {_fmt(child.sum)}")
                    out.append(f"{name}_count{ls} {cum}")
                else:
                    out.append(f"{name}{_label_str(child.labels)} "
                               f"{_fmt(child.get())}")
        return "\n".join(out) + "\n"


# ---------------------------------------------------------------------
# request lifecycle spans

QUEUE, ENCODE, PREFILL, DECODE, PARKED = (
    "queue", "encode", "prefill", "decode", "parked")
SPAN_PHASES = (QUEUE, ENCODE, PREFILL, DECODE, PARKED)


class RequestSpan:
    """One request's wall-clock lifecycle, every moment attributed to
    exactly one phase. Transitions close the open interval into
    ``phases`` and open the next, so intervals are disjoint and cover
    [submit_t, finish_t] — ``sum(phases.values())`` equals the wall
    time up to float rounding, which is the ``<=`` invariant the span
    test pins across preemption and encdec ENCODE phases."""

    __slots__ = ("rid", "submit_t", "admit_t", "first_token_t", "finish_t",
                 "finish_reason", "phases", "phase", "_t0", "last_token_t")

    def __init__(self, rid: int, now: float):
        self.rid = rid
        self.submit_t = now
        self.admit_t: Optional[float] = None
        self.first_token_t: Optional[float] = None
        self.finish_t: Optional[float] = None
        self.finish_reason: Optional[str] = None
        self.phases: Dict[str, float] = {}
        self.phase = QUEUE
        self._t0 = now
        self.last_token_t: Optional[float] = None

    def to_phase(self, phase: str, now: float):
        dt = now - self._t0
        if dt > 0:
            self.phases[self.phase] = self.phases.get(self.phase, 0.0) + dt
        self.phase = phase
        self._t0 = now

    def mark_admit(self, now: float, phase: str):
        self.admit_t = now
        self.to_phase(phase, now)

    def token(self, now: float) -> bool:
        """Record a token emission; True when it was the first."""
        first = self.first_token_t is None
        if first:
            self.first_token_t = now
        self.last_token_t = now
        return first

    def finish(self, now: float, reason: str):
        self.to_phase("done", now)
        self.finish_t = now
        self.finish_reason = reason

    @property
    def wall(self) -> Optional[float]:
        return (self.finish_t - self.submit_t
                if self.finish_t is not None else None)


# ---------------------------------------------------------------------
# structured trace events

class TraceRing:
    """Bounded ring of structured trace events. ``append`` is one deque
    append (thread-safe under the GIL); overflow silently drops the
    OLDEST events and counts them, so a long-running server with a
    forgotten ring never grows without bound."""

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1: {capacity}")
        self.capacity = capacity
        self._buf: collections.deque = collections.deque(maxlen=capacity)
        self._seen = 0

    def emit(self, event: str, **fields):
        self._seen += 1
        rec = {"ts": round(time.time(), 6), "event": event}
        rec.update(fields)
        self._buf.append(rec)

    def __len__(self):
        return len(self._buf)

    @property
    def dropped(self) -> int:
        return max(0, self._seen - self.capacity)

    def drain(self) -> List[dict]:
        out = list(self._buf)
        self._buf.clear()
        return out

    def write_jsonl(self, path) -> int:
        """Flush the ring to a JSON-lines file (the ``--trace-log``
        sink); returns how many events were written."""
        import json

        events = self.drain()
        with open(path, "a") as f:
            for rec in events:
                f.write(json.dumps(rec) + "\n")
        return len(events)


# ---------------------------------------------------------------------
# the serving metric families

# tick phase vocabulary (DESIGN.md §6.6): admission bookkeeping, the
# preempt/resume pass, the one-per-tick encoder call, and the prefill /
# decode jitted calls split device-vs-host — "device" ends at
# block_until_ready on the sampled tokens, "host" is the numpy pull +
# python token/retirement loop after it.
TICK_PHASES = ("admission", "preempt", "encode",
               "prefill_device", "prefill_host",
               "decode_device", "decode_host")


class EngineTelemetry:
    """The standard serving metric families over one registry, plus the
    span bookkeeping and the optional trace ring. Engine-side only —
    the HTTP front-end registers its own families into the same
    registry so one ``/metrics`` scrape covers the whole process."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 trace_events: int = 0):
        self.registry = r = registry or MetricsRegistry()
        self.ring = TraceRing(trace_events) if trace_events else None

        self.submitted = r.counter(
            "serve_requests_submitted_total", "Requests accepted by submit()")
        self.finished = r.counter(
            "serve_requests_finished_total",
            "Requests finished, by finish reason", labels=("reason",))
        self.rejected = r.counter(
            "serve_requests_rejected_total",
            "Submits rejected by admission-queue backpressure")
        self.tokens = r.counter(
            "serve_tokens_total", "Output tokens emitted by the tick loop")
        self.prefill_tokens = r.counter(
            "serve_prefill_tokens_total",
            "Prompt tokens streamed through chunked prefill")
        self.prefix_lookups = r.counter(
            "serve_prefix_lookups_total",
            "Prefix-trie admission lookups, by result", labels=("result",))
        self.preempts = r.counter(
            "serve_preempts_total", "Slots parked by the preempt pass")
        self.resumes = r.counter(
            "serve_resumes_total", "Parked requests resumed into a slot")
        self.encode_ticks = r.counter(
            "serve_encode_ticks_total", "Encoder passes run by the ENCODE phase")
        self.retraces = r.counter(
            "serve_retraces_total",
            "Tick-function retraces observed after warmup() "
            "(steady state must stay 0)")

        self.ttft = r.histogram(
            "serve_request_ttft_seconds",
            "Submit to first emitted token, queue wait included")
        self.itl = r.histogram(
            "serve_request_itl_seconds",
            "Gap between consecutive emitted tokens of one request")
        self.e2e = r.histogram(
            "serve_request_e2e_seconds", "Submit to finish, whole lifecycle")
        self.queue_wait = r.histogram(
            "serve_request_queue_wait_seconds", "Submit to slot admission")
        self.tick = r.histogram(
            "serve_tick_seconds", "One engine tick, all phases")
        tick_phase = r.histogram(
            "serve_tick_phase_seconds", "One engine tick, by phase",
            labels=("phase",))
        # children pre-resolved so the tick path never takes the family
        # lock or hashes label kwargs
        self.tick_phase = {p: tick_phase.labels(phase=p)
                           for p in TICK_PHASES}

    def bind_engine(self, engine):
        """Register the scrape-time gauges that read live engine state
        (zero tick-thread cost: sampled only when /metrics renders)."""
        r = self.registry
        r.gauge("serve_queue_depth", "Requests waiting for admission",
                fn=lambda: engine._queue.qsize())
        r.gauge("serve_live_slots", "Slots with a live request",
                fn=lambda: len(engine._live))
        r.gauge("serve_free_slots", "Unoccupied slots",
                fn=lambda: len(engine._free))
        r.gauge("serve_parked_requests", "Preempted requests awaiting resume",
                fn=lambda: len(engine._parked))
        pools = r.gauge("serve_pool_pages", "KV pool capacity in pages",
                        labels=("family",))
        used = r.gauge("serve_pool_pages_used", "KV pool pages referenced",
                       labels=("family",))
        util = r.gauge("serve_pool_utilization",
                       "KV pool pages referenced / capacity",
                       labels=("family",))
        for pool in (engine.pool, engine.xpool):
            if pool is None:
                continue
            pools.labels(family=pool.family).fn = (
                lambda p=pool: p.n_pages)
            used.labels(family=pool.family).fn = (
                lambda p=pool: p.used_pages)
            util.labels(family=pool.family).fn = (
                lambda p=pool: p.utilization)
        if engine.trie is not None:
            r.gauge("serve_trie_nodes", "Prefix-trie nodes pinned",
                    fn=lambda: len(engine.trie))
            r.counter("serve_trie_evictions_total",
                      "Prefix-trie LRU leaf evictions",
                      fn=lambda: engine.trie.evictions)
            # the trie owns its lookup bookkeeping (PrefixTrie.match);
            # fn-backing the children avoids a second engine-side count
            self.prefix_lookups.labels(result="hit").fn = (
                lambda: engine.trie.hits)
            self.prefix_lookups.labels(result="miss").fn = (
                lambda: engine.trie.misses)
        if engine.enc_cache is not None:
            r.gauge("serve_enc_cache_entries",
                    "Cached encoder outputs (digest-keyed)",
                    fn=lambda: len(engine.enc_cache))
        return self

    # ---- scrape-side summaries ---------------------------------------
    def latency_summary(self) -> Dict[str, Dict[str, Optional[float]]]:
        """Histogram quantiles in ms for the enriched ``/stats`` body
        and the loadgen summary: {"ttft_ms": {"p50":…, "p99":…,
        "count":…}, …}. Quantiles are bucket-interpolated — accurate to
        one log-bucket width."""
        def q(h):
            return {
                "p50": _ms(h.quantile(0.50)),
                "p99": _ms(h.quantile(0.99)),
                "count": h.count if hasattr(h, "count") else h._solo().count,
            }

        return {
            "ttft_ms": q(self.ttft._solo()),
            "itl_ms": q(self.itl._solo()),
            "e2e_ms": q(self.e2e._solo()),
            "queue_wait_ms": q(self.queue_wait._solo()),
            "tick_ms": q(self.tick._solo()),
        }


def _ms(v: Optional[float]) -> Optional[float]:
    return None if v is None else round(1e3 * v, 3)

"""Token sampling: greedy / temperature / top-k, pure-functional."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0       # 0 -> greedy
    top_k: Optional[int] = None
    max_tokens: int = 64
    eos_id: int = -1               # -1 -> never stops on a token


def sample_logits(
    logits: jax.Array,
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
) -> jax.Array:
    """logits (B, V) -> token ids (B,). Static sampler config (jit-stable)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k is not None and top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

"""Token sampling: greedy / temperature / top-k, pure-functional.

Two entry points:

* ``sample_logits``       — static scalar config, one shared key for the
  whole batch. Kept for single-stream callers and tests.
* ``sample_logits_batch`` — per-row ``(B,)`` temperature / top-k arrays
  AND per-row ``(B, 2)`` PRNG keys as *runtime* values, so a
  continuous-batching engine can serve slots with different request
  params from ONE jitted tick. Row ``i`` samples exactly what
  ``sample_logits(logits[i:i+1], keys[i], ...)`` would: the engine keys
  each row from its request's own key stream (``fold_in(request_key,
  token_index)``), which makes every request's tokens independent of
  scheduling order, batch composition, and prefill chunking — the
  invariant the chunked-vs-monolithic parity tests pin down.

``SamplingParams`` fields default to ``None`` sentinels meaning "inherit
the engine default" — an explicit ``temperature=0.0`` (greedy) or
``top_k=0`` (restriction off) therefore wins over a stochastic
``ServeConfig`` default instead of being swallowed by truthiness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: Optional[float] = None  # None -> engine default; 0 -> greedy
    top_k: Optional[int] = None          # None -> engine default; 0 -> off
    max_tokens: int = 64
    eos_id: int = -1                     # -1 -> never stops on a token
    seed: Optional[int] = None           # explicit per-request PRNG seed:
    # the request's key stream becomes PRNGKey(seed) instead of
    # fold_in(engine_root, rid), so a stochastic request's tokens no
    # longer depend on WHICH rid the admission order handed it — the
    # property a concurrent streaming front-end needs for reproducible
    # sampling (greedy requests never consume their key either way)
    priority: Optional[str] = None       # scheduling class ("interactive"
    # | "batch"; engine.PRIORITY_RANKS is authoritative). None inherits
    # ServeConfig.default_priority. Scheduling-only: it orders admission
    # and selects preemption victims but NEVER touches sampling, so a
    # request's tokens are identical at any priority (the preemption
    # parity wall depends on that)

    def resolve(
        self, default_temperature: float, default_top_k: Optional[int]
    ) -> "ResolvedSampling":
        """Fill ``None`` sentinels from the engine defaults (``is None``
        checks — explicit falsy values like 0.0 / 0 are kept verbatim)."""
        t = self.temperature if self.temperature is not None \
            else default_temperature
        k = self.top_k if self.top_k is not None else default_top_k
        return ResolvedSampling(
            temperature=float(t),
            top_k=int(k) if k is not None else 0,
            eos_id=int(self.eos_id),
            seed=int(self.seed) if self.seed is not None else None,
        )

    # ---- HTTP handoff -------------------------------------------------
    _JSON_FIELDS = ("temperature", "top_k", "max_tokens", "eos_id", "seed",
                    "priority")

    @classmethod
    def from_json(cls, body: dict) -> "SamplingParams":
        """Build params from a decoded request body, ignoring non-sampling
        keys (``prompt``, ``stream``, ...) so one body dict serves both
        the HTTP layer and the engine. Unknown *sampling-looking* typos
        are NOT guessed at — only the documented field names bind."""
        kw = {}
        for f in cls._JSON_FIELDS:
            if body.get(f) is not None:
                kw[f] = body[f]
        if "temperature" in kw:
            kw["temperature"] = float(kw["temperature"])
        for f in ("top_k", "max_tokens", "eos_id", "seed"):
            if f in kw:
                kw[f] = int(kw[f])
        if "priority" in kw:
            kw["priority"] = str(kw["priority"])
        return cls(**kw)

    def to_json(self) -> dict:
        """The inverse handoff (client helpers, loadgen replay): only
        non-default fields are emitted so a replayed request is exactly
        the submitted one."""
        out = {}
        for f in self._JSON_FIELDS:
            v = getattr(self, f)
            if v is not None and v != getattr(type(self)(), f):
                out[f] = v
        return out


@dataclasses.dataclass(frozen=True)
class ResolvedSampling:
    """Concrete per-request sampler state (no sentinels except ``seed``):
    what the engine stores in its per-slot arrays. ``top_k == 0`` means no
    restriction; ``seed is None`` means the engine derives the request key
    from its rid."""
    temperature: float
    top_k: int
    eos_id: int
    seed: Optional[int] = None


def sample_logits(
    logits: jax.Array,
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
) -> jax.Array:
    """logits (B, V) -> token ids (B,). Static sampler config (jit-stable)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    # k >= V restricts nothing (and would crash lax.top_k) — skip it, the
    # same semantics the batch sampler documents for its runtime k.
    if top_k is not None and 0 < top_k < logits.shape[-1]:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_logits_batch(
    logits: jax.Array,
    keys: jax.Array,
    *,
    temperature: jax.Array,
    top_k: jax.Array,
) -> jax.Array:
    """Row-wise sampling with per-row params AND per-row keys as runtime
    arrays.

    logits (B, V); keys (B, 2) uint32 — one PRNG key per row; temperature
    (B,) float (<= 0 -> greedy row); top_k (B,) int32 (0 or >= V -> no
    restriction). Returns token ids (B,) int32.

    Row i reproduces ``sample_logits(logits[i:i+1], keys[i], ...)``
    bit-for-bit: the stochastic path is the same Gumbel-argmax that
    ``jax.random.categorical`` computes, with row i's noise drawn from
    keys[i] alone. Greedy rows ignore their key entirely, so greedy
    requests are deterministic even when batched next to stochastic ones.
    """
    lf = logits.astype(jnp.float32)
    b, v = lf.shape
    if keys.shape[:1] != (b,) or keys.ndim != 2:
        raise ValueError(
            f"keys must be one PRNG key per row, shape ({b}, 2); got "
            f"{keys.shape} — a single shared key no longer identifies "
            "which request's stream each row consumes"
        )
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    temperature = temperature.astype(jnp.float32)
    k = jnp.clip(top_k.astype(jnp.int32), 0, v)
    # Greedy rows never need their top-k applied (argmax is always in the
    # top k), so they must not arm the sort path either — a greedy request
    # carrying an explicit top_k would otherwise force the full-vocab sort
    # for the whole batch on every tick.
    restrict = (k > 0) & (k < v) & (temperature > 0.0)

    def _stochastic(_):
        safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
        scaled = lf / safe_t[:, None]

        # Per-row k-th threshold from one descending sort: rows with a
        # varying runtime k cannot use lax.top_k (static k), but the k-th
        # largest value is just a gather into the sorted row. The sort is
        # gated too — unrestricted sampling never pays it.
        def _with_topk(s):
            sorted_desc = -jnp.sort(-s, axis=-1)
            kth = jnp.take_along_axis(
                sorted_desc, jnp.clip(k - 1, 0, v - 1)[:, None], axis=-1
            )
            return jnp.where(restrict[:, None] & (s < kth), -jnp.inf, s)

        masked = jax.lax.cond(
            jnp.any(restrict), _with_topk, lambda s: s, scaled
        )
        # categorical(key, row) == argmax(row + gumbel(key, row.shape)):
        # drawing each row's Gumbel noise from its own key keeps rows
        # independent of their batch neighbors (and of batch position).
        noise = jax.vmap(lambda kk: jax.random.gumbel(kk, (v,)))(keys)
        sampled = jnp.argmax(masked + noise, axis=-1).astype(jnp.int32)
        return jnp.where(temperature > 0.0, sampled, greedy)

    # All-greedy batches (the ServeConfig default) skip sampling entirely:
    # the decode tick then costs one argmax, same as before sampling moved
    # on-device — the sort/gumbel only run when a live slot asks.
    return jax.lax.cond(
        jnp.any(temperature > 0.0), _stochastic, lambda _: greedy, None
    )

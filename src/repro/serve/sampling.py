"""Token sampling: greedy / temperature / top-k, pure-functional.

Two entry points:

* ``sample_logits``       — static scalar config, one sampler per jit
  specialization. Kept for single-stream callers and tests.
* ``sample_logits_batch`` — per-row ``(B,)`` temperature / top-k arrays as
  *runtime* values, so a continuous-batching engine can serve slots with
  different request params from ONE jitted decode tick (no recompile when
  a new request lands in a slot, and only token ids cross back to host).

``SamplingParams`` fields default to ``None`` sentinels meaning "inherit
the engine default" — an explicit ``temperature=0.0`` (greedy) or
``top_k=0`` (restriction off) therefore wins over a stochastic
``ServeConfig`` default instead of being swallowed by truthiness.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: Optional[float] = None  # None -> engine default; 0 -> greedy
    top_k: Optional[int] = None          # None -> engine default; 0 -> off
    max_tokens: int = 64
    eos_id: int = -1                     # -1 -> never stops on a token

    def resolve(
        self, default_temperature: float, default_top_k: Optional[int]
    ) -> "ResolvedSampling":
        """Fill ``None`` sentinels from the engine defaults (``is None``
        checks — explicit falsy values like 0.0 / 0 are kept verbatim)."""
        t = self.temperature if self.temperature is not None \
            else default_temperature
        k = self.top_k if self.top_k is not None else default_top_k
        return ResolvedSampling(
            temperature=float(t),
            top_k=int(k) if k is not None else 0,
            eos_id=int(self.eos_id),
        )


@dataclasses.dataclass(frozen=True)
class ResolvedSampling:
    """Concrete per-request sampler state (no sentinels): what the engine
    stores in its per-slot arrays. ``top_k == 0`` means no restriction."""
    temperature: float
    top_k: int
    eos_id: int


def sample_logits(
    logits: jax.Array,
    key: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
) -> jax.Array:
    """logits (B, V) -> token ids (B,). Static sampler config (jit-stable)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    # k >= V restricts nothing (and would crash lax.top_k) — skip it, the
    # same semantics the batch sampler documents for its runtime k.
    if top_k is not None and 0 < top_k < logits.shape[-1]:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample_logits_batch(
    logits: jax.Array,
    key: jax.Array,
    *,
    temperature: jax.Array,
    top_k: jax.Array,
) -> jax.Array:
    """Row-wise sampling with per-row params as runtime arrays.

    logits (B, V); temperature (B,) float (<= 0 -> greedy row); top_k (B,)
    int32 (0 or >= V -> no restriction). Returns token ids (B,) int32.
    Greedy rows ignore the key, so greedy requests are deterministic even
    when batched next to stochastic ones.
    """
    lf = logits.astype(jnp.float32)
    v = lf.shape[-1]
    greedy = jnp.argmax(lf, axis=-1).astype(jnp.int32)

    temperature = temperature.astype(jnp.float32)
    k = jnp.clip(top_k.astype(jnp.int32), 0, v)
    # Greedy rows never need their top-k applied (argmax is always in the
    # top k), so they must not arm the sort path either — a greedy request
    # carrying an explicit top_k would otherwise force the full-vocab sort
    # for the whole batch on every tick.
    restrict = (k > 0) & (k < v) & (temperature > 0.0)

    def _stochastic(_):
        safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
        scaled = lf / safe_t[:, None]

        # Per-row k-th threshold from one descending sort: rows with a
        # varying runtime k cannot use lax.top_k (static k), but the k-th
        # largest value is just a gather into the sorted row. The sort is
        # gated too — unrestricted sampling never pays it.
        def _with_topk(s):
            sorted_desc = -jnp.sort(-s, axis=-1)
            kth = jnp.take_along_axis(
                sorted_desc, jnp.clip(k - 1, 0, v - 1)[:, None], axis=-1
            )
            return jnp.where(restrict[:, None] & (s < kth), -jnp.inf, s)

        masked = jax.lax.cond(
            jnp.any(restrict), _with_topk, lambda s: s, scaled
        )
        sampled = jax.random.categorical(key, masked, axis=-1)
        return jnp.where(temperature > 0.0, sampled.astype(jnp.int32), greedy)

    # All-greedy batches (the ServeConfig default) skip sampling entirely:
    # the decode tick then costs one argmax, same as before sampling moved
    # on-device — the sort/categorical only run when a live slot asks.
    return jax.lax.cond(
        jnp.any(temperature > 0.0), _stochastic, lambda _: greedy, None
    )

"""Minimal asyncio HTTP/SSE client for the serving front-end.

Stdlib-only on purpose: the tests, the load benchmark, and the CLI
burst mode all talk to ``EngineServer`` through these helpers, so the
wire format is exercised by the same few dozen lines everywhere — a
framing bug cannot hide behind a framework.

``sse_generate`` returns every SSE event plus a monotonic receive
timestamp per event, which is exactly what the load harness needs to
compute TTFT (submit -> first token event) and ITL (gaps between token
events) without instrumenting the server.
"""
from __future__ import annotations

import asyncio
import json
import time
from typing import List, Optional, Tuple


def generate_payload(prompt, *, max_tokens: Optional[int] = None,
                     temperature: Optional[float] = None,
                     top_k: Optional[int] = None,
                     seed: Optional[int] = None,
                     priority: Optional[str] = None,
                     stream: Optional[bool] = None) -> dict:
    """One place that spells the POST /generate body. ``None`` fields are
    omitted so the server's ``SamplingParams.from_json`` sees exactly the
    caller's intent (the engine fills defaults); ``priority`` is the
    scheduling class ("interactive" | "batch") the pressure scheduler
    orders admission by."""
    payload: dict = {"prompt": list(prompt)}
    for key, val in (("max_tokens", max_tokens), ("temperature", temperature),
                     ("top_k", top_k), ("seed", seed),
                     ("priority", priority), ("stream", stream)):
        if val is not None:
            payload[key] = val
    return payload


def _request_bytes(method: str, path: str, body: Optional[dict]) -> bytes:
    data = json.dumps(body).encode() if body is not None else b""
    head = (f"{method} {path} HTTP/1.1\r\n"
            f"Host: localhost\r\n"
            f"Content-Length: {len(data)}\r\n"
            f"Connection: close\r\n\r\n")
    return head.encode() + data


async def _read_head(reader: asyncio.StreamReader) -> Tuple[int, dict]:
    line = await reader.readline()
    status = int(line.split()[1])
    headers = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        k, _, v = h.decode("latin1").partition(":")
        headers[k.strip().lower()] = v.strip()
    return status, headers


async def request_json(host: str, port: int, method: str, path: str,
                       body: Optional[dict] = None) -> Tuple[int, dict]:
    """One plain JSON round-trip (``/stats``, ``/healthz``, rejects,
    non-streaming ``/generate``)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes(method, path, body))
        await writer.drain()
        status, headers = await _read_head(reader)
        n = int(headers.get("content-length", 0) or 0)
        raw = await reader.readexactly(n) if n else await reader.read()
        return status, json.loads(raw.decode() or "{}")
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionError:
            pass


async def request_text(host: str, port: int, method: str = "GET",
                       path: str = "/metrics") -> Tuple[int, str]:
    """One plain-text round-trip — the ``/metrics`` scrape (Prometheus
    text exposition, not JSON). Returns ``(status, body_text)``; error
    statuses return their JSON error body as raw text."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(_request_bytes(method, path, None))
        await writer.drain()
        status, headers = await _read_head(reader)
        n = int(headers.get("content-length", 0) or 0)
        raw = await reader.readexactly(n) if n else await reader.read()
        return status, raw.decode()
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionError:
            pass


async def sse_generate(
    host: str, port: int, payload: dict, *,
    read_delay: float = 0.0,
) -> Tuple[int, List[dict], List[float]]:
    """POST /generate and consume the SSE stream to the final event.

    Returns ``(status, events, recv_times)`` — ``recv_times[i]`` is the
    ``time.perf_counter()`` at which event i was parsed. On a non-200
    (e.g. the 429 backpressure reject) the JSON error body comes back as
    the single event. ``read_delay`` sleeps between event reads — the
    deliberately slow consumer the backpressure tests need."""
    reader, writer = await asyncio.open_connection(host, port)
    events: List[dict] = []
    times: List[float] = []
    try:
        writer.write(_request_bytes("POST", "/generate", payload))
        await writer.drain()
        status, headers = await _read_head(reader)
        if status != 200 or "text/event-stream" not in headers.get(
                "content-type", ""):
            n = int(headers.get("content-length", 0) or 0)
            raw = await reader.readexactly(n) if n else await reader.read()
            events.append(json.loads(raw.decode() or "{}"))
            times.append(time.perf_counter())
            return status, events, times
        buf = b""
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                return status, events, times
            buf += chunk
            while b"\n\n" in buf:
                frame, buf = buf.split(b"\n\n", 1)
                if not frame.startswith(b"data: "):
                    continue
                evt = json.loads(frame[len(b"data: "):].decode())
                events.append(evt)
                times.append(time.perf_counter())
                if evt.get("done") or "error" in evt:
                    return status, events, times
            if read_delay:
                await asyncio.sleep(read_delay)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except ConnectionError:
            pass

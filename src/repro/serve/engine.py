"""Batched serving engine: slot-based continuous batching with CHUNKED
prefill fused into the decode tick (Sarathi-style), over packed-tile
weights.

Design (vLLM/Sarathi-style, adapted to fixed-shape XLA):

* ``n_slots`` concurrent sequences share the decode caches. A request
  occupies a slot from admission to completion and moves through two
  phases: PREFILL (its prompt is streamed into the caches
  ``chunk_tokens`` columns at a time by a fixed-shape ``model.extend``
  call at per-slot offsets) then DECODE (one token per tick through the
  fixed-shape ``(n_slots, 1)`` decode step). Admission is O(1)
  bookkeeping — no model call — so a long prompt never stalls the tick
  loop the way the old admission-time monolithic prefill did.
* Each engine tick = scheduler + at most two jitted calls:
    1. a token-budget pass hands out ``chunk_tokens`` per tick,
       decode-priority: every decoding slot is charged 1 token first,
       the remainder goes to prefilling slots in admission order (the
       head-of-queue prefill always gets >= 1 so it cannot starve).
    2. ``_extend`` advances the scheduled prefill chunks (m = chunk rows
       per slot -> the matmul kernel path),
    3. ``_decode`` advances the decoding slots (m = n_slots rows -> the
       matvec kernel path); its writes are confined to decoding slots by
       a per-slot cache merge, so concurrent prefill state is untouched.
  Both calls have static shapes — nothing recompiles as requests come
  and go, and only token ids cross back to host.
* Sampling runs inside the jitted calls against per-slot ``(n_slots,)``
  temperature/top-k arrays AND per-slot PRNG keys: token t of request r
  is sampled with ``fold_in(fold_in(PRNGKey(seed), r.rid), t)``, so a
  request's tokens are a pure function of (weights, prompt, params,
  seed, rid) — independent of chunk size, batch neighbors, and
  scheduling order. That invariant is what the chunked-vs-monolithic
  parity tests pin down.
* Prompts are NOT padded into the context: slot positions start at 0 and
  only true prompt tokens enter the caches (padding columns of a chunk
  are dropped before the cache write). The old per-bucket left-padded
  prefill — and its per-admission full-cache splice — is gone; the only
  compiled prefill shape is the ``(n_slots, chunk_tokens)`` extend.
* Weights are SERVE-form (packed tiles + alphas, repro.serve.weights);
  passing ``mesh=`` places them with the serving sharding rules and
  traces extend/decode under those rules (DESIGN.md §5).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import queue
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import axis_rules, param_shardings
from repro.serve.sampling import SamplingParams, sample_logits_batch

PREFILL = "prefill"
DECODE = "decode"


def _tick_fns(model):
    """The three jitted serving entry points for ``model``, built once and
    cached ON the model object: every engine over the same model (replica
    pools, re-created engines, the test matrix's chunk-size sweeps) reuses
    one trace cache instead of recompiling per engine. The functions close
    over nothing but the model; batch width, chunk width, and — under a
    mesh — input shardings are ordinary retrace keys."""
    cached = getattr(model, "_serve_tick_fns", None)
    if cached is not None:
        return cached

    def _row_keys(base_keys, counts):
        return jax.vmap(jax.random.fold_in)(base_keys, counts)

    def _decode_tick(params, tokens, caches, lengths, active,
                     temps, topks, base_keys, counts):
        """decode step + per-slot sampling fused under one jit, confined
        to the ``active`` decoding slots: the (n_slots, vocab) logits
        never leave the device and prefilling/free slots keep their
        caches, lengths, and last token bit-identical."""
        logits, new_caches, new_lengths = model.decode_step(
            params, tokens, caches, lengths
        )
        nxt = sample_logits_batch(
            logits, _row_keys(base_keys, counts),
            temperature=temps, top_k=topks,
        )
        caches = model.merge_caches(caches, new_caches, active)
        lengths = jnp.where(active, new_lengths, lengths)
        nxt = jnp.where(active, nxt, tokens[:, 0])
        return nxt, caches, lengths

    def _extend_tick(params, block, caches, lengths, n_new,
                     temps, topks, base_keys, counts):
        """one chunked-prefill step for every scheduled slot + sampling of
        each slot's candidate first token (the host keeps it only for
        slots whose prompt just completed)."""
        logits, caches, lengths = model.extend(
            params, block, caches, lengths, n_new
        )
        toks = sample_logits_batch(
            logits, _row_keys(base_keys, counts),
            temperature=temps, top_k=topks,
        )
        return toks, caches, lengths

    def _reset_slot(caches, slot):
        """Zero one slot's rows across every cache family: recurrent/SSM
        state MUST start from zeros (extend continues from the slot's
        state), attention rows are cleared for hygiene."""
        out = []
        for seg, c in zip(model.segments, caches):
            ax = 1 if seg.scanned else 0
            out.append(jax.tree.map(
                lambda v: v.at[(slice(None),) * ax + (slot,)].set(
                    jnp.zeros((), v.dtype)
                ),
                c,
            ))
        return out

    fns = (jax.jit(_decode_tick), jax.jit(_extend_tick), jax.jit(_reset_slot))
    model._serve_tick_fns = fns
    return fns


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None  # "eos" | "length" once done
    admit_step: Optional[int] = None     # engine tick of admission
    token_steps: List[int] = dataclasses.field(default_factory=list)
    # engine tick at which each output token was emitted: token_steps[0]
    # is the TTFT tick; successive gaps are per-token inter-token ticks


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_len: int = 256                  # cache capacity per slot
    chunk_tokens: int = 32              # extend width == per-tick token budget
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        """Fail fast on a bad chunk width. chunk_tokens is both the extend
        call's compiled column count and the per-tick token budget; a
        non-positive value wedges the scheduler and one past max_len could
        scatter past the cache."""
        if self.chunk_tokens <= 0:
            raise ValueError(
                f"chunk_tokens must be positive: {self.chunk_tokens}"
            )
        if self.chunk_tokens > self.max_len:
            raise ValueError(
                f"chunk_tokens {self.chunk_tokens} exceeds max_len "
                f"{self.max_len}: a chunk could not fit the decode cache"
            )


class BatchedEngine:
    def __init__(self, model, params, cfg: ServeConfig, *, mesh=None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            # Place the serve weights with the serving rules: packed tile
            # rows ("tile_rows") shard over the model axis, ragged or
            # non-dividing dims drop to replicated (distributed/sharding).
            from repro.nn import module as mod

            logical = mod.logical_axes(model.specs())
            abstract = jax.tree.map(
                lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), params
            )
            shardings = param_shardings(
                mesh, logical, abstract_tree=abstract
            )
            params = jax.device_put(params, shardings)
        self.params = params
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._live: Dict[int, Request] = {}      # slot -> request
        self._free = list(range(cfg.n_slots))
        self._rid = itertools.count()
        self._root_key = jax.random.PRNGKey(cfg.seed)

        # per-slot phase machine (host side)
        self._phase = [None] * cfg.n_slots       # None | PREFILL | DECODE
        self._offsets = np.zeros((cfg.n_slots,), np.int64)  # prompt consumed
        self._admit_order: List[int] = []        # prefill scheduling FIFO

        cache_dtype = getattr(model.ctx, "compute_dtype", jnp.bfloat16)
        self.caches = model.init_caches(cfg.n_slots, cfg.max_len, cache_dtype)
        self.lengths = jnp.zeros((cfg.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((cfg.n_slots, 1), jnp.int32)
        # Per-slot sampling params, populated at admission from the
        # request's resolved SamplingParams (None sentinels -> ServeConfig
        # defaults). temps/topks/keys ride into the jitted calls as runtime
        # arrays; eos ids stay host-side for retirement bookkeeping.
        self.temps = jnp.zeros((cfg.n_slots,), jnp.float32)
        self.topks = jnp.zeros((cfg.n_slots,), jnp.int32)
        self._eos_ids = np.full((cfg.n_slots,), -1, np.int64)
        # per-slot request key + emitted-token count: token t of a request
        # samples with fold_in(request_key, t), independent of scheduling
        self._slot_keys = jnp.zeros((cfg.n_slots, 2), jnp.uint32)
        self._counts = np.zeros((cfg.n_slots,), np.int64)

        self._decode, self._extend, self._reset = _tick_fns(model)
        self.steps = 0

    def _mesh_ctx(self):
        """Sharding-rule context for traces/executions; no-op without mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self.mesh)

    # ------------------------------------------------------------------
    def submit(
        self, prompt, params: Optional[SamplingParams] = None
    ) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # Validate HERE, not at admission: a bad prompt then fails fast
        # without consuming a slot or wedging the tick loop mid-admission.
        if prompt.ndim != 1 or len(prompt) == 0:
            raise ValueError("prompt must be a non-empty 1-D token sequence")
        if len(prompt) > self.cfg.max_len:
            raise ValueError(
                f"prompt len {len(prompt)} exceeds max_len {self.cfg.max_len}"
            )
        req = Request(
            rid=next(self._rid),
            prompt=prompt,
            params=params or SamplingParams(),
        )
        self._queue.put(req)
        return req

    def _maybe_retire(self, slot: int, req: Request, tok: int) -> bool:
        """Retire a just-extended request. EOS is checked before the length
        cap so a stop token arriving exactly at max_tokens reports "eos"."""
        if tok == int(self._eos_ids[slot]):
            req.finish_reason = "eos"
        elif len(req.output) >= req.params.max_tokens:
            req.finish_reason = "length"
        else:
            return False
        req.done = True
        self._live.pop(slot, None)
        self._free.append(slot)
        self._phase[slot] = None
        if slot in self._admit_order:
            self._admit_order.remove(slot)
        # Reset the slot's sampling params: a stale temperature/top-k on a
        # dead slot would keep tripping jnp.any(...) in the batch sampler
        # and defeat its all-greedy / no-top-k fast paths for every later
        # tick until the slot is reused.
        self.temps = self.temps.at[slot].set(0.0)
        self.topks = self.topks.at[slot].set(0)
        self._eos_ids[slot] = -1
        self._counts[slot] = 0
        return True

    def _admit(self, slot: int, req: Request):
        """O(1) admission: claim the slot and zero its state — the prompt
        itself streams in through subsequent extend ticks."""
        self._live[slot] = req
        self._phase[slot] = PREFILL
        self._offsets[slot] = 0
        self._admit_order.append(slot)
        req.admit_step = self.steps
        self.lengths = self.lengths.at[slot].set(0)
        self.caches = self._reset(self.caches, slot)
        # Resolve the request's sampling params against the engine defaults
        # (is-None sentinels: an explicit temperature=0.0 / top_k=0 wins
        # over a stochastic ServeConfig default) and pin them to the slot —
        # every token of this request reads them from the per-slot arrays.
        res = req.params.resolve(self.cfg.temperature, self.cfg.top_k)
        self.temps = self.temps.at[slot].set(res.temperature)
        self.topks = self.topks.at[slot].set(res.top_k)
        self._eos_ids[slot] = res.eos_id
        self._slot_keys = self._slot_keys.at[slot].set(
            jax.random.fold_in(self._root_key, req.rid)
        )
        self._counts[slot] = 0

    # ------------------------------------------------------------------
    def _schedule_prefill(self, n_decoding: int) -> Dict[int, int]:
        """Token-budget pass: chunk_tokens per tick, decode-priority.

        Every decoding slot is charged one token up front; what remains
        goes to prefilling slots in admission order, each capped at the
        chunk width. The head of the prefill queue always receives at
        least one token so prefill progresses even when decoding slots
        consume the whole budget."""
        c = self.cfg.chunk_tokens
        budget = c - n_decoding
        takes: Dict[int, int] = {}
        first = True
        for slot in self._admit_order:
            if self._phase[slot] != PREFILL:
                continue
            rem = len(self._live[slot].prompt) - int(self._offsets[slot])
            floor = 1 if first else 0
            take = min(c, rem, max(budget, floor))
            first = False
            if take <= 0:
                continue
            takes[slot] = take
            budget -= take
        return takes

    def _run_extend(self, takes: Dict[int, int]):
        cfg = self.cfg
        block = np.zeros((cfg.n_slots, cfg.chunk_tokens), np.int32)
        n_new = np.zeros((cfg.n_slots,), np.int32)
        for slot, take in takes.items():
            off = int(self._offsets[slot])
            block[slot, :take] = self._live[slot].prompt[off:off + take]
            n_new[slot] = take
        toks, self.caches, self.lengths = self._extend(
            self.params, jnp.asarray(block), self.caches, self.lengths,
            jnp.asarray(n_new), self.temps, self.topks,
            self._slot_keys, jnp.asarray(self._counts),
        )
        toks_host = np.asarray(toks)
        for slot, take in takes.items():
            req = self._live[slot]
            self._offsets[slot] += take
            if self._offsets[slot] == len(req.prompt):
                # prompt complete: the chunk's last-column logits are the
                # request's first sampled token
                self._phase[slot] = DECODE
                self._admit_order.remove(slot)
                tok = int(toks_host[slot])
                req.output.append(tok)
                req.token_steps.append(self.steps)
                self._counts[slot] += 1
                self.tokens = self.tokens.at[slot, 0].set(tok)
                self._maybe_retire(slot, req, tok)

    def _run_decode(self, decoding: List[int]):
        active = np.zeros((self.cfg.n_slots,), bool)
        active[decoding] = True
        nxt, self.caches, self.lengths = self._decode(
            self.params, self.tokens, self.caches, self.lengths,
            jnp.asarray(active), self.temps, self.topks,
            self._slot_keys, jnp.asarray(self._counts),
        )
        nxt_host = np.asarray(nxt)
        self.tokens = nxt[:, None]
        for slot in decoding:
            req = self._live[slot]
            tok = int(nxt_host[slot])
            req.output.append(tok)
            req.token_steps.append(self.steps)
            self._counts[slot] += 1
            self._maybe_retire(slot, req, tok)

    def step(self):
        """One engine tick: admissions + scheduled prefill chunks + one
        batched decode step. Every live decoding slot emits exactly one
        token per tick regardless of concurrent prefill (the fairness
        invariant); a prefilling slot emits its first token on the tick
        its final chunk lands."""
        with self._mesh_ctx():
            while self._free and not self._queue.empty():
                self._admit(self._free.pop(0), self._queue.get())
            if not self._live:
                return
            decoding = [s for s in range(self.cfg.n_slots)
                        if self._phase[s] == DECODE]
            takes = self._schedule_prefill(len(decoding))
            if takes:
                self._run_extend(takes)
            if decoding:
                self._run_decode(decoding)
        self.steps += 1

    def run_until_drained(self, max_steps: int = 10_000, on_tick=None) -> int:
        """Step until every submitted request completes; returns the tick
        count. ``on_tick(engine)`` runs after each tick — drivers hook it
        for per-tick wall-clock latency accounting without forfeiting the
        bounded-steps wedge diagnostics below."""
        for i in range(max_steps):
            if self._queue.empty() and not self._live:
                return i
            self.step()
            if on_tick is not None:
                on_tick(self)
        slots = ", ".join(
            f"slot {s}: rid={r.rid} {self._phase[s]}"
            f"@{int(self._offsets[s])}/{len(r.prompt)}"
            f" ({len(r.output)}/{r.params.max_tokens} tok)"
            for s, r in sorted(self._live.items())
        )
        raise RuntimeError(
            f"engine did not drain after {max_steps} steps: "
            f"{self._queue.qsize()} queued, {len(self._live)} live — "
            f"{slots or 'no live slots'}"
        )

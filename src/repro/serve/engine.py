"""Batched serving engine: slot-based continuous batching with CHUNKED
prefill fused into the decode tick (Sarathi-style), over packed-tile
weights.

Design (vLLM/Sarathi-style, adapted to fixed-shape XLA):

* ``n_slots`` concurrent sequences share the decode caches. A request
  occupies a slot from admission to completion and moves through two
  phases: PREFILL (its prompt is streamed into the caches
  ``chunk_tokens`` columns at a time by a fixed-shape ``model.extend``
  call at per-slot offsets) then DECODE (one token per tick through the
  fixed-shape ``(n_slots, 1)`` decode step). Admission is O(1)
  bookkeeping — no model call — so a long prompt never stalls the tick
  loop the way the old admission-time monolithic prefill did.
* Each engine tick = scheduler + at most two jitted calls:
    1. a token-budget pass hands out ``chunk_tokens`` per tick,
       decode-priority: every decoding slot is charged 1 token first,
       the remainder goes to prefilling slots in admission order (the
       head-of-queue prefill always gets >= 1 so it cannot starve).
    2. ``_extend`` advances the scheduled prefill chunks (m = chunk rows
       per slot -> the matmul kernel path),
    3. ``_decode`` advances the decoding slots (m = n_slots rows -> the
       matvec kernel path); its writes are confined to decoding slots by
       a per-slot cache merge, so concurrent prefill state is untouched.
  Both calls have static shapes — nothing recompiles as requests come
  and go, and only token ids cross back to host.
* Sampling runs inside the jitted calls against per-slot ``(n_slots,)``
  temperature/top-k arrays AND per-slot PRNG keys: token t of request r
  is sampled with ``fold_in(fold_in(PRNGKey(seed), r.rid), t)``, so a
  request's tokens are a pure function of (weights, prompt, params,
  seed, rid) — independent of chunk size, batch neighbors, and
  scheduling order. That invariant is what the chunked-vs-monolithic
  parity tests pin down.
* Prompts are NOT padded into the context: slot positions start at 0 and
  only true prompt tokens enter the caches (padding columns of a chunk
  are dropped before the cache write). The old per-bucket left-padded
  prefill — and its per-admission full-cache splice — is gone; the only
  compiled prefill shape is the ``(n_slots, chunk_tokens)`` extend.
* Attention K/V lives in a PAGED POOL (serve/kvpool.py): fixed
  ``page_tokens`` pages, a free-list allocator with per-page refcounts,
  and one int32 page table ``(n_slots, max_len // page_tokens)`` that
  every full-attention layer reads — the jitted tick gathers a per-slot
  contiguous view and scatters new rows through the table, so shapes
  stay static and the compiled functions are unchanged as pages move.
  With ``prefix_cache`` a radix trie over prompt token ids
  (serve/prefix.py) pins completed page runs plus recurrent-state
  snapshots at page boundaries; admission maps the longest cached
  prefix into the slot in O(1) and chunked prefill starts at the first
  uncached token. Retirement publishes the finished prompt's pages back
  into the trie; LRU leaf eviction reclaims pages when the pool runs
  dry. Tokens are byte-identical with the cache on or off (the prefix
  parity wall in tests/test_prefix_cache.py).
* SCHEDULING UNDER PRESSURE (DESIGN.md §6.4): requests carry a priority
  CLASS (``interactive`` > ``batch``). With ``ServeConfig.priorities``
  the admission queue is no longer FIFO: candidates order by
  ``(class rank, uncached prefill tokens, submission order)`` — the
  middle term consults the radix trie WITHOUT pinning it
  (``PrefixTrie.probe``), so a request whose prompt is largely cached
  jumps the queue proportionally to the prefill it skips. A starvation
  floor bounds the jumping: after ``starvation_limit`` consecutive
  admissions that overtook the oldest waiter, the oldest waiter is
  force-admitted. With ``ServeConfig.preempt`` a waiting request of a
  strictly higher class may PREEMPT a lower-class slot when no slot is
  free: the victim's recurrent state is snapshotted (the same
  ``_snapshot_slot`` machinery the prefix trie uses — valid at ANY
  position, not just page boundaries), its pool pages stay retained off
  to the side, and the parked request later resumes into any free slot
  byte-exactly (tokens are scheduling-invariant by the PRNG design
  above, so preempt-on == preempt-off is byte-identical — the parity
  wall in tests/test_preempt.py). A request preempted
  ``max_preempts`` times becomes immune, so the batch class keeps a
  progress floor under a sustained interactive flood.
* Weights are SERVE-form (packed tiles + alphas, repro.serve.weights);
  passing ``mesh=`` places them with the serving sharding rules and
  traces extend/decode under those rules (DESIGN.md §5).
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import itertools
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import axis_rules, param_shardings
from repro.serve.kvpool import KVPool
from repro.serve.prefix import EncoderCache, PrefixTrie
from repro.serve.sampling import SamplingParams, sample_logits_batch
from repro.serve.servable import ensure_servable
from repro.serve.telemetry import PARKED, EngineTelemetry, RequestSpan

PREFILL = "prefill"
DECODE = "decode"
# Encoder-decoder models only: the phase between admission and PREFILL in
# which the request's source frames run through the encoder (one fixed-
# shape batch=1 call, budget-charged against the tick like a prefill
# chunk) and the projected cross-attention K/V lands in its pool pages.
ENCODE = "encode"

# Priority classes, best first: rank 0 outranks rank 1. The class names
# are the wire-level vocabulary (`"priority"` field of POST /generate);
# submit() rejects anything else so typos fail fast instead of silently
# scheduling at an unintended class.
PRIORITY_RANKS: Dict[str, int] = {"interactive": 0, "batch": 1}

# Trace probe: each jitted tick function bumps its counter when its
# PYTHON body runs — i.e. exactly when jax traces (or retraces) it.
# Executing a cached executable (or an AOT-compiled one) never runs the
# body, so a stable counter across a tick is a machine-checkable "this
# tick compiled nothing" — the property ``BatchedEngine.warmup`` exists
# to establish for the first real request (tests/test_warmup.py).
TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()


class AdmissionQueueFull(RuntimeError):
    """Typed backpressure signal: ``submit`` on an engine whose bounded
    admission queue (``ServeConfig.max_queued``) is at capacity. The
    serving front-end maps this to HTTP 429 instead of letting requests
    pile up unboundedly behind the tick loop."""

    def __init__(self, queued: int, capacity: int):
        super().__init__(
            f"admission queue full: {queued} queued >= max_queued "
            f"{capacity} — retry later or raise max_queued"
        )
        self.queued = queued
        self.capacity = capacity


class _AdmissionQueue:
    """Thread-safe waiting set the scheduler picks from by POLICY, not
    position: ``submit`` appends from any thread, the tick thread takes a
    snapshot, chooses a candidate (FIFO, or the priority/prefix-aware
    key), and removes it. Aborted-while-queued requests are pruned lazily
    on every size/snapshot access so ``qsize`` reflects real pressure.
    Keeps the ``queue.Queue``-shaped ``empty``/``qsize`` surface the
    front-end and tests already poll."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: List["Request"] = []

    def put(self, req: "Request") -> None:
        with self._lock:
            self._items.append(req)

    def _prune_locked(self) -> None:
        self._items = [r for r in self._items if not r.done]

    def qsize(self) -> int:
        with self._lock:
            self._prune_locked()
            return len(self._items)

    def empty(self) -> bool:
        return self.qsize() == 0

    def snapshot(self) -> List["Request"]:
        with self._lock:
            self._prune_locked()
            return list(self._items)

    def remove(self, req: "Request") -> bool:
        with self._lock:
            try:
                self._items.remove(req)
                return True
            except ValueError:
                return False

    def drain(self) -> List["Request"]:
        with self._lock:
            items, self._items = self._items, []
            return [r for r in items if not r.done]


@dataclasses.dataclass
class PreemptedState:
    """Everything needed to resume a preempted request byte-exactly into
    ANY slot: the retained pool-page run (full-attention K/V never moves
    — resuming just rewrites a page table row), the recurrent-family
    snapshot (SSM/RG-LRU carries + windowed rings, captured at the exact
    preemption position), and the host-side slot registers (phase,
    prefill offset, cache length, last sampled token, PRNG fold
    position, pending trie-boundary snapshots)."""

    req: "Request"
    phase: str                          # PREFILL | DECODE at preemption
    offset: int                         # prompt tokens consumed
    length: int                         # cache length (tokens written)
    pages: List[int]                    # retained pool pages, in order
    snapshot: object                    # recurrent pytree (None: stateless)
    snaps: Dict[int, object]            # captured trie-boundary snapshots
    need_snaps: set                     # boundaries still to capture
    count: int                          # emitted tokens == PRNG fold pos
    last_token: int                     # decode input token at preemption
    xpages: List[int] = dataclasses.field(default_factory=list)
    # retained CROSS-pool pages (encoder-decoder models; read-only after
    # encode, so parking retains them exactly like self-attention pages —
    # no re-snapshot needed, resume rewrites the cross table row)
    enc_len: int = 0                    # valid encoder rows behind xpages


def _tick_fns(model):
    """The jitted serving entry points for ``model``, built once and
    cached ON the model object: every engine over the same model (replica
    pools, re-created engines, the test matrix's chunk-size sweeps) reuses
    one trace cache instead of recompiling per engine. The functions close
    over nothing but the model; batch width, chunk width, page-table
    width, and — under a mesh — input shardings are ordinary retrace
    keys."""
    cached = getattr(model, "_serve_tick_fns", None)
    if cached is not None:
        return cached
    cross = getattr(model, "has_cross_attn", False)

    def _row_keys(base_keys, counts):
        return jax.vmap(jax.random.fold_in)(base_keys, counts)

    def _extra_kw(extra):
        """Cross models thread (cross page table, encoder lengths) as
        trailing varargs so the decoder-only tick signatures — and their
        traces — stay exactly what the existing parity walls pin."""
        if not extra:
            return {}
        xptab, enc_lens = extra
        return {"cross_page_table": xptab, "enc_lens": enc_lens}

    def _decode_tick(params, tokens, caches, lengths, active,
                     temps, topks, base_keys, counts, ptab, *extra):
        """decode step + per-slot sampling fused under one jit, confined
        to the ``active`` decoding slots: the (n_slots, vocab) logits
        never leave the device and prefilling/free slots keep their
        caches, lengths, and last token bit-identical. Paged pool writes
        are confined in-kernel by ``active``; per-slot families by the
        merge."""
        TRACE_COUNTS["decode_tick"] += 1
        logits, new_caches, new_lengths = model.decode_step(
            params, tokens, caches, lengths,
            page_table=ptab, active=active, **_extra_kw(extra),
        )
        nxt = sample_logits_batch(
            logits, _row_keys(base_keys, counts),
            temperature=temps, top_k=topks,
        )
        caches = model.merge_caches(caches, new_caches, active, paged=True)
        lengths = jnp.where(active, new_lengths, lengths)
        nxt = jnp.where(active, nxt, tokens[:, 0])
        return nxt, caches, lengths

    def _extend_tick(params, block, caches, lengths, n_new,
                     temps, topks, base_keys, counts, ptab, *extra):
        """one chunked-prefill step for every scheduled slot + sampling of
        each slot's candidate first token (the host keeps it only for
        slots whose prompt just completed)."""
        TRACE_COUNTS["extend_tick"] += 1
        logits, caches, lengths = model.extend(
            params, block, caches, lengths, n_new, page_table=ptab,
            **_extra_kw(extra),
        )
        toks = sample_logits_batch(
            logits, _row_keys(base_keys, counts),
            temperature=temps, top_k=topks,
        )
        return toks, caches, lengths

    def _reset_slot(caches, slot):
        """Zero one slot's rows across the per-slot cache families
        (recurrent/SSM state MUST start from zeros); paged pool leaves
        pass through — their pages are shared or about to be remapped."""
        TRACE_COUNTS["reset_slot"] += 1
        return model.reset_slot_caches(caches, slot, paged=True)

    def _snapshot_slot(caches, slot):
        """One slot's recurrent-family state (prefix-trie snapshot)."""
        TRACE_COUNTS["snapshot_slot"] += 1
        return model.snapshot_slot_caches(caches, slot)

    def _restore_slot(caches, slot, snaps):
        """Prefix-hit admission: write a pinned snapshot into a slot."""
        TRACE_COUNTS["restore_slot"] += 1
        return model.restore_slot_caches(caches, slot, snaps)

    fns = (jax.jit(_decode_tick), jax.jit(_extend_tick),
           jax.jit(_reset_slot), jax.jit(_snapshot_slot),
           jax.jit(_restore_slot))
    if cross:
        def _encode_tick(params, frames, valid, caches, xptab):
            """ENCODE phase: one padded batch=1 encoder pass + the cross
            K/V projection scattered through the admitted slot's cross
            page-table row. The ONLY writer of cross pages — decode and
            extend treat the family as read-only ever after."""
            TRACE_COUNTS["encode_tick"] += 1
            memory = model.encode_serve(params, frames, valid)
            positions = jnp.broadcast_to(
                jnp.arange(frames.shape[1]), valid.shape)
            return model.write_cross(
                params, memory, caches, xptab, positions, valid)

        fns = fns + (jax.jit(_encode_tick),)
    model._serve_tick_fns = fns
    return fns


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None  # "eos" | "length" | "aborted"
    admit_step: Optional[int] = None     # engine tick of admission
    token_steps: List[int] = dataclasses.field(default_factory=list)
    # engine tick at which each output token was emitted: token_steps[0]
    # is the TTFT tick; successive gaps are per-token inter-token ticks
    prefix_hit_tokens: int = 0           # prompt tokens served from the
    # prefix cache at admission (page-aligned; 0 on a cold miss)
    priority: str = "batch"              # resolved class (submit() fills it
    # from params.priority / ServeConfig.default_priority and validates)
    submit_step: int = 0                 # engine tick at submission: the
    # per-class TTFT stats measure from here, queue wait included
    preempt_count: int = 0               # times preempted so far; at
    # ServeConfig.max_preempts the request becomes preemption-immune
    frames: Optional[np.ndarray] = None  # (enc_len, d_model) source frame
    # embeddings — required for encoder-decoder models, rejected otherwise
    enc_digest: Optional[bytes] = None   # blake2b of the frame bytes: the
    # EncoderCache key (two requests over the same source share pages)
    enc_reused: bool = False             # admission skipped ENCODE via a
    # warm EncoderCache hit (the encdec analogue of prefix_hit_tokens)
    span: Optional[RequestSpan] = None   # wall-clock lifecycle span
    # (telemetry on only; observation-only — never read by the scheduler)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_len: int = 256                  # cache capacity per slot
    chunk_tokens: int = 32              # extend width == per-tick token budget
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: int = 0
    page_tokens: int = 16               # attention KV pool page size
    pool_pages: Optional[int] = None    # pool capacity; default = the
    # dense-equivalent n_slots * (max_len // page_tokens)
    prefix_cache: bool = False          # radix-trie shared-prefix reuse
    prefix_nodes: int = 512             # trie node cap (snapshots hold
    # real device memory for the recurrent families)
    max_queued: Optional[int] = None    # admission-queue capacity; a full
    # queue makes submit() raise AdmissionQueueFull (typed backpressure —
    # the HTTP front-end's 429) instead of queueing unboundedly. None
    # keeps the historical unbounded queue for batch drivers.
    priorities: bool = False            # class-aware + prefix-aware
    # admission ordering (off = strict FIFO, the historical behavior)
    preempt: bool = False               # preempt-and-resume of strictly
    # lower-priority slots when a higher-class request waits with no free
    # slot; requires priorities (a FIFO admission would hand the freed
    # slot to the wrong request and thrash)
    default_priority: str = "batch"     # class for requests that don't say
    starvation_limit: int = 8           # priority mode's aging floor: max
    # consecutive admissions that may overtake the oldest waiter before
    # it is force-admitted
    max_preempts: int = 3               # per-request preemption cap; at
    # the cap a request becomes immune (the batch-class progress floor)
    enc_tokens: Optional[int] = None    # encoder-decoder models: padded
    # encoder width (the ENCODE tick's one compiled shape) and the cap on
    # a request's frame count. None resolves to max_len in the engine.
    cross_pages: Optional[int] = None   # cross-attention pool capacity;
    # default = (n_slots + 1) runs so one EncoderCache entry can stay
    # warm beside a full house of live slots
    enc_cache_entries: int = 128        # EncoderCache entry cap (LRU)
    compute_path: str = "float"         # dense serve compute: "float"
    # (byte-parity reference) | "int8" | "xnor" — the integer paths
    # quantize decode-tick activations and accumulate on the packed tile
    # words (kernels/tiled_xnor.py). The MODEL must be built with the
    # matching ModelContext.compute_path (launch/serve.py --compute-path
    # sets both); the engine records it here for validation and /stats.
    telemetry: bool = True              # serving telemetry (DESIGN.md §6.6):
    # metric registry + request spans + tick phase timing + the retrace
    # detector. Observation-only — tokens are byte-identical on or off
    # (the parity wall in tests/test_telemetry.py); off removes even the
    # per-tick perf_counter reads for overhead-sensitive benchmarking.
    trace_events: int = 0               # capacity of the structured
    # trace-event ring (submit/admit/preempt/resume/finish/retrace);
    # 0 disables the ring. Drained by the CLI's --trace-log sink.

    def __post_init__(self):
        """Fail fast on an impossible engine shape.

        n_slots/max_len: a zero-slot engine wedges the scheduler silently
        (every submit queues forever) and a zero-length cache can hold no
        token. chunk_tokens is both the extend call's compiled column
        count and the per-tick token budget; non-positive wedges the
        scheduler, past max_len could scatter past the cache.
        page_tokens must divide max_len so the paged gather view is
        EXACTLY the dense (max_len,) layout — that equality is what makes
        paged-vs-dense tokens byte-identical. pool_pages below one slot's
        worth could never complete a full-length sequence."""
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1: {self.n_slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1: {self.max_len}")
        if self.chunk_tokens <= 0:
            raise ValueError(
                f"chunk_tokens must be positive: {self.chunk_tokens}"
            )
        if self.chunk_tokens > self.max_len:
            raise ValueError(
                f"chunk_tokens {self.chunk_tokens} exceeds max_len "
                f"{self.max_len}: a chunk could not fit the decode cache"
            )
        if self.page_tokens <= 0:
            raise ValueError(
                f"page_tokens must be positive: {self.page_tokens}"
            )
        if self.max_len % self.page_tokens:
            raise ValueError(
                f"page_tokens {self.page_tokens} must divide max_len "
                f"{self.max_len}: the per-slot page-table view must be "
                f"exactly the dense cache layout"
            )
        if self.pool_pages is not None:
            if self.pool_pages < self.max_len // self.page_tokens:
                raise ValueError(
                    f"pool_pages {self.pool_pages} is below one slot's "
                    f"worth ({self.max_len // self.page_tokens} pages): "
                    f"no sequence could reach max_len"
                )
        if self.prefix_nodes < 1:
            raise ValueError(
                f"prefix_nodes must be >= 1: {self.prefix_nodes}"
            )
        if self.max_queued is not None and self.max_queued < 1:
            raise ValueError(
                f"max_queued must be >= 1 (or None for unbounded): "
                f"{self.max_queued}"
            )
        if self.preempt and not self.priorities:
            raise ValueError(
                "preempt=True requires priorities=True: preemption frees "
                "a slot FOR a higher-class waiter, but FIFO admission "
                "would hand it to the oldest request instead and thrash"
            )
        if self.default_priority not in PRIORITY_RANKS:
            raise ValueError(
                f"default_priority {self.default_priority!r} is not a "
                f"priority class: {sorted(PRIORITY_RANKS)}"
            )
        if self.starvation_limit < 1:
            raise ValueError(
                f"starvation_limit must be >= 1: {self.starvation_limit} "
                f"(0 would force-admit the oldest waiter every time — "
                f"that is just FIFO; use priorities=False)"
            )
        if self.max_preempts < 0:
            raise ValueError(
                f"max_preempts must be >= 0: {self.max_preempts}"
            )
        if self.enc_tokens is not None and self.enc_tokens < 1:
            raise ValueError(
                f"enc_tokens must be >= 1 (or None for max_len): "
                f"{self.enc_tokens}"
            )
        if self.cross_pages is not None and self.cross_pages < 1:
            raise ValueError(
                f"cross_pages must be >= 1 (or None for the default): "
                f"{self.cross_pages}"
            )
        if self.enc_cache_entries < 1:
            raise ValueError(
                f"enc_cache_entries must be >= 1: {self.enc_cache_entries}"
            )
        if self.trace_events < 0:
            raise ValueError(
                f"trace_events must be >= 0 (0 disables the ring): "
                f"{self.trace_events}"
            )
        if self.trace_events and not self.telemetry:
            raise ValueError(
                "trace_events requires telemetry=True: the trace ring is "
                "emitted from the telemetry call sites"
            )
        from repro.kernels.tiled_xnor import COMPUTE_PATHS

        if self.compute_path not in COMPUTE_PATHS:
            raise ValueError(
                f"unknown compute_path {self.compute_path!r}: expected "
                f"one of {COMPUTE_PATHS}"
            )


class BatchedEngine:
    def __init__(self, model, params, cfg: ServeConfig, *, mesh=None):
        # The model <-> engine contract is the ServableModel protocol
        # (serve/servable.py, DESIGN.md §6.5); fail at construction with
        # the family menu, not mid-tick with an AttributeError.
        ensure_servable(model)
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            # Place the serve weights with the serving rules: packed tile
            # rows ("tile_rows") shard over the model axis, ragged or
            # non-dividing dims drop to replicated (distributed/sharding).
            from repro.nn import module as mod

            logical = mod.logical_axes(model.specs())
            abstract = jax.tree.map(
                lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), params
            )
            shardings = param_shardings(
                mesh, logical, abstract_tree=abstract
            )
            params = jax.device_put(params, shardings)
        self.params = params
        self._queue = _AdmissionQueue()
        self._live: Dict[int, Request] = {}      # slot -> request
        # preempted requests waiting to resume: pages retained, recurrent
        # state snapshotted, no slot. Competes with the admission queue
        # under the same candidate key (a decode-phase parked request has
        # zero remaining prefill, so it naturally resumes first in class).
        self._parked: List[PreemptedState] = []
        self._overtakes = 0       # consecutive non-oldest admissions
        self._preempted_since_tick = False
        self._class_ttft: Dict[str, List[int]] = {}  # class -> [sum, n]
        self._free = list(range(cfg.n_slots))
        self._rid = itertools.count()
        self._root_key = jax.random.PRNGKey(cfg.seed)

        # per-slot phase machine (host side)
        self._phase = [None] * cfg.n_slots       # None | PREFILL | DECODE
        self._offsets = np.zeros((cfg.n_slots,), np.int64)  # prompt consumed
        self._admit_order: List[int] = []        # prefill scheduling FIFO

        # paged attention KV pool + per-slot page tables (host-managed;
        # the table rides into the jitted calls as a runtime int32 array)
        self.pt = cfg.page_tokens
        self.npp = cfg.max_len // self.pt        # pages per slot
        self._paged = model.has_full_attn
        n_pages = cfg.pool_pages or cfg.n_slots * self.npp
        self.pool = KVPool(n_pages, self.pt) if self._paged else None
        self._ptab = np.zeros((cfg.n_slots, self.npp), np.int32)
        self._n_mapped = np.zeros((cfg.n_slots,), np.int64)  # pages held

        # Cross-attention cache family (encoder-decoder models): a SECOND
        # pool with its own page-table rows. Pages are written once by the
        # ENCODE tick and read-only ever after, masked by per-slot encoder
        # lengths — so sharing them across requests over the same source
        # is pure refcounting, exactly like trie-pinned prefix pages.
        self._cross = getattr(model, "has_cross_attn", False)
        if self._cross:
            self.enc_tokens = cfg.enc_tokens or cfg.max_len
            self.x_npp = -(-self.enc_tokens // self.pt)  # x-pages per slot
            x_pages = cfg.cross_pages or (cfg.n_slots + 1) * self.x_npp
            if x_pages < self.x_npp:
                raise ValueError(
                    f"cross_pages {x_pages} is below one request's worth "
                    f"({self.x_npp} pages for enc_tokens={self.enc_tokens})"
                )
            self.xpool = KVPool(x_pages, self.pt, family="cross_attn")
            self._xptab = np.zeros((cfg.n_slots, self.x_npp), np.int32)
            self._xn_mapped = np.zeros((cfg.n_slots,), np.int64)
            self._enc_lens = np.zeros((cfg.n_slots,), np.int32)
        else:
            self.enc_tokens = None
            self.x_npp = 0
            self.xpool = None

        # shared-prefix radix trie + per-slot boundary snapshots. Cross
        # models DISABLE the token-keyed trie regardless of prefix_cache:
        # decoder self-attention K/V depends on the cross-attended encoder
        # memory, so a prompt prefix computed against one source would be
        # silently WRONG for another. What prefix_cache buys them instead
        # is the digest-keyed EncoderCache — reuse of the encoder output
        # itself, which IS prompt-independent.
        self.trie = (
            PrefixTrie(self.pt, pool=self.pool, max_nodes=cfg.prefix_nodes)
            if cfg.prefix_cache and not self._cross else None
        )
        self.enc_cache = (
            EncoderCache(self.xpool, max_entries=cfg.enc_cache_entries)
            if cfg.prefix_cache and self._cross else None
        )
        self._stateful = model.has_recurrent_state
        self._snaps: List[Dict[int, object]] = [
            {} for _ in range(cfg.n_slots)
        ]
        # page boundaries of the slot's prompt that must be snapshotted
        # (their trie node is missing or snapshotless); computed once at
        # admission so prefill neither pauses at nor captures boundaries
        # the trie already covers
        self._need_snaps: List[set] = [set() for _ in range(cfg.n_slots)]
        self._stats = {
            "admitted": 0, "prefix_hits": 0, "prefix_tokens": 0,
            "prompt_tokens": 0, "tokens_out": 0, "aborted": 0,
            "rejected": 0, "peak_queue_depth": 0,
            "preempt_free_ticks": 0, "work_ticks": 0,
            "preempts": 0, "resumes": 0, "preempted_tokens": 0,
            "encode_ticks": 0, "enc_cache_hits": 0,
        }

        # Streaming hooks: the front-end registers these to learn about
        # tokens the instant the tick emits them (on_token runs in
        # whatever thread drives step(); it must be cheap and non-blocking
        # — the server's implementation just enqueues onto the detokenize
        # backlog). on_finish fires exactly once per request, including
        # aborts.
        self.on_token: Optional[Callable[[Request, int], None]] = None
        self.on_finish: Optional[Callable[[Request], None]] = None

        cache_dtype = getattr(model.ctx, "compute_dtype", jnp.bfloat16)
        self._cache_dtype = cache_dtype
        cache_kw = {}
        if self._cross:
            cache_kw["cross_pages"] = self.xpool.n_pages
        self.caches = model.init_caches(
            cfg.n_slots, cfg.max_len, cache_dtype,
            page_tokens=self.pt if self._paged else None,
            n_pages=n_pages if self._paged else None,
            **cache_kw,
        )
        self.lengths = jnp.zeros((cfg.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((cfg.n_slots, 1), jnp.int32)
        # Per-slot sampling params, populated at admission from the
        # request's resolved SamplingParams (None sentinels -> ServeConfig
        # defaults). temps/topks/keys ride into the jitted calls as runtime
        # arrays; eos ids stay host-side for retirement bookkeeping.
        self.temps = jnp.zeros((cfg.n_slots,), jnp.float32)
        self.topks = jnp.zeros((cfg.n_slots,), jnp.int32)
        self._eos_ids = np.full((cfg.n_slots,), -1, np.int64)
        # per-slot request key + emitted-token count: token t of a request
        # samples with fold_in(request_key, t), independent of scheduling
        self._slot_keys = jnp.zeros((cfg.n_slots, 2), jnp.uint32)
        self._counts = np.zeros((cfg.n_slots,), np.int64)

        fns = _tick_fns(model)
        (self._decode, self._extend, self._reset,
         self._snapshot, self._restore) = fns[:5]
        self._encode = fns[5] if len(fns) > 5 else None
        # AOT-compiled executables keyed by tick-fn name, filled by
        # warmup(): call sites prefer these over the lazily-traced jit
        # wrappers so a warmed engine's first real tick runs zero traces.
        self._aot: Dict[str, object] = {}
        self.steps = 0

        # Serving telemetry (DESIGN.md §6.6). Strictly observation-only:
        # every call site below is a counter bump, a span transition, or
        # a perf_counter read — nothing feeds back into scheduling or
        # sampling, so tokens are byte-identical with tel on or off.
        self.tel: Optional[EngineTelemetry] = (
            EngineTelemetry(trace_events=cfg.trace_events).bind_engine(self)
            if cfg.telemetry else None
        )
        self._tick_phases: Dict[str, float] = {}
        # Retrace detector: armed by warmup(). Compares the global
        # TRACE_COUNTS sum across ONE tick (this thread runs the whole
        # tick, so any delta is attributable to this engine's tick fns),
        # not against a warmup-time snapshot — another engine warming up
        # on this process must not trip a false positive here.
        self._retrace_armed = False
        self._retrace_warned = False

    def _mesh_ctx(self):
        """Sharding-rule context for traces/executions; no-op without mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self.mesh)

    def _cross_extra(self):
        """Trailing tick-fn args for the cross family: (cross page table,
        per-slot encoder lengths). Empty for decoder-only models, so
        their tick calls — and compiled signatures — are unchanged."""
        if not self._cross:
            return ()
        return (jnp.asarray(self._xptab), jnp.asarray(self._enc_lens))

    # ------------------------------------------------------------------
    def warmup(self) -> Dict[str, float]:
        """Ahead-of-time compile every tick executable for THIS engine's
        shapes (``jax.jit(...).lower(...).compile()`` per entry point), so
        the first real request never pays a trace+compile inside its TTFT.

        The engine has exactly two hot compiled shapes — the
        ``(n_slots, 1)`` decode tick and the ``(n_slots, chunk_tokens)``
        extend tick — plus the per-slot reset that admission runs, and
        (prefix cache on a stateful model) the snapshot/restore pair.
        Warmup lowers each against the live engine state arrays, which
        are byte-for-byte the avals the real ticks will pass, and stores
        the compiled executables in ``self._aot``; the tick call sites
        prefer those over the lazily-traced jit wrappers, so a warmed
        engine's first tick runs ZERO new traces (the ``TRACE_COUNTS``
        probe in tests/test_warmup.py pins this).

        Returns per-entry-point compile seconds. Raises ``RuntimeError``
        naming the entry point and its scheduler-side shapes when a
        lower/compile fails — a warmup that silently half-succeeds would
        just move the first trace stall back into serving."""
        cfg = self.cfg
        active = jnp.asarray(np.zeros((cfg.n_slots,), bool))
        counts = jnp.asarray(self._counts)
        ptab = jnp.asarray(self._ptab)
        block = jnp.asarray(np.zeros((cfg.n_slots, cfg.chunk_tokens),
                                     np.int32))
        n_new = jnp.asarray(np.zeros((cfg.n_slots,), np.int32))
        extra = self._cross_extra()
        plans = [
            ("decode_tick", self._decode,
             (self.params, self.tokens, self.caches, self.lengths, active,
              self.temps, self.topks, self._slot_keys, counts, ptab,
              *extra),
             f"tokens int32[{cfg.n_slots},1], ptab int32[{cfg.n_slots},"
             f"{self.npp}]"),
            ("extend_tick", self._extend,
             (self.params, block, self.caches, self.lengths, n_new,
              self.temps, self.topks, self._slot_keys, counts, ptab,
              *extra),
             f"block int32[{cfg.n_slots},{cfg.chunk_tokens}], ptab "
             f"int32[{cfg.n_slots},{self.npp}]"),
            ("reset_slot", self._reset, (self.caches, 0),
             f"slot int32[], {cfg.n_slots}-slot caches"),
        ]
        if self._cross:
            d = self.model.cfg.d_model
            plans.append((
                "encode_tick", self._encode,
                (self.params,
                 jnp.zeros((1, self.enc_tokens, d), self._cache_dtype),
                 jnp.zeros((1, self.enc_tokens), bool), self.caches,
                 jnp.zeros((1, self.x_npp), jnp.int32)),
                f"frames [{1},{self.enc_tokens},{d}], xptab "
                f"int32[1,{self.x_npp}]"))
        # snapshot/restore executables serve BOTH the prefix trie's
        # boundary snapshots and the preempting scheduler's parking; warm
        # them whenever a stateful model could need either.
        if self._stateful and (self.trie is not None or cfg.preempt):
            plans.append(("snapshot_slot", self._snapshot, (self.caches, 0),
                          f"slot int32[], {cfg.n_slots}-slot caches"))
        timings: Dict[str, float] = {}
        with self._mesh_ctx():
            for name, fn, args, desc in plans:
                t0 = time.perf_counter()
                try:
                    self._aot[name] = fn.lower(*args).compile()
                except Exception as e:
                    raise RuntimeError(
                        f"AOT warmup failed for '{name}' ({desc}): {e}"
                    ) from e
                timings[name] = time.perf_counter() - t0
            if "snapshot_slot" in self._aot:
                # restore's input signature includes the snapshot pytree;
                # one warm snapshot execution (on the zeroed caches, result
                # discarded) yields exactly the avals admission will pass
                snaps = self._aot["snapshot_slot"](self.caches, 0)
                t0 = time.perf_counter()
                try:
                    self._aot["restore_slot"] = self._restore.lower(
                        self.caches, 0, snaps).compile()
                except Exception as e:
                    raise RuntimeError(
                        f"AOT warmup failed for 'restore_slot' (slot "
                        f"int32[], {len(jax.tree_util.tree_leaves(snaps))}"
                        f"-leaf snapshot): {e}"
                    ) from e
                timings["restore_slot"] = time.perf_counter() - t0
        # arm the steady-state retrace detector: after AOT warmup every
        # tick must execute compiled code only, so any TRACE_COUNTS bump
        # inside a subsequent step() is a compile stall worth flagging
        self._retrace_armed = True
        return timings

    @property
    def aot_warm(self) -> bool:
        return bool(self._aot)

    # ------------------------------------------------------------------
    def submit(
        self, prompt, params: Optional[SamplingParams] = None,
        frames=None,
    ) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # Validate HERE, not at admission: a bad prompt then fails fast
        # without consuming a slot or wedging the tick loop mid-admission.
        if prompt.ndim != 1 or len(prompt) == 0:
            raise ValueError("prompt must be a non-empty 1-D token sequence")
        if len(prompt) > self.cfg.max_len:
            raise ValueError(
                f"prompt len {len(prompt)} exceeds max_len {self.cfg.max_len}"
            )
        enc_digest = None
        if self._cross:
            if frames is None:
                raise ValueError(
                    "encoder-decoder serving: submit() needs frames "
                    "(enc_len, d_model) source embeddings alongside the "
                    "decoder prompt")
            frames = np.ascontiguousarray(np.asarray(frames, np.float32))
            if (frames.ndim != 2
                    or frames.shape[1] != self.model.cfg.d_model):
                raise ValueError(
                    f"frames must be (enc_len, d_model="
                    f"{self.model.cfg.d_model}): got {frames.shape}")
            if not 0 < frames.shape[0] <= self.enc_tokens:
                raise ValueError(
                    f"frame count {frames.shape[0]} outside "
                    f"(0, enc_tokens={self.enc_tokens}]")
            # digest over shape + bytes: the EncoderCache key — two
            # requests over the same source share cross pages verbatim
            enc_digest = hashlib.blake2b(
                np.int64(frames.shape[0]).tobytes() + frames.tobytes(),
                digest_size=16,
            ).digest()
        elif frames is not None:
            raise ValueError(
                f"{type(self.model).__name__} has no encoder: frames are "
                f"only accepted for encoder-decoder models")
        params = params or SamplingParams()
        cls = (params.priority if params.priority is not None
               else self.cfg.default_priority)
        if cls not in PRIORITY_RANKS:
            raise ValueError(
                f"unknown priority class {cls!r}: expected one of "
                f"{sorted(PRIORITY_RANKS)}"
            )
        if (self.cfg.max_queued is not None
                and self._queue.qsize() >= self.cfg.max_queued):
            self._stats["rejected"] += 1
            if self.tel is not None:
                self.tel.rejected.inc()
            raise AdmissionQueueFull(self._queue.qsize(),
                                     self.cfg.max_queued)
        req = Request(
            rid=next(self._rid),
            prompt=prompt,
            params=params,
            priority=cls,
            submit_step=self.steps,
            frames=frames,
            enc_digest=enc_digest,
        )
        if self.tel is not None:
            req.span = RequestSpan(req.rid, time.monotonic())
            self.tel.submitted.inc()
            if self.tel.ring is not None:
                self.tel.ring.emit(
                    "submit", rid=req.rid, prompt_tokens=len(prompt),
                    priority=cls)
        self._queue.put(req)
        return req

    @property
    def has_work(self) -> bool:
        """True while anything is queued, live, or parked — the tick
        loop's "keep stepping" predicate. Parked requests count: they
        hold pages and an unfinished stream even when no slot is live."""
        return (bool(self._live) or bool(self._parked)
                or not self._queue.empty())

    def _maybe_retire(self, slot: int, req: Request, tok: int) -> bool:
        """Retire a just-extended request. EOS is checked before the length
        cap so a stop token arriving exactly at max_tokens reports "eos";
        the cache-capacity cap retires a sequence whose NEXT decode step
        would write K/V past max_len — every emitted token attended a
        complete cache, instead of silently dropping the newest rows and
        generating from a truncated context. Retirement publishes the
        finished prompt's complete pages (and boundary snapshots) into
        the prefix trie, then drops the slot's page references — shared
        pages survive through the trie's pin."""
        if tok == int(self._eos_ids[slot]):
            req.finish_reason = "eos"
        elif len(req.output) >= req.params.max_tokens:
            req.finish_reason = "length"
        elif len(req.prompt) + len(req.output) > self.cfg.max_len:
            req.finish_reason = "length"
        else:
            return False
        req.done = True
        if self.trie is not None:
            n_pub = len(req.prompt) // self.pt
            if n_pub:
                pages = (
                    [int(self._ptab[slot, i]) for i in range(n_pub)]
                    if self.pool is not None else None
                )
                self.trie.insert(
                    req.prompt[: n_pub * self.pt], pages,
                    self._snaps[slot], now=self.steps,
                )
        self._release_slot(slot)
        self._finish_telemetry(req)
        if self.on_finish is not None:
            self.on_finish(req)
        return True

    def _finish_telemetry(self, req: Request):
        """Close a request's span and record its end-of-life metrics —
        the shared telemetry tail of retirement and abort (idempotent:
        an abort racing a natural finish observes once)."""
        tel, span = self.tel, req.span
        if tel is None or span is None or span.finish_t is not None:
            return
        span.finish(time.monotonic(), req.finish_reason)
        tel.finished.labels(reason=req.finish_reason).inc()
        tel.e2e.observe(span.wall)
        if tel.ring is not None:
            tel.ring.emit(
                "finish", rid=req.rid, reason=req.finish_reason,
                tokens=len(req.output), wall_s=round(span.wall, 6),
                phases={k: round(v, 6) for k, v in span.phases.items()})

    def _release_slot(self, slot: int):
        """Return a slot (and every page it maps) to the free pools: the
        shared tail of retirement and abort. Shared pages survive through
        the trie's pin — only this slot's references drop."""
        if self.pool is not None:
            for i in range(int(self._n_mapped[slot])):
                self.pool.release(int(self._ptab[slot, i]))
            self._n_mapped[slot] = 0
        if self.xpool is not None:
            for i in range(int(self._xn_mapped[slot])):
                self.xpool.release(int(self._xptab[slot, i]))
            self._xn_mapped[slot] = 0
            self._enc_lens[slot] = 0
        self._snaps[slot] = {}
        self._need_snaps[slot] = set()
        self._live.pop(slot, None)
        self._free.append(slot)
        self._phase[slot] = None
        if slot in self._admit_order:
            self._admit_order.remove(slot)
        # Reset the slot's sampling params: a stale temperature/top-k on a
        # dead slot would keep tripping jnp.any(...) in the batch sampler
        # and defeat its all-greedy / no-top-k fast paths for every later
        # tick until the slot is reused.
        self.temps = self.temps.at[slot].set(0.0)
        self.topks = self.topks.at[slot].set(0)
        self._eos_ids[slot] = -1
        self._counts[slot] = 0

    def abort(self, req: Request) -> bool:
        """Cancel a request, queued, live, or PARKED: its slot and pages
        free immediately, nothing is published to the prefix trie (an
        aborted prompt may have prefilled only partially — publishing a
        half-written page run would poison later prefix hits), and
        ``on_finish`` fires with ``finish_reason == "aborted"``. Aborting
        a parked request releases its retained page run and drops its
        snapshot — no slot is involved.

        NOT thread-safe against a concurrent ``step()`` — the caller
        (the server's shutdown path) must stop the tick loop first.
        Returns False if the request already finished."""
        if req.done:
            return False
        req.done = True
        req.finish_reason = "aborted"
        self._stats["aborted"] += 1
        for slot, r in list(self._live.items()):
            if r is req:
                self._release_slot(slot)
                break
        else:
            for parked in self._parked:
                if parked.req is req:
                    self._release_parked(parked)
                    break
        # a queued (never-admitted) request drops out of the waiting set
        # on the next prune (the done flag set above is the tombstone)
        self._finish_telemetry(req)
        if self.on_finish is not None:
            self.on_finish(req)
        return True

    def abort_all(self) -> int:
        """Abort every queued, parked, and live request (server
        shutdown); returns how many actually transitioned."""
        n = 0
        for r in list(self._live.values()):
            n += bool(self.abort(r))
        for parked in list(self._parked):
            n += bool(self.abort(parked.req))
        for r in self._queue.drain():
            n += bool(self.abort(r))
        return n

    def _release_parked(self, parked: PreemptedState):
        """Drop a parked request's held resources: page references back to
        the pool, snapshot to the GC. The inverse of the retain-in-place
        that ``preempt_slot`` performed."""
        if self.pool is not None:
            for pid in parked.pages:
                self.pool.release(pid)
        if self.xpool is not None:
            for pid in parked.xpages:
                self.xpool.release(pid)
        parked.pages = []
        parked.xpages = []
        parked.snapshot = None
        parked.snaps = {}
        self._parked.remove(parked)

    def _admit(self, slot: int, req: Request):
        """O(1) admission: claim the slot, zero its per-slot state, and —
        with the prefix cache on — map the longest trie-pinned prefix in:
        the matched page run lands in the slot's page table (refcounted,
        no K/V copy) and the deepest boundary snapshot restores the
        recurrent families, so chunked prefill starts at the first
        UNCACHED token."""
        self._live[slot] = req
        self._phase[slot] = PREFILL
        self._admit_order.append(slot)
        req.admit_step = self.steps
        boundary, path = 0, []
        if self.trie is not None:
            path = self.trie.match(
                req.prompt, require_snapshot=self._stateful, now=self.steps
            )
            boundary = len(path) * self.pt
        self._stats["admitted"] += 1
        self._stats["prompt_tokens"] += len(req.prompt)
        if boundary:
            self._stats["prefix_hits"] += 1
            self._stats["prefix_tokens"] += boundary
        req.prefix_hit_tokens = boundary
        if self.pool is not None:
            for i, node in enumerate(path):
                self.pool.retain(node.page)
                self._ptab[slot, i] = node.page
            self._n_mapped[slot] = len(path)
        self._snaps[slot] = {}
        self._need_snaps[slot] = (
            self._boundaries_needing_snapshots(req.prompt)
            if self.trie is not None and self._stateful else set()
        )
        self._offsets[slot] = boundary
        self.lengths = self.lengths.at[slot].set(boundary)
        self.caches = self._aot.get("reset_slot", self._reset)(
            self.caches, slot)
        if boundary and self._stateful:
            self.caches = self._aot.get("restore_slot", self._restore)(
                self.caches, slot, path[-1].snapshot
            )
        # Resolve the request's sampling params against the engine defaults
        # (is-None sentinels: an explicit temperature=0.0 / top_k=0 wins
        # over a stochastic ServeConfig default) and pin them to the slot —
        # every token of this request reads them from the per-slot arrays.
        res = req.params.resolve(self.cfg.temperature, self.cfg.top_k)
        self.temps = self.temps.at[slot].set(res.temperature)
        self.topks = self.topks.at[slot].set(res.top_k)
        self._eos_ids[slot] = res.eos_id
        # An explicit per-request seed roots the key stream at
        # PRNGKey(seed) — rid-independent, so a stochastic request replays
        # identically no matter what admission order a concurrent
        # front-end produced. Without one, the historical rid-derived
        # stream keeps batch drivers reproducible per (engine seed, rid).
        self._slot_keys = self._slot_keys.at[slot].set(
            jax.random.PRNGKey(res.seed) if res.seed is not None
            else jax.random.fold_in(self._root_key, req.rid)
        )
        self._counts[slot] = 0
        if self._cross:
            # encoder-decoder: the request must ENCODE before its prompt
            # can prefill — unless the EncoderCache already holds this
            # source, in which case the whole cross page run maps in O(1)
            # and the phase machine skips straight to PREFILL
            self._enc_lens[slot] = len(req.frames)
            self._xn_mapped[slot] = 0
            self._phase[slot] = ENCODE
            self._try_enc_cache(slot, req)
        if self.tel is not None and req.span is not None:
            now = time.monotonic()
            # phase strings are shared between the engine's phase machine
            # and the span vocabulary, so the slot's resolved phase (an
            # enc-cache hit lands straight in PREFILL) names the interval
            req.span.mark_admit(now, self._phase[slot])
            self.tel.queue_wait.observe(now - req.span.submit_t)
            if self.tel.ring is not None:
                self.tel.ring.emit(
                    "admit", rid=req.rid, slot=slot,
                    prefix_hit_tokens=boundary,
                    phase=self._phase[slot])

    def _try_enc_cache(self, slot: int, req: Request) -> bool:
        """Warm-source admission: map a cached encoder output's page run
        into the slot's cross table and skip the ENCODE phase."""
        if self.enc_cache is None or req.enc_digest is None:
            return False
        entry = self.enc_cache.get(req.enc_digest, now=self.steps)
        if entry is None:
            return False
        for i, pid in enumerate(entry.pages):
            self._xptab[slot, i] = pid
        self._xn_mapped[slot] = len(entry.pages)
        self._enc_lens[slot] = entry.enc_len
        self._phase[slot] = PREFILL
        req.enc_reused = True
        self._stats["enc_cache_hits"] += 1
        # a LATE warm hit (resolved by step(), not at admission) ends the
        # span's encode interval; at admission the span has not marked
        # admit yet and _admit names the resolved phase itself
        if req.span is not None and req.span.admit_t is not None:
            req.span.to_phase(PREFILL, time.monotonic())
        return True

    # ---- scheduling under pressure -----------------------------------
    def preempt_slot(self, slot: int) -> bool:
        """Park the request occupying ``slot`` and free the slot, keeping
        every byte of its progress: pool pages stay retained (the K/V
        never moves — resuming rewrites a page-table row), recurrent
        state is snapshotted at the CURRENT position (snapshot/restore is
        position-exact; the page-boundary rule exists only for trie
        sharing semantics), and the host-side registers (offset, length,
        last token, PRNG fold count, pending boundary snapshots) ride in
        the :class:`PreemptedState`. The scheduler calls this when a
        strictly higher-class request waits with no free slot; tests call
        it directly to force preemption at arbitrary ticks. Like
        ``abort``, not safe against a concurrent ``step()``.
        Returns False if the slot is not live."""
        req = self._live.get(slot)
        if req is None:
            return False
        with self._mesh_ctx():
            snap = None
            if self._stateful:
                snap = self._aot.get("snapshot_slot", self._snapshot)(
                    self.caches, slot)
            pages = (
                [int(self._ptab[slot, i])
                 for i in range(int(self._n_mapped[slot]))]
                if self.pool is not None else []
            )
            xpages = (
                [int(self._xptab[slot, i])
                 for i in range(int(self._xn_mapped[slot]))]
                if self.xpool is not None else []
            )
            parked = PreemptedState(
                req=req,
                phase=self._phase[slot],
                offset=int(self._offsets[slot]),
                length=int(self.lengths[slot]),
                pages=pages,
                snapshot=snap,
                snaps=self._snaps[slot],
                need_snaps=self._need_snaps[slot],
                count=int(self._counts[slot]),
                last_token=int(self.tokens[slot, 0]),
                xpages=xpages,
                enc_len=(int(self._enc_lens[slot])
                         if self.xpool is not None else 0),
            )
            self._parked.append(parked)
            req.preempt_count += 1
            # free the slot WITHOUT releasing its pages (they now belong
            # to the parked record) and without firing on_finish
            self._n_mapped[slot] = 0
            if self.xpool is not None:
                self._xn_mapped[slot] = 0
                self._enc_lens[slot] = 0
            self._snaps[slot] = {}
            self._need_snaps[slot] = set()
            self._live.pop(slot)
            self._free.append(slot)
            self._phase[slot] = None
            if slot in self._admit_order:
                self._admit_order.remove(slot)
            self.temps = self.temps.at[slot].set(0.0)
            self.topks = self.topks.at[slot].set(0)
            self._eos_ids[slot] = -1
            self._counts[slot] = 0
            self._stats["preempts"] += 1
            self._stats["preempted_tokens"] += parked.length
            self._preempted_since_tick = True
            if self.tel is not None:
                self.tel.preempts.inc()
                if req.span is not None:
                    req.span.to_phase(PARKED, time.monotonic())
                if self.tel.ring is not None:
                    self.tel.ring.emit(
                        "preempt", rid=req.rid, slot=slot,
                        phase=parked.phase, tokens_kept=parked.length)
        return True

    def _resume(self, slot: int, parked: PreemptedState):
        """Restore a parked request into ``slot`` byte-exactly: page run
        back into the slot's table row, recurrent snapshot over the
        freshly zeroed per-slot rows, host registers verbatim. The
        request's tokens continue exactly where the uninterrupted run
        would be (the preemption parity wall pins this)."""
        req = parked.req
        self._live[slot] = req
        self._phase[slot] = parked.phase
        if parked.phase in (PREFILL, ENCODE):
            self._admit_order.append(slot)
        if self.pool is not None:
            for i, pid in enumerate(parked.pages):
                self._ptab[slot, i] = pid
            self._n_mapped[slot] = len(parked.pages)
        if self.xpool is not None:
            # cross pages come back by table rewrite alone: they were
            # written once at encode and never re-snapshotted (read-only)
            for i, pid in enumerate(parked.xpages):
                self._xptab[slot, i] = pid
            self._xn_mapped[slot] = len(parked.xpages)
            self._enc_lens[slot] = parked.enc_len
        self._snaps[slot] = parked.snaps
        self._need_snaps[slot] = parked.need_snaps
        self._offsets[slot] = parked.offset
        self.lengths = self.lengths.at[slot].set(parked.length)
        self.caches = self._aot.get("reset_slot", self._reset)(
            self.caches, slot)
        if self._stateful and parked.snapshot is not None:
            self.caches = self._aot.get("restore_slot", self._restore)(
                self.caches, slot, parked.snapshot)
        res = req.params.resolve(self.cfg.temperature, self.cfg.top_k)
        self.temps = self.temps.at[slot].set(res.temperature)
        self.topks = self.topks.at[slot].set(res.top_k)
        self._eos_ids[slot] = res.eos_id
        self._slot_keys = self._slot_keys.at[slot].set(
            jax.random.PRNGKey(res.seed) if res.seed is not None
            else jax.random.fold_in(self._root_key, req.rid)
        )
        self._counts[slot] = parked.count
        self.tokens = self.tokens.at[slot, 0].set(parked.last_token)
        self._stats["resumes"] += 1
        if self.tel is not None:
            self.tel.resumes.inc()
            if req.span is not None:
                req.span.to_phase(parked.phase, time.monotonic())
            if self.tel.ring is not None:
                self.tel.ring.emit("resume", rid=req.rid, slot=slot,
                                   phase=parked.phase)

    def _rank(self, req: Request) -> int:
        return PRIORITY_RANKS[req.priority]

    def _candidate_key(self, cand: Union[Request, PreemptedState]):
        """Priority-mode admission order: (class rank, uncached prefill
        tokens, submission order). The middle term is the work admission
        would actually schedule — a parked decode-phase request costs 0
        (it resumes straight into decode), a parked prefill resumes at
        its offset, and a fresh request's cached prefix is probed from
        the trie WITHOUT pinning recency (the probe is advisory; the
        authoritative match happens at admission)."""
        if isinstance(cand, PreemptedState):
            if cand.phase == ENCODE:
                # parked before its encoder ran: full encode + prefill
                cost = len(cand.req.prompt) + cand.enc_len
            elif cand.phase == PREFILL:
                cost = len(cand.req.prompt) - cand.offset
            else:
                cost = 0
            return (self._rank(cand.req), cost, cand.req.rid)
        cached = 0
        if self.trie is not None:
            cached = self.trie.probe(
                cand.prompt, require_snapshot=self._stateful)
        cost = len(cand.prompt) - cached
        if self._cross and cand.frames is not None:
            # charge the ENCODE pass unless the source is already warm in
            # the EncoderCache (advisory, like the trie probe — the
            # authoritative lookup happens at admission)
            if not (self.enc_cache is not None
                    and cand.enc_digest in self.enc_cache):
                cost += len(cand.frames)
        return (self._rank(cand), cost, cand.rid)

    @staticmethod
    def _cand_rid(cand: Union[Request, PreemptedState]) -> int:
        return (cand.req if isinstance(cand, PreemptedState) else cand).rid

    def _pick_candidate(self, cands: List[Union[Request, PreemptedState]]):
        """Choose the next admission from the waiting set (queued + parked).

        FIFO mode: oldest submission first (parked requests keep their
        original rid, so a forced preemption resumes in order). Priority
        mode: best ``_candidate_key``, with the aging floor — once
        ``starvation_limit`` consecutive admissions have overtaken the
        oldest waiter, the oldest waiter is force-admitted and the
        counter resets. No class can starve: the floor is class-blind."""
        oldest = min(cands, key=self._cand_rid)
        if not self.cfg.priorities:
            return oldest
        if self._overtakes >= self.cfg.starvation_limit:
            choice = oldest
        else:
            choice = min(cands, key=self._candidate_key)
        if choice is oldest:
            self._overtakes = 0
        else:
            self._overtakes += 1
        return choice

    def _admissions(self):
        """Fill free slots from the waiting set: resume parked requests
        and admit fresh ones under one ordering rule."""
        while self._free:
            cands = self._queue.snapshot() + self._parked
            if not cands:
                break
            choice = self._pick_candidate(cands)
            slot = self._free.pop(0)
            if isinstance(choice, PreemptedState):
                self._parked.remove(choice)
                self._resume(slot, choice)
            else:
                self._queue.remove(choice)
                self._admit(slot, choice)

    def _preempt_pass(self) -> int:
        """Free slots for waiting higher-class requests by parking
        strictly lower-class victims. A victim must outrank (numerically:
        higher rank value than) the BEST waiting overflow request and
        still be under its ``max_preempts`` immunity cap; among victims
        the worst class goes first, most recent admission breaking ties
        (deterministic, and the youngest slot has the least decode
        momentum). Each parking frees one slot, so the overflow shrinks
        monotonically and the loop terminates."""
        n = 0
        while True:
            waiting = self._queue.snapshot() + self._parked
            if self.cfg.priorities:
                waiting.sort(key=self._candidate_key)
            else:                        # pragma: no cover - preempt=>prio
                waiting.sort(key=self._cand_rid)
            overflow = waiting[len(self._free):]
            if not overflow:
                break
            best_rank = min(
                self._rank(c.req if isinstance(c, PreemptedState) else c)
                for c in overflow
            )
            victims = [
                s for s, r in self._live.items()
                if self._rank(r) > best_rank
                and r.preempt_count < self.cfg.max_preempts
            ]
            if not victims:
                break
            victim = max(
                victims,
                key=lambda s: (self._rank(self._live[s]),
                               self._live[s].admit_step,
                               self._live[s].rid),
            )
            self.preempt_slot(victim)
            n += 1
        return n

    # ------------------------------------------------------------------
    def _boundaries_needing_snapshots(self, prompt) -> set:
        """Page boundaries of ``prompt`` whose trie node is missing (or
        snapshotless, e.g. republished after eviction) — the only places
        prefill must pause at and capture recurrent state. Once the walk
        falls off the trie every deeper boundary needs one."""
        need, node = set(), self.trie.root
        for i in range(len(prompt) // self.pt):
            if node is not None:
                key = tuple(int(t) for t in
                            prompt[i * self.pt:(i + 1) * self.pt])
                node = node.children.get(key)
            if node is None or node.snapshot is None:
                need.add((i + 1) * self.pt)
        return need

    def _alloc_page(self) -> int:
        """Take a page from the pool, evicting LRU trie leaves on demand.
        A trie eviction drops the trie's reference; the loop keeps going
        because a page shared with a live slot does not free until that
        slot retires."""
        pid = self.pool.alloc()
        while pid is None:
            if self.trie is None or not self.trie.evict_one():
                raise RuntimeError(
                    f"KV page pool exhausted ({self.pool.n_pages} pages, "
                    f"0 free, {len(self.trie) if self.trie else 0} trie "
                    f"nodes): raise pool_pages"
                )
            pid = self.pool.alloc()
        return pid

    def _alloc_xpage(self) -> int:
        """Take a cross-pool page, evicting LRU EncoderCache entries on
        demand (their pages free once no live slot maps them)."""
        pid = self.xpool.alloc()
        while pid is None:
            if self.enc_cache is None or not self.enc_cache.evict_one():
                raise RuntimeError(
                    f"cross-attention page pool exhausted "
                    f"({self.xpool.n_pages} pages, 0 free, "
                    f"{len(self.enc_cache) if self.enc_cache else 0} "
                    f"cached encoder outputs): raise cross_pages"
                )
            pid = self.xpool.alloc()
        return pid

    def _run_encode(self, slot: int) -> int:
        """The slot's ENCODE phase: allocate its cross page run, run the
        padded batch=1 encoder tick (frames -> memory -> per-layer cross
        K/V scattered through the slot's cross table row), publish the
        result to the EncoderCache, and advance the phase machine to
        PREFILL. Returns the token charge against this tick's budget."""
        req = self._live[slot]
        enc_len = int(self._enc_lens[slot])
        need = -(-enc_len // self.pt)
        while self._xn_mapped[slot] < need:
            self._xptab[slot, self._xn_mapped[slot]] = self._alloc_xpage()
            self._xn_mapped[slot] += 1
        d = self.model.cfg.d_model
        frames = np.zeros((1, self.enc_tokens, d), np.float32)
        frames[0, :enc_len] = req.frames
        valid = np.zeros((1, self.enc_tokens), bool)
        valid[0, :enc_len] = True
        self.caches = self._aot.get("encode_tick", self._encode)(
            self.params, jnp.asarray(frames, self._cache_dtype),
            jnp.asarray(valid), self.caches,
            jnp.asarray(self._xptab[slot:slot + 1]),
        )
        self._phase[slot] = PREFILL
        self._stats["encode_ticks"] += 1
        if self.tel is not None:
            self.tel.encode_ticks.inc()
            if req.span is not None:
                req.span.to_phase(PREFILL, time.monotonic())
        if self.enc_cache is not None:
            pages = [int(self._xptab[slot, i]) for i in range(need)]
            self.enc_cache.put(req.enc_digest, pages, enc_len,
                               now=self.steps)
        return min(enc_len, self.cfg.chunk_tokens)

    def _ensure_pages(self, slot: int, last_pos: int):
        """Grow the slot's page table to cover ``last_pos``: fresh private
        pages for everything past the mapped prefix. Positions past the
        table's reach (length overruns) are left to the scatter's drop —
        identical to the dense cache's out-of-bounds behavior."""
        if self.pool is None:
            return
        need = min(last_pos // self.pt, self.npp - 1)
        while self._n_mapped[slot] <= need:
            pid = self._alloc_page()
            self._ptab[slot, self._n_mapped[slot]] = pid
            self._n_mapped[slot] += 1

    def _schedule_prefill(self, n_decoding: int,
                          extra_charge: int = 0) -> Dict[int, int]:
        """Token-budget pass: chunk_tokens per tick, decode-priority.

        Every decoding slot is charged one token up front; what remains
        goes to prefilling slots in admission order, each capped at the
        chunk width. The head of the prefill queue always receives at
        least one token so prefill progresses even when decoding slots
        consume the whole budget.

        With the prefix cache on a STATEFUL model (recurrent carries or
        windowed rings), a chunk additionally never crosses a page
        boundary that still NEEDS a snapshot (``_need_snaps``, computed
        at admission): the recurrent state right after the chunk then
        sits at exactly the boundary the trie pins. Boundaries the trie
        already covers don't pause the chunk, so a warm repeat of a
        shared prompt prefills at full chunk width. Stateless (pure
        full-attention) models never cap — their pages are position-
        addressed, chunk splits don't matter.

        ``extra_charge`` bills work already done this tick outside this
        pass — the ENCODE phase's padded encoder call — against the same
        budget, so an encode-heavy tick hands out fewer prefill columns
        (the head-of-queue floor still guarantees progress)."""
        c = self.cfg.chunk_tokens
        budget = c - n_decoding - extra_charge
        takes: Dict[int, int] = {}
        first = True
        for slot in self._admit_order:
            if self._phase[slot] != PREFILL:
                continue
            off = int(self._offsets[slot])
            rem = len(self._live[slot].prompt) - off
            floor = 1 if first else 0
            take = min(c, rem, max(budget, floor))
            ahead = [b for b in self._need_snaps[slot] if b > off]
            if ahead:
                take = min(take, min(ahead) - off)
            first = False
            if take <= 0:
                continue
            takes[slot] = take
            budget -= take
        return takes

    def _run_extend(self, takes: Dict[int, int]):
        cfg = self.cfg
        tel = self.tel
        block = np.zeros((cfg.n_slots, cfg.chunk_tokens), np.int32)
        n_new = np.zeros((cfg.n_slots,), np.int32)
        for slot, take in takes.items():
            off = int(self._offsets[slot])
            block[slot, :take] = self._live[slot].prompt[off:off + take]
            n_new[slot] = take
            self._ensure_pages(slot, off + take - 1)
        t0 = time.perf_counter() if tel is not None else 0.0
        toks, self.caches, self.lengths = self._aot.get(
            "extend_tick", self._extend)(
            self.params, jnp.asarray(block), self.caches, self.lengths,
            jnp.asarray(n_new), self.temps, self.topks,
            self._slot_keys, jnp.asarray(self._counts),
            jnp.asarray(self._ptab), *self._cross_extra(),
        )
        if tel is not None:
            # bound the device phase: async dispatch means the call above
            # returned before the computation finished; waiting on the
            # sampled tokens (needed on host immediately below anyway)
            # splits device compute from host bookkeeping without
            # changing any value
            jax.block_until_ready(toks)
            t1 = time.perf_counter()
            self._tick_phases["prefill_device"] = t1 - t0
            tel.prefill_tokens.inc(sum(takes.values()))
        toks_host = np.asarray(toks)
        for slot, take in takes.items():
            req = self._live[slot]
            self._offsets[slot] += take
            off_new = int(self._offsets[slot])
            if off_new in self._need_snaps[slot]:
                # prefill just landed on a boundary the trie is missing:
                # pin the recurrent state HERE so the published (or
                # snapshot-backfilled) node can restore it
                self._snaps[slot][off_new] = self._aot.get(
                    "snapshot_slot", self._snapshot)(self.caches, slot)
            if self._offsets[slot] == len(req.prompt):
                # prompt complete: the chunk's last-column logits are the
                # request's first sampled token
                self._phase[slot] = DECODE
                self._admit_order.remove(slot)
                tok = int(toks_host[slot])
                req.output.append(tok)
                req.token_steps.append(self.steps)
                if len(req.output) == 1:
                    # first token: per-class TTFT in ticks, measured from
                    # submission (queue wait + parked time included)
                    acc = self._class_ttft.setdefault(req.priority, [0, 0])
                    acc[0] += self.steps - req.submit_step
                    acc[1] += 1
                self._counts[slot] += 1
                self._stats["tokens_out"] += 1
                if tel is not None:
                    tel.tokens.inc()
                    if req.span is not None:
                        now = time.monotonic()
                        req.span.to_phase(DECODE, now)
                        if req.span.token(now):
                            tel.ttft.observe(now - req.span.submit_t)
                self.tokens = self.tokens.at[slot, 0].set(tok)
                if self.on_token is not None:
                    self.on_token(req, tok)
                self._maybe_retire(slot, req, tok)
        if tel is not None:
            self._tick_phases["prefill_host"] = time.perf_counter() - t1

    def _run_decode(self, decoding: List[int]):
        tel = self.tel
        active = np.zeros((self.cfg.n_slots,), bool)
        active[decoding] = True
        for slot in decoding:
            req = self._live[slot]
            pos = len(req.prompt) + len(req.output) - 1  # row this step writes
            if pos < self.cfg.max_len:
                self._ensure_pages(slot, pos)
        t0 = time.perf_counter() if tel is not None else 0.0
        nxt, self.caches, self.lengths = self._aot.get(
            "decode_tick", self._decode)(
            self.params, self.tokens, self.caches, self.lengths,
            jnp.asarray(active), self.temps, self.topks,
            self._slot_keys, jnp.asarray(self._counts),
            jnp.asarray(self._ptab), *self._cross_extra(),
        )
        if tel is not None:
            jax.block_until_ready(nxt)
            t1 = time.perf_counter()
            self._tick_phases["decode_device"] = t1 - t0
        nxt_host = np.asarray(nxt)
        self.tokens = nxt[:, None]
        for slot in decoding:
            req = self._live[slot]
            tok = int(nxt_host[slot])
            req.output.append(tok)
            req.token_steps.append(self.steps)
            self._counts[slot] += 1
            self._stats["tokens_out"] += 1
            if tel is not None:
                tel.tokens.inc()
                if req.span is not None:
                    now = time.monotonic()
                    prev = req.span.last_token_t
                    if req.span.token(now):
                        tel.ttft.observe(now - req.span.submit_t)
                    else:
                        tel.itl.observe(now - prev)
            if self.on_token is not None:
                self.on_token(req, tok)
            self._maybe_retire(slot, req, tok)
        if tel is not None:
            self._tick_phases["decode_host"] = time.perf_counter() - t1

    def step(self):
        """One engine tick: preemption pass + admissions/resumes +
        scheduled prefill chunks + one batched decode step. Every live
        decoding slot emits exactly one token per tick regardless of
        concurrent prefill (the fairness invariant); a prefilling slot
        emits its first token on the tick its final chunk lands. A slot
        preempted this tick emits nothing — exactly the cost the
        preempt-free tick rate reports."""
        tel = self.tel
        with self._mesh_ctx():
            if tel is not None:
                t_tick = time.perf_counter()
                trace_pre = (sum(TRACE_COUNTS.values())
                             if self._retrace_armed else 0)
            if self.cfg.preempt:
                self._preempt_pass()
            if tel is not None:
                t_adm = time.perf_counter()
                self._tick_phases["preempt"] = t_adm - t_tick
            self._admissions()
            depth = self._queue.qsize()
            if depth > self._stats["peak_queue_depth"]:
                self._stats["peak_queue_depth"] = depth
            if tel is not None:
                self._tick_phases["admission"] = time.perf_counter() - t_adm
            if not self._live:
                # idle tick: no jitted call ran, nothing to observe (an
                # empty-engine poll loop must not drown the tick
                # histograms in zero-work samples)
                self._tick_phases.clear()
                return
            # ENCODE pass (cross models): warm-cache late hits resolve in
            # O(1); at most ONE padded encoder call actually runs per tick
            # and its cost is billed against the prefill budget below.
            enc_charge = 0
            if self._cross:
                t_enc = time.perf_counter() if tel is not None else 0.0
                for s in list(self._admit_order):
                    if self._phase[s] != ENCODE:
                        continue
                    if self._try_enc_cache(s, self._live[s]):
                        continue
                    enc_charge = self._run_encode(s)
                    break
                if tel is not None and enc_charge:
                    self._tick_phases["encode"] = (
                        time.perf_counter() - t_enc)
            decoding = [s for s in range(self.cfg.n_slots)
                        if self._phase[s] == DECODE]
            dec_reqs = [(self._live[s], len(self._live[s].output))
                        for s in decoding]
            takes = self._schedule_prefill(len(decoding),
                                           extra_charge=enc_charge)
            if takes:
                self._run_extend(takes)
            if decoding:
                self._run_decode(decoding)
            # preempt-free accounting: a work tick is clean iff no slot
            # was preempted since the last tick AND every slot that
            # entered it decoding emitted exactly one token. The rate is
            # what the preempting scheduler SPENT to keep the interactive
            # class's TTFT down.
            self._stats["work_ticks"] += 1
            if (not self._preempted_since_tick
                    and all(len(r.output) == n + 1 for r, n in dec_reqs)):
                self._stats["preempt_free_ticks"] += 1
            self._preempted_since_tick = False
            if tel is not None:
                self._observe_tick(t_tick, trace_pre)
        self.steps += 1

    def _observe_tick(self, t_tick: float, trace_pre: int):
        """End-of-tick telemetry: the tick + per-phase histograms, then
        the steady-state retrace check. Runs only with telemetry on."""
        tel = self.tel
        tel.tick.observe(time.perf_counter() - t_tick)
        for phase, dt in self._tick_phases.items():
            tel.tick_phase[phase].observe(dt)
        self._tick_phases.clear()
        if not self._retrace_armed:
            return
        delta = sum(TRACE_COUNTS.values()) - trace_pre
        if delta <= 0:
            return
        # a tick function's Python body ran DURING this tick — after AOT
        # warmup that means jax compiled something mid-serving (shape
        # drift, a cache miss, an un-warmed entry point): exactly the
        # stall class warmup() exists to prevent. Count every retrace,
        # warn once per engine.
        tel.retraces.inc(delta)
        if tel.ring is not None:
            tel.ring.emit("retrace", tick=self.steps, n_traces=delta)
        if not self._retrace_warned:
            self._retrace_warned = True
            warnings.warn(
                f"serve engine re-traced {delta} tick function(s) at tick "
                f"{self.steps} after AOT warmup — a compile stall is "
                f"hiding in the serving path (see serve_retraces_total)",
                RuntimeWarning,
                stacklevel=2,
            )

    def stats(self) -> Dict[str, object]:
        """Engine health counters for the serve CLI / HTTP ``/stats``
        endpoint (and tests): admission hit rate, prefill tokens the
        prefix cache skipped, page-pool utilization, queue pressure
        (current + peak depth, typed rejects), throughput (tokens out,
        work ticks), the preempt-free tick rate, and whether the tick
        executables are AOT-warm."""
        s = dict(self._stats)
        s["hit_rate"] = s["prefix_hits"] / max(s["admitted"], 1)
        s["prefill_tokens_skipped"] = s.pop("prefix_tokens")
        if self.pool is not None:
            s["pool_pages"] = self.pool.n_pages
            s["pages_in_use"] = self.pool.used_pages
            s["page_utilization"] = self.pool.used_pages / self.pool.n_pages
        # per-cache-family pool utilization (ServableModel cache families;
        # the flat pool_* keys above stay for the historical dashboards)
        s["cache_families"] = {
            p.family: {
                "pages": p.n_pages,
                "in_use": p.used_pages,
                "utilization": p.used_pages / p.n_pages,
            }
            for p in (self.pool, self.xpool) if p is not None
        }
        s["enc_cache_entries"] = (
            len(self.enc_cache) if self.enc_cache is not None else 0
        )
        s["trie_nodes"] = len(self.trie) if self.trie is not None else 0
        s["evictions"] = self.trie.evictions if self.trie is not None else 0
        s["queue_depth"] = self._queue.qsize()
        s["compute_path"] = self.cfg.compute_path
        s["live_slots"] = len(self._live)
        s["free_slots"] = len(self._free)
        s["parked"] = len(self._parked)
        s["ticks"] = self.steps
        s["preempt_free_tick_rate"] = (
            s["preempt_free_ticks"] / max(s["work_ticks"], 1)
        )
        # per-class first-token latency in TICKS (submission -> first
        # token, queue wait and parked time included): the number the
        # priority scheduler exists to move, wall-clock-free so tests can
        # assert on it deterministically
        s["class_ttft_ticks"] = {
            cls: round(total / n, 2)
            for cls, (total, n) in sorted(self._class_ttft.items())
        }
        s["class_counts"] = {
            cls: n for cls, (_, n) in sorted(self._class_ttft.items())
        }
        s["aot_warm"] = self.aot_warm
        if self.tel is not None:
            # wall-clock latency quantiles from the telemetry histograms
            # (bucket-interpolated, ms): the /stats mirror of what
            # /metrics exposes raw — absent entirely with telemetry off
            s["latency"] = self.tel.latency_summary()
            s["retraces"] = self.tel.retraces.get()
        return s

    def run_until_drained(self, max_steps: int = 10_000, on_tick=None) -> int:
        """Step until every submitted request completes; returns the tick
        count. ``on_tick(engine)`` runs after each tick — drivers hook it
        for per-tick wall-clock latency accounting without forfeiting the
        bounded-steps wedge diagnostics below."""
        for i in range(max_steps):
            if not self.has_work:
                return i
            self.step()
            if on_tick is not None:
                on_tick(self)
        slots = ", ".join(
            f"slot {s}: rid={r.rid} {self._phase[s]}"
            f"@{int(self._offsets[s])}/{len(r.prompt)}"
            f" ({len(r.output)}/{r.params.max_tokens} tok)"
            for s, r in sorted(self._live.items())
        )
        parked = ", ".join(
            f"rid={p.req.rid} parked {p.phase}@{p.offset}"
            f"/{len(p.req.prompt)} ({len(p.req.output)} tok, "
            f"preempted x{p.req.preempt_count})"
            for p in self._parked
        )
        raise RuntimeError(
            f"engine did not drain after {max_steps} steps: "
            f"{self._queue.qsize()} queued, {len(self._live)} live, "
            f"{len(self._parked)} parked — "
            f"{'; '.join(x for x in (slots, parked) if x) or 'no live slots'}"
        )

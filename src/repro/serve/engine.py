"""Batched serving engine: slot-based continuous batching over
(prefill, decode_step) with packed-tile weights.

Design (vLLM-style, adapted to fixed-shape XLA):

* ``n_slots`` concurrent sequences share one decode step of static shape
  (B=n_slots, 1). A request occupies a slot from admission to completion.
* Admission runs prefill for the incoming prompt (LEFT-padded to a fixed
  bucket so prefill compiles once per bucket and the last position is the
  true final prompt token), then *splices* the prompt's caches into the
  slot's rows of the shared decode cache.
* Each engine tick = one jitted (decode step + per-slot sampling) for all
  live slots + host-side bookkeeping (EOS/max_tokens retirement, new
  admissions). Sampling params live in per-slot ``(n_slots,)`` arrays
  populated at admission and fed to the tick as runtime values, so every
  token honors its request's temperature/top-k, nothing recompiles when a
  new request lands in a slot, and only token ids cross back to host.
  Dead slots run the same step (masked out) — shapes never change.
* Weights are SERVE-form (packed tiles + alphas, repro.serve.weights); the
  model's serve path applies them through the tile-reuse math, so HBM holds
  q bits per tiled layer, not N.
* Passing ``mesh=`` places the weights with the serving sharding rules
  (packed tile rows over the model axis — 1/TP tile bytes per device) and
  traces prefill/decode under those rules, so the tile-reuse matmuls run
  tensor-parallel through the shard_map wrappers in kernels/ops.py
  (DESIGN.md §5). Without a mesh nothing touches device placement APIs.

The engine is exact on CPU with reduced configs (integration tests) and is
the same code path the dry-run compiles for the production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import queue
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import axis_rules, param_shardings
from repro.serve.sampling import (
    SamplingParams,
    sample_logits,
    sample_logits_batch,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None  # "eos" | "length" once done


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 4
    max_len: int = 256                  # cache capacity per slot
    prefill_buckets: Tuple[int, ...] = (32, 128)
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        """Fail fast on a bad bucket ladder. An oversized bucket would let
        ``submit()`` accept a prompt whose prefill cache cannot be spliced
        into the ``max_len`` decode cache (corruption or a shape error deep
        inside the tick loop); an empty/unsorted ladder breaks bucketing."""
        b = tuple(self.prefill_buckets)
        if not b:
            raise ValueError("prefill_buckets must be non-empty")
        if any(x <= 0 for x in b):
            raise ValueError(f"prefill_buckets must be positive: {b}")
        if list(b) != sorted(set(b)):
            raise ValueError(
                f"prefill_buckets must be strictly increasing: {b}"
            )
        if b[-1] > self.max_len:
            raise ValueError(
                f"prefill bucket {b[-1]} exceeds max_len {self.max_len}: "
                "a prompt admitted through it could not fit the decode cache"
            )


class BatchedEngine:
    def __init__(self, model, params, cfg: ServeConfig, *, mesh=None):
        self.model = model
        self.cfg = cfg
        self.mesh = mesh
        if mesh is not None:
            # Place the serve weights with the serving rules: packed tile
            # rows ("tile_rows") shard over the model axis, ragged or
            # non-dividing dims drop to replicated (distributed/sharding).
            from repro.nn import module as mod

            logical = mod.logical_axes(model.specs())
            abstract = jax.tree.map(
                lambda v: jax.ShapeDtypeStruct(v.shape, v.dtype), params
            )
            shardings = param_shardings(
                mesh, logical, abstract_tree=abstract
            )
            params = jax.device_put(params, shardings)
        self.params = params
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._live: Dict[int, Request] = {}      # slot -> request
        self._free = list(range(cfg.n_slots))
        self._key = jax.random.PRNGKey(cfg.seed)
        self._rid = itertools.count()

        cache_dtype = getattr(model.ctx, "compute_dtype", jnp.bfloat16)
        self.caches = model.init_caches(cfg.n_slots, cfg.max_len, cache_dtype)
        self.lengths = jnp.zeros((cfg.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((cfg.n_slots, 1), jnp.int32)
        # Per-slot sampling params, populated at admission from the
        # request's resolved SamplingParams (None sentinels -> ServeConfig
        # defaults). temps/topks ride into the jitted tick as runtime
        # arrays; eos ids stay host-side for retirement bookkeeping.
        self.temps = jnp.zeros((cfg.n_slots,), jnp.float32)
        self.topks = jnp.zeros((cfg.n_slots,), jnp.int32)
        self._eos_ids = np.full((cfg.n_slots,), -1, np.int64)

        def _tick(params, tokens, caches, lengths, temps, topks, key):
            """decode step + per-slot sampling fused under one jit: the
            (n_slots, vocab) logits never leave the device."""
            logits, caches, lengths = model.decode_step(
                params, tokens, caches, lengths
            )
            nxt = sample_logits_batch(
                logits, key, temperature=temps, top_k=topks
            )
            return nxt, caches, lengths

        self._decode = jax.jit(_tick)
        self._prefill = {
            b: jax.jit(lambda p, batch, b=b: model.prefill(p, batch, cfg.max_len))
            for b in cfg.prefill_buckets
        }
        self.steps = 0

    def _mesh_ctx(self):
        """Sharding-rule context for traces/executions; no-op without mesh."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self.mesh)

    # ------------------------------------------------------------------
    def submit(
        self, prompt, params: Optional[SamplingParams] = None
    ) -> Request:
        prompt = np.asarray(prompt, np.int32)
        # Validate against the bucket ladder HERE, not at admission: a
        # too-long prompt then fails fast without consuming a slot or
        # wedging the tick loop mid-admission.
        self._bucket(len(prompt))
        req = Request(
            rid=next(self._rid),
            prompt=prompt,
            params=params or SamplingParams(),
        )
        self._queue.put(req)
        return req

    def _bucket(self, n: int) -> int:
        for b in self.cfg.prefill_buckets:
            if n <= b:
                return b
        raise ValueError(
            f"prompt len {n} exceeds largest bucket {self.cfg.prefill_buckets[-1]}"
        )

    def _maybe_retire(self, slot: int, req: Request, tok: int) -> bool:
        """Retire a just-extended request. EOS is checked before the length
        cap so a stop token arriving exactly at max_tokens reports "eos"."""
        if tok == int(self._eos_ids[slot]):
            req.finish_reason = "eos"
        elif len(req.output) >= req.params.max_tokens:
            req.finish_reason = "length"
        else:
            return False
        req.done = True
        self._live.pop(slot, None)
        self._free.append(slot)
        # Reset the slot's sampling params: a stale temperature/top-k on a
        # dead slot would keep tripping jnp.any(...) in the batch sampler
        # and defeat its all-greedy / no-top-k fast paths for every later
        # tick until the slot is reused.
        self.temps = self.temps.at[slot].set(0.0)
        self.topks = self.topks.at[slot].set(0)
        self._eos_ids[slot] = -1
        return True

    def _admit(self, slot: int, req: Request):
        n = len(req.prompt)
        b = self._bucket(n)
        toks = np.zeros((1, b), np.int32)
        # LEFT-pad so the last position is the true final prompt token —
        # left pads attend as ordinary (zero-token) context, which keeps the
        # prefill a single fixed-shape call per bucket.
        toks[0, b - n:] = req.prompt
        logits, caches, _ = self._prefill[b](self.params, {"tokens": toks})
        # splice the prompt caches into this slot's rows
        self.caches = jax.tree.map(
            lambda dst, src: _splice_cache(dst, src, slot), self.caches, caches
        )
        self.lengths = self.lengths.at[slot].set(b)
        # Resolve the request's sampling params against the engine defaults
        # (is-None sentinels: an explicit temperature=0.0 / top_k=0 wins
        # over a stochastic ServeConfig default) and pin them to the slot —
        # every subsequent decode tick reads them from the per-slot arrays.
        res = req.params.resolve(self.cfg.temperature, self.cfg.top_k)
        self.temps = self.temps.at[slot].set(res.temperature)
        self.topks = self.topks.at[slot].set(res.top_k)
        self._eos_ids[slot] = res.eos_id
        self._key, sub = jax.random.split(self._key)
        # Prefill-token sampling: the resolved params are static scalars
        # here, so the scalar sampler applies (same masked logits and key
        # stream as the batch sampler — tokens are identical).
        first = sample_logits(
            logits, sub, temperature=res.temperature, top_k=res.top_k,
        )
        tok = int(first[0])
        req.output.append(tok)
        self.tokens = self.tokens.at[slot, 0].set(first[0])
        self._live[slot] = req
        # the prefill token itself may already satisfy EOS or max_tokens=1
        self._maybe_retire(slot, req, tok)

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admissions + a single batched decode step."""
        with self._mesh_ctx():
            while self._free and not self._queue.empty():
                self._admit(self._free.pop(0), self._queue.get())
            if not self._live:
                return
            self._key, sub = jax.random.split(self._key)
            nxt, self.caches, self.lengths = self._decode(
                self.params, self.tokens, self.caches, self.lengths,
                self.temps, self.topks, sub,
            )
        nxt_host = np.asarray(nxt)
        self.tokens = nxt[:, None]
        for slot, req in list(self._live.items()):
            tok = int(nxt_host[slot])
            req.output.append(tok)
            self._maybe_retire(slot, req, tok)
        self.steps += 1

    def run_until_drained(self, max_steps: int = 10_000) -> int:
        for i in range(max_steps):
            if self._queue.empty() and not self._live:
                return i
            self.step()
        raise RuntimeError("engine did not drain")


# ---------------------------------------------------------------------------
def _splice_cache(dst: jax.Array, src: jax.Array, slot: int) -> jax.Array:
    """Insert a B=1 prefill cache leaf into row ``slot`` of the engine cache.

    Leaves may carry a leading layer-stack dim: dst (L, B, ...) vs src
    (L, 1, ...), or be unstacked: dst (B, ...) vs src (1, ...). The batch
    axis is wherever dst.shape and src.shape first differ.
    """
    if dst.ndim != src.ndim:
        raise ValueError(f"cache rank mismatch {dst.shape} vs {src.shape}")
    batch_axis = None
    for i, (d, s) in enumerate(zip(dst.shape, src.shape)):
        if d != s:
            batch_axis = i
            break
    if batch_axis is None:  # shapes equal (n_slots == 1)
        return src.astype(dst.dtype)
    # time axes may also differ (prefill cache padded to max_len already by
    # model._pad_cache, so only batch should differ)
    idx = [slice(None)] * dst.ndim
    idx[batch_axis] = slot
    return dst.at[tuple(idx)].set(
        jnp.squeeze(src, axis=batch_axis).astype(dst.dtype)
    )

"""Async HTTP/SSE serving front-end over ``BatchedEngine``.

The production shell ROADMAP item 1 asks for: an asyncio streaming
server whose tick loop never blocks on host-side string work and whose
first request never pays a trace.

Dataflow (DESIGN.md §6.3):

    asyncio loop (1 thread)          engine thread           detok thread
    ------------------------         --------------          ------------
    POST /generate ──submit──▶ admission queue
                               tick loop: step() ──on_token──▶ backlog
    TokenStream.push ◀──call_soon_threadsafe── codec ◀────────── drain
    SSE writer ◀── bounded per-stream buffer

* The HTTP layer is plain asyncio streams — no framework dependency; the
  protocol surface is four routes: ``POST /generate`` (JSON body →
  SSE stream of token events, or one JSON reply with ``stream: false``),
  ``GET /stats`` (engine + server counters, plus histogram quantiles
  when telemetry is on), ``GET /metrics`` (the engine's telemetry
  registry in Prometheus text exposition format, plus the HTTP-side
  families this module registers into the same registry), and
  ``GET /healthz``.
  The body's optional ``"priority"`` field ("interactive" | "batch")
  rides through ``SamplingParams.from_json`` into the engine's
  admission queue: under ``ServeConfig.priorities``/``preempt`` an
  interactive request overtakes queued batch work and may preempt a
  decoding batch slot (DESIGN.md §6.4); an unknown class is a 400.
* The ENGINE THREAD owns every jitted call: it drains the admission
  queue and ticks while work exists, sleeping on a condition variable
  otherwise. ``submit`` only enqueues (the engine's own thread-safe
  queue) — a handler never traces, ticks, or blocks on the device.
* Detokenization runs on the DEDICATED backlog thread
  (serve/detok.py): the tick's ``on_token`` callback is one queue put.
  Token text re-enters the loop thread via ``call_soon_threadsafe`` into
  per-stream BOUNDED buffers.
* Backpressure is typed end to end: a full admission queue
  (``ServeConfig.max_queued``) raises ``AdmissionQueueFull`` → HTTP 429
  with a JSON body, never a blocked tick loop. A slow SSE consumer hits
  its stream's bounded buffer: policy ``"disconnect"`` ends that stream
  (and aborts its request), ``"drop"`` sheds token events but keeps the
  final event; either way other streams never stall — each connection is
  its own task and the engine never waits on a writer.
* ``close()`` is the mid-flight shutdown contract the regression wall
  pins: stop accepting, join the tick thread, abort every queued+live
  request (slots and pool pages free — the PR 5 no-leak invariant),
  flush the detokenize backlog (every token emitted before shutdown
  still reaches its stream as text), then join the backlog thread.
* ``start(aot=True)`` runs ``BatchedEngine.warmup()`` before the first
  connection is accepted, so the first request's TTFT contains zero
  trace/compile work (docs/kernels.md, tests/test_warmup.py).
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import json
import queue
import socket
import threading
import time
from typing import Callable, Dict, Optional

from repro.serve.detok import DetokenizeWorker, PieceCodec
from repro.serve.engine import AdmissionQueueFull, BatchedEngine, Request
from repro.serve.sampling import SamplingParams

# the /metrics histogram's route label vocabulary — anything else maps to
# "other" so a path-scanning client cannot mint unbounded label children
_ROUTES = ("/generate", "/stats", "/metrics", "/healthz")

SLOW_DISCONNECT = "disconnect"
SLOW_DROP = "drop"


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    host: str = "127.0.0.1"
    port: int = 8000                 # 0 -> OS-assigned (tests)
    stream_buffer: int = 256         # per-stream bounded event buffer
    slow_policy: str = SLOW_DISCONNECT   # bounded-buffer overflow policy
    drain_timeout: float = 5.0       # max seconds a writer may sit in
    # drain() before the consumer is declared slow (policy applies)
    write_high_water: Optional[int] = None  # transport write buffer limit
    # in bytes; tiny values make drain() engage at test scale
    sndbuf: Optional[int] = None     # SO_SNDBUF on accepted connections;
    # like write_high_water this exists so the slow-consumer policy is
    # testable: default kernel buffers absorb ~100s of KB before drain()
    # ever blocks, far past what a short test stream emits

    def __post_init__(self):
        if self.slow_policy not in (SLOW_DISCONNECT, SLOW_DROP):
            raise ValueError(
                f"slow_policy must be '{SLOW_DISCONNECT}' or '{SLOW_DROP}':"
                f" {self.slow_policy!r}")
        if self.stream_buffer < 1:
            raise ValueError(
                f"stream_buffer must be >= 1: {self.stream_buffer}")


class TokenStream:
    """One request's bounded event buffer, owned by the loop thread.

    ``push`` (called via ``call_soon_threadsafe``) appends token events
    up to ``maxsize``; past that the event is DROPPED and the overflow
    flag sticks — the consumer's policy decides whether that means
    disconnect or just gaps. The final (``done``) event always lands:
    it is the one event a consumer cannot re-derive."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self._buf: collections.deque = collections.deque()
        self._wake = asyncio.Event()
        self.overflowed = False
        self.dropped = 0
        self.finished = False

    def push(self, event: dict) -> bool:
        if event.get("done"):
            self.finished = True
            self._buf.append(event)
            self._wake.set()
            return True
        if len(self._buf) >= self.maxsize:
            self.overflowed = True
            self.dropped += 1
            self._wake.set()
            return False
        self._buf.append(event)
        self._wake.set()
        return True

    async def next(self) -> dict:
        while not self._buf:
            self._wake.clear()
            await self._wake.wait()
        return self._buf.popleft()


class EngineServer:
    """The asyncio front-end; one per ``BatchedEngine``."""

    def __init__(self, engine: BatchedEngine, cfg: ServerConfig = None,
                 *, codec: Optional[PieceCodec] = None):
        self.engine = engine
        self.cfg = cfg or ServerConfig()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._streams: Dict[int, TokenStream] = {}
        self._closed = False
        self.counters = {"streams_opened": 0, "slow_disconnects": 0,
                         "http_rejects": 0, "client_aborts": 0,
                         "sse_dropped_events": 0}
        # HTTP-side metric families, registered into the ENGINE's
        # registry so one /metrics scrape covers the whole process.
        # fn-backed counters read the dict above — the loop thread keeps
        # its single-writer bookkeeping, the registry just exposes it.
        self._http_hist = None
        tel = engine.tel
        if tel is not None:
            r = tel.registry
            self._http_hist = r.histogram(
                "serve_http_request_seconds",
                "HTTP request handling, accept to close, by route",
                labels=("route",))
            for key, name, help_ in (
                ("streams_opened", "serve_streams_opened_total",
                 "Token streams opened by POST /generate"),
                ("slow_disconnects", "serve_slow_disconnects_total",
                 "Streams ended by the slow-consumer policy"),
                ("http_rejects", "serve_http_rejects_total",
                 "HTTP 429 responses from admission backpressure"),
                ("client_aborts", "serve_client_aborts_total",
                 "Requests aborted because the client disconnected"),
                ("sse_dropped_events", "serve_sse_dropped_events_total",
                 "Token events shed by bounded stream buffers"),
            ):
                r.counter(name, help_,
                          fn=lambda k=key: self.counters[k])
            r.gauge("serve_open_streams", "Live token streams",
                    fn=lambda: len(self._streams))
            r.gauge("serve_detok_backlog",
                    "Tokens queued for detokenization",
                    fn=lambda: self.detok.depth)
            r.gauge("serve_detok_backlog_peak",
                    "High-water mark of the detokenize backlog",
                    fn=lambda: self.detok.peak_depth)
        # throughput state for the periodic stats line (tokens at the
        # previous stats_line() call -> tok/s over the interval)
        self._last_stats = (time.monotonic(), 0)

        # engine thread machinery
        self._stop = False
        self._wake = threading.Condition()
        self._abort_q: "queue.Queue[Request]" = queue.Queue()
        self._tick_error: Optional[BaseException] = None
        self._tick_thread = threading.Thread(
            target=self._tick_loop, name="engine-tick", daemon=True)

        # engine -> detok handoff (engine thread side is two queue puts)
        engine.on_token = lambda req, tok: self.detok.push(req.rid, tok)
        engine.on_finish = lambda req: self.detok.finish(
            req.rid, req.finish_reason or "aborted")
        self.detok = DetokenizeWorker(self._emit, codec=codec)

    # ---- lifecycle ----------------------------------------------------
    async def start(self, *, aot: bool = True) -> int:
        """Bind, optionally AOT-warm the engine, start the tick thread.
        Returns the bound port (useful with ``port=0``)."""
        self._loop = asyncio.get_running_loop()
        if aot:
            # warm BEFORE accepting: a compile triggered by the first
            # request would sit squarely inside its TTFT. to_thread keeps
            # a supervising loop responsive during multi-second compiles.
            await asyncio.to_thread(self.engine.warmup)
        self._server = await asyncio.start_server(
            self._handle, self.cfg.host, self.cfg.port)
        self._tick_thread.start()
        return self.port

    @property
    def port(self) -> int:
        return self._server.sockets[0].getsockname()[1]

    async def serve_forever(self):
        async with self._server:
            await self._server.serve_forever()

    async def close(self):
        """Mid-flight-safe shutdown; see the module docstring contract."""
        if self._closed:
            return
        self._closed = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self._stop = True
        with self._wake:
            self._wake.notify_all()
        if self._tick_thread.is_alive() or self._tick_thread.ident:
            await asyncio.to_thread(self._tick_thread.join, 30.0)
        # tick thread is down -> abort is now safe; every live/queued
        # request fires on_finish -> a final "aborted" event per stream
        self.engine.abort_all()
        # sentinel lands BEHIND the aborts' final events: joining here
        # guarantees partial text of mid-flight streams was flushed
        await asyncio.to_thread(self.detok.close)

    # ---- engine thread ------------------------------------------------
    def _tick_loop(self):
        eng = self.engine
        while not self._stop:
            while not self._abort_q.empty():
                try:
                    eng.abort(self._abort_q.get_nowait())
                except queue.Empty:      # pragma: no cover
                    break
            # has_work counts parked (preempted) requests too: a parked
            # stream with an empty queue still needs ticks to resume
            if not eng.has_work:
                with self._wake:
                    if self._stop:
                        return
                    self._wake.wait(0.05)
                continue
            try:
                eng.step()
            except BaseException as e:   # noqa: BLE001 - fail every stream
                self._tick_error = e
                self._stop = True
                eng.abort_all()
                return

    def _kick(self):
        with self._wake:
            self._wake.notify_all()

    # ---- detok thread -> loop thread ----------------------------------
    def _emit(self, sid, event: dict):
        if self._loop is None or self._loop.is_closed():
            return
        try:
            self._loop.call_soon_threadsafe(self._deliver, sid, event)
        except RuntimeError:             # loop closed mid-call
            pass

    def _deliver(self, sid, event: dict):
        stream = self._streams.get(sid)
        if stream is not None and not stream.push(event):
            # bounded-buffer shed (push keeps the final event always)
            self.counters["sse_dropped_events"] += 1

    # ---- HTTP ---------------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        t0 = time.perf_counter()
        route = "other"
        try:
            if self.cfg.write_high_water is not None:
                writer.transport.set_write_buffer_limits(
                    high=self.cfg.write_high_water)
            if self.cfg.sndbuf is not None:
                sock = writer.get_extra_info("socket")
                if sock is not None:
                    sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF,
                                    self.cfg.sndbuf)
            line = await reader.readline()
            if not line:
                return
            try:
                method, path, _ = line.decode("latin1").split()
            except ValueError:
                await self._respond(writer, 400, {"error": "bad_request"})
                return
            headers = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length", 0) or 0)
            if n:
                body = await reader.readexactly(n)

            route = path if path in _ROUTES else "other"
            if method == "GET" and path == "/healthz":
                await self._respond(writer, 200, {"ok": True})
            elif method == "GET" and path == "/stats":
                await self._respond(writer, 200, self.stats())
            elif method == "GET" and path == "/metrics":
                await self._metrics(writer)
            elif method == "POST" and path == "/generate":
                await self._generate(writer, body)
            else:
                await self._respond(writer, 404, {"error": "not_found",
                                                  "path": path})
        except (asyncio.IncompleteReadError, ConnectionError):
            pass
        finally:
            if self._http_hist is not None:
                # for SSE this spans the whole stream, not just the
                # headers — /generate's histogram child reads as
                # "connection lifetime", the GET routes as true latency
                self._http_hist.labels(route=route).observe(
                    time.perf_counter() - t0)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    async def _metrics(self, writer):
        """Prometheus text exposition of the shared registry. A typed
        404 with telemetry off: scraping a deliberately dark engine is a
        config error worth a loud answer, not an empty page."""
        tel = self.engine.tel
        if tel is None:
            await self._respond(writer, 404, {
                "error": "telemetry_disabled",
                "detail": "engine built with ServeConfig(telemetry=False)"})
            return
        data = tel.registry.render().encode()
        with _suppress_conn():
            writer.write(
                f"HTTP/1.1 200 OK\r\n"
                f"Content-Type: text/plain; version=0.0.4; "
                f"charset=utf-8\r\n"
                f"Content-Length: {len(data)}\r\n"
                f"Connection: close\r\n\r\n".encode() + data)
            await writer.drain()

    async def _generate(self, writer, body: bytes):
        try:
            payload = json.loads(body.decode() or "{}")
            prompt = payload["prompt"]
            params = SamplingParams.from_json(payload)
        except (KeyError, ValueError, TypeError) as e:
            await self._respond(writer, 400, {
                "error": "bad_request", "detail": f"{type(e).__name__}: {e}"})
            return
        if self._tick_error is not None:
            await self._respond(writer, 500, {
                "error": "engine_failed", "detail": str(self._tick_error)})
            return
        streaming = bool(payload.get("stream", True))
        try:
            req = self.engine.submit(prompt, params)
        except AdmissionQueueFull as e:
            self.counters["http_rejects"] += 1
            await self._respond(writer, 429, {
                "error": "admission_queue_full",
                "queued": e.queued, "capacity": e.capacity,
                "retry": True})
            return
        except ValueError as e:
            await self._respond(writer, 400, {
                "error": "bad_prompt", "detail": str(e)})
            return
        # Register BEFORE yielding control: _deliver runs on this same
        # loop thread, so no token event can slip between submit and this
        # assignment. Non-streaming requests buffer every event (a request
        # emits at most max_tokens+1), streaming ones get the bounded
        # buffer the slow-consumer policy guards.
        maxsize = (self.cfg.stream_buffer if streaming
                   else req.params.max_tokens + 2)
        stream = TokenStream(maxsize)
        self._streams[req.rid] = stream
        self.counters["streams_opened"] += 1
        self._kick()
        try:
            if streaming:
                await self._stream_sse(writer, req, stream)
            else:
                await self._collect_json(writer, req, stream)
        finally:
            self._streams.pop(req.rid, None)
            if not req.done:
                # client went away mid-generation: hand the abort to the
                # tick thread (engine.abort is not tick-concurrent-safe)
                self.counters["client_aborts"] += 1
                self._abort_q.put(req)
                self._kick()

    async def _stream_sse(self, writer, req: Request, stream: TokenStream):
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n")
        disconnect = self.cfg.slow_policy == SLOW_DISCONNECT
        while True:
            if stream.overflowed and disconnect:
                self.counters["slow_disconnects"] += 1
                with _suppress_conn():
                    writer.write(_sse({"error": "slow_consumer",
                                       "policy": SLOW_DISCONNECT}))
                return
            event = await stream.next()
            if stream.dropped and not event.get("done"):
                event = dict(event, dropped=stream.dropped)
            try:
                writer.write(_sse(event))
                await asyncio.wait_for(writer.drain(),
                                       self.cfg.drain_timeout)
            except asyncio.TimeoutError:
                # the socket would not take the bytes in time: the
                # consumer is slow at the transport level, same verdict
                # as a buffer overflow
                self.counters["slow_disconnects"] += 1
                return
            except ConnectionError:
                return                   # client is simply gone
            if event.get("done"):
                return

    async def _collect_json(self, writer, req: Request,
                            stream: TokenStream):
        tokens, text = [], []
        while True:
            event = await stream.next()
            if event.get("done"):
                await self._respond(writer, 200, {
                    "tokens": tokens, "text": event["text"],
                    "finish_reason": event["finish_reason"],
                    "n_tokens": event["n_tokens"]})
                return
            tokens.append(event["token"])
            text.append(event["text"])

    async def _respond(self, writer, status: int, body: dict):
        phrase = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  429: "Too Many Requests", 500: "Internal Server Error"}
        data = json.dumps(body, default=_json_default).encode()
        with _suppress_conn():
            writer.write(
                f"HTTP/1.1 {status} {phrase.get(status, 'Error')}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(data)}\r\n"
                f"Connection: close\r\n\r\n".encode() + data)
            await writer.drain()

    def stats(self) -> dict:
        s = self.engine.stats()
        s.update(self.counters)
        s["detok_backlog"] = self.detok.depth
        s["detok_backlog_peak"] = self.detok.peak_depth
        s["open_streams"] = len(self._streams)
        tel = self.engine.tel
        if tel is not None and self._http_hist is not None:
            # the engine already contributed s["latency"]; fold the HTTP
            # route histograms in beside it (ms, bucket-interpolated)
            s["latency"]["http_ms"] = {
                "/".join(lv for _, lv in child.labels) or "all": {
                    "p50": _ms(child.quantile(0.50)),
                    "p99": _ms(child.quantile(0.99)),
                    "count": child.count,
                }
                for child in self._http_hist.children.values()
            }
        return s

    def stats_line(self) -> str:
        """One-line steady-state report for the CLI's ``--stats-interval``
        loop, sourced from the telemetry registry (value_of reads the
        same children /metrics renders). Throughput is measured over the
        window since the previous call."""
        tel = self.engine.tel
        now = time.monotonic()
        t_prev, tok_prev = self._last_stats
        if tel is not None:
            tokens = tel.registry.value_of("serve_tokens_total") or 0
        else:                            # registry off: engine counters
            tokens = self.engine.stats()["tokens_out"]
        self._last_stats = (now, tokens)
        rate = (tokens - tok_prev) / max(now - t_prev, 1e-9)
        s = self.engine.stats()
        pools = " ".join(
            f"{fam}={f['utilization']:.0%}"
            for fam, f in sorted(s.get("cache_families", {}).items())
        ) or "n/a"
        line = (
            f"tok/s={rate:7.1f} tokens={tokens} "
            f"live={s['live_slots']}/{self.engine.cfg.n_slots} "
            f"parked={s['parked']} queued={s['queue_depth']} "
            f"streams={len(self._streams)} pool[{pools}] "
            f"prefix_hit={s['hit_rate']:.0%} "
            f"detok_backlog={self.detok.depth}"
        )
        if tel is not None:
            lat = s["latency"]
            p50 = lat["ttft_ms"]["p50"]
            itl = lat["itl_ms"]["p50"]
            line += (f" ttft_p50={p50 if p50 is not None else '-'}ms"
                     f" itl_p50={itl if itl is not None else '-'}ms")
            retr = s.get("retraces", 0)
            if retr:
                line += f" RETRACES={retr}"
        return line


def _ms(v: Optional[float]) -> Optional[float]:
    return None if v is None else round(1e3 * v, 3)


def _sse(event: dict) -> bytes:
    return b"data: " + json.dumps(
        event, default=_json_default).encode() + b"\n\n"


def _json_default(o):
    if hasattr(o, "item"):
        return o.item()
    return str(o)


class _suppress_conn:
    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return et is not None and issubclass(et, ConnectionError)


async def run_server(engine: BatchedEngine, cfg: ServerConfig = None,
                     *, aot: bool = True, codec=None,
                     ready: Optional[Callable] = None,
                     stats_interval: float = 0.0):
    """Boot and serve until cancelled or signalled (the CLI entry point).

    SIGINT/SIGTERM are turned into a graceful stop via the loop's signal
    handler — a raw KeyboardInterrupt would otherwise be raised into
    whatever handler task happens to be running and leak a traceback
    mid-``writer.write``. ``stats_interval > 0`` prints the one-line
    telemetry report (``EngineServer.stats_line``) every that many
    seconds for the CLI's ``--stats-interval``."""
    import signal

    srv = EngineServer(engine, cfg, codec=codec)
    port = await srv.start(aot=aot)
    if ready is not None:
        ready(srv, port)
    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    hooked = []
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            loop.add_signal_handler(sig, stop.set)
            hooked.append(sig)
        except (NotImplementedError, RuntimeError):
            pass  # non-main thread / platform without signal support
    serving = asyncio.ensure_future(srv.serve_forever())
    waiter = asyncio.ensure_future(stop.wait())
    tasks = [serving, waiter]
    if stats_interval > 0:
        async def _stats_loop():
            while True:
                await asyncio.sleep(stats_interval)
                print(f"[stats] {srv.stats_line()}", flush=True)

        tasks.append(asyncio.ensure_future(_stats_loop()))
    try:
        await asyncio.wait({serving, waiter},
                           return_when=asyncio.FIRST_COMPLETED)
    except asyncio.CancelledError:
        pass
    finally:
        for task in tasks:
            task.cancel()
        await asyncio.gather(*tasks, return_exceptions=True)
        for sig in hooked:
            loop.remove_signal_handler(sig)
        await srv.close()

"""Radix trie over prompt token ids: shared-prefix reuse for the engine.

Nodes live at PAGE granularity: each non-root node is one
``page_tokens``-token edge, so a node at depth d pins the cache state of
the prefix ``tokens[: d * page_tokens]`` — two resources, one per cache
family class:

  * ``page``     — the attention-pool page id holding that page's K/V
                   rows in every full-attention layer (refcounted by the
                   :class:`~repro.serve.kvpool.KVPool`; the trie holds
                   one reference per pinned node). ``None`` for models
                   with no full-attention layers.
  * ``snapshot`` — a pytree of the RECURRENT cache families' per-slot
                   state at exactly the page boundary: SSM ``(h, conv)``
                   carries, RG-LRU ``(h, conv)`` carries, and windowed-
                   attention ring contents. ``None`` for stateless
                   (pure full-attention) models, where the pages alone
                   reconstruct the prefix.

Admission matches the longest pinned prefix (page-aligned, and capped at
``len(prompt) - 1`` so at least one prompt token always runs through the
model to produce first-token logits), maps the matched page run into the
slot's page table, and restores the deepest matched snapshot — all O(1)
in prefix length, no re-prefill, no K/V copy. Retirement publishes the
finished prompt's complete pages back as new nodes.

Eviction is LRU over LEAF nodes only (an inner node's children address
cache state that extends it, so the path must die bottom-up). Evicting a
node drops the trie's page reference; the page returns to the pool once
no live slot maps it. The node count is capped (``max_nodes``) because
recurrent snapshots hold real device memory, and the engine also evicts
on demand when the page pool runs dry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.serve.kvpool import KVPool


@dataclasses.dataclass(eq=False)
class PrefixNode:
    key: Tuple[int, ...]                 # this node's page_tokens token ids
    parent: Optional["PrefixNode"]
    depth: int                           # pages from root (root = 0)
    page: Optional[int] = None           # attention pool page id
    snapshot: Any = None                 # recurrent-state pytree at boundary
    last_used: int = 0
    children: Dict[Tuple[int, ...], "PrefixNode"] = dataclasses.field(
        default_factory=dict
    )

    def is_leaf(self) -> bool:
        return not self.children


class PrefixTrie:
    def __init__(self, page_tokens: int, pool: Optional[KVPool] = None,
                 max_nodes: int = 512):
        if page_tokens <= 0:
            raise ValueError(f"page_tokens must be positive: {page_tokens}")
        if max_nodes < 1:
            raise ValueError(f"max_nodes must be >= 1: {max_nodes}")
        self.pt = page_tokens
        self.pool = pool
        self.max_nodes = max_nodes
        self.root = PrefixNode(key=(), parent=None, depth=0)
        self._nodes: List[PrefixNode] = []     # every non-root node
        self.evictions = 0
        # admission-lookup outcome counters: a ``match`` that pinned at
        # least one page is a hit. Monotonic — the telemetry registry
        # exposes them as fn-backed counters (serve_prefix_lookups_total)
        # rather than double-counting engine-side. ``probe`` is advisory
        # and deliberately uncounted (it runs per-candidate per-tick).
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._nodes)

    # ------------------------------------------------------------------
    def match(self, tokens, *, require_snapshot: bool = False,
              now: int = 0) -> List[PrefixNode]:
        """Longest pinned page-aligned prefix of ``tokens``, as the node
        path from the shallowest matched page down.

        Capped at ``(len(tokens) - 1) // page_tokens`` pages so a full
        match still leaves >= 1 token to prefill (the logits source for
        the request's first sampled token). With ``require_snapshot`` the
        walk answers with the deepest node that actually HAS a snapshot
        (a republished inner node can lack one) — shallower snapshotless
        nodes on the path are fine, the restore only reads the last."""
        toks = [int(t) for t in tokens]
        n_max = (len(toks) - 1) // self.pt
        node, path = self.root, []
        for i in range(n_max):
            child = node.children.get(tuple(toks[i * self.pt:(i + 1) * self.pt]))
            if child is None:
                break
            path.append(child)
            node = child
        best = len(path) - 1
        while best >= 0 and require_snapshot and path[best].snapshot is None:
            best -= 1
        path = path[: best + 1]
        if path:
            self.hits += 1
        else:
            self.misses += 1
        for n in path:
            n.last_used = now
        return path

    # ------------------------------------------------------------------
    def probe(self, tokens, *, require_snapshot: bool = False) -> int:
        """How many TOKENS of ``tokens`` a ``match`` would serve from the
        trie — WITHOUT pinning: no ``last_used`` touch, no refcount, no
        state change at all. The scheduler's prefix-aware admission
        ordering calls this on every queued candidate every tick; if the
        probe bumped recency, merely *waiting* in the queue would keep a
        prefix warm and starve eviction. Mirrors ``match`` exactly (same
        page cap, same snapshot gating) so the predicted skip equals what
        admission actually gets."""
        toks = [int(t) for t in tokens]
        n_max = (len(toks) - 1) // self.pt
        node, path = self.root, []
        for i in range(n_max):
            child = node.children.get(
                tuple(toks[i * self.pt:(i + 1) * self.pt]))
            if child is None:
                break
            path.append(child)
            node = child
        best = len(path) - 1
        while best >= 0 and require_snapshot and path[best].snapshot is None:
            best -= 1
        return (best + 1) * self.pt

    # ------------------------------------------------------------------
    def insert(self, tokens, pages: Optional[List[int]],
               snapshots: Dict[int, Any], *, now: int = 0) -> int:
        """Publish a finished prompt's complete pages.

        ``tokens`` must be page-aligned (the caller truncates to whole
        pages); ``pages[i]`` is the slot's pool page holding page i (the
        trie RETAINS it — the caller keeps its own reference and releases
        it as usual), ``snapshots[boundary]`` the recurrent-state pytree
        captured when prefill crossed ``boundary`` tokens. Existing nodes
        keep their page (first publisher wins; the newcomer's pages
        simply drop with its slot) but a missing SNAPSHOT is backfilled —
        a node republished after eviction would otherwise stay
        snapshotless forever and permanently cap the stateful match
        depth at its boundary. Returns the number of new nodes."""
        if len(tokens) % self.pt:
            raise ValueError(
                f"insert of {len(tokens)} tokens is not page-aligned "
                f"(page_tokens={self.pt})"
            )
        toks = [int(t) for t in tokens]
        node, created = self.root, 0
        protect = set()
        for i in range(len(toks) // self.pt):
            key = tuple(toks[i * self.pt:(i + 1) * self.pt])
            child = node.children.get(key)
            if child is None:
                if len(self._nodes) >= self.max_nodes and not self.evict_one(
                    exclude=protect | {node}
                ):
                    break                      # cap hit, nothing evictable
                child = PrefixNode(
                    key=key, parent=node, depth=node.depth + 1,
                    page=pages[i] if pages else None,
                    snapshot=snapshots.get((i + 1) * self.pt),
                    last_used=now,
                )
                if child.page is not None:
                    self.pool.retain(child.page)
                node.children[key] = child
                self._nodes.append(child)
                created += 1
            else:
                child.last_used = now
                if child.snapshot is None:
                    child.snapshot = snapshots.get((i + 1) * self.pt)
            protect.add(child)
            node = child
        return created

    # ------------------------------------------------------------------
    def evict_one(self, exclude=()) -> bool:
        """Detach the least-recently-used LEAF (bottom-up death), release
        its page reference and drop its snapshot. Returns False when no
        leaf is evictable."""
        victim = None
        for n in self._nodes:
            if n.children or n in exclude:
                continue
            if victim is None or n.last_used < victim.last_used:
                victim = n
        if victim is None:
            return False
        victim.parent.children.pop(victim.key)
        self._nodes.remove(victim)
        if victim.page is not None:
            self.pool.release(victim.page)
        victim.snapshot = None
        self.evictions += 1
        return True

    def clear(self) -> None:
        while self.evict_one():
            pass

    def held_pages(self) -> List[int]:
        return [n.page for n in self._nodes if n.page is not None]


# ---------------------------------------------------------------------------
# Encoder-output reuse (encoder-decoder serving)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(eq=False)
class _EncEntry:
    pages: List[int]            # cross-pool page run holding the K/V
    enc_len: int                # valid memory rows (mask bound at decode)
    last_used: int = 0


class EncoderCache:
    """Digest-keyed cache of encoded sources in CROSS-POOL pages.

    The token-keyed :class:`PrefixTrie` cannot serve encoder-decoder
    models — decoder self-attention K/V depends on the cross-attended
    encoder memory, so a prompt prefix computed against one source is
    WRONG for another (DESIGN.md §6.5). What IS reusable is the encoder
    output itself: two requests over the same source (same frame bytes,
    keyed by digest) share the cross-attention pages verbatim, because
    those pages are read-only after the ENCODE phase and independent of
    the decoder prompt. A hit maps the whole page run into the admitted
    slot's cross page table and skips its ENCODE phase entirely.

    Same refcount discipline as the trie: the cache holds one pool
    reference per page per entry; a mapped slot holds its own; pages
    free when the last drops. Eviction is LRU whole-entry (the run is
    only useful complete)."""

    def __init__(self, pool: KVPool, max_entries: int = 128):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1: {max_entries}")
        self.pool = pool
        self.max_entries = max_entries
        self._entries: Dict[bytes, _EncEntry] = {}
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, digest: bytes) -> bool:
        return digest in self._entries

    def get(self, digest: bytes, *, now: int = 0) -> Optional[_EncEntry]:
        """Hit -> the entry with every page RETAINED for the caller (who
        releases them at slot teardown, like any mapped page)."""
        e = self._entries.get(digest)
        if e is None:
            return None
        for p in e.pages:
            self.pool.retain(p)
        e.last_used = now
        return e

    def put(self, digest: bytes, pages: List[int], enc_len: int, *,
            now: int = 0) -> bool:
        """Publish a finished encode's page run (first publisher wins,
        like trie nodes). Retains every page; evicts LRU past the cap."""
        if digest in self._entries:
            self._entries[digest].last_used = now
            return False
        while len(self._entries) >= self.max_entries:
            if not self.evict_one():
                return False
        for p in pages:
            self.pool.retain(p)
        self._entries[digest] = _EncEntry(list(pages), enc_len, now)
        return True

    def evict_one(self, exclude=()) -> bool:
        victim = None
        for d, e in self._entries.items():
            if d in exclude:
                continue
            if victim is None or e.last_used < self._entries[victim].last_used:
                victim = d
        if victim is None:
            return False
        e = self._entries.pop(victim)
        for p in e.pages:
            self.pool.release(p)
        self.evictions += 1
        return True

    def clear(self) -> None:
        while self.evict_one():
            pass

    def held_pages(self) -> List[int]:
        return [p for e in self._entries.values() for p in e.pages]

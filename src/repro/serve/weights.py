"""Convert TRAIN-mode masters into the shipped SERVE representation.

TRAIN params hold full-precision masters (W [, A]); SERVE params hold what
the paper actually stores after training (Section 3, "After training is
complete"):

    tiled layer   -> packed tile bits (q bits in int32 lanes) + alpha(s)
    BWNN layer    -> row-packed sign bits + one alpha
    fp32 layer    -> weights cast to the serving compute dtype

The converter pairs the two spec trees of the *same* architecture built in
TRAIN and SERVE mode and dispatches on the serve node's keys, so it works
for Dense, stacked (scan-over-layers) Dense, and (L, E, ...) MoE expert
banks without any per-model code.

This is also the elastic-rejoin broadcast payload (DESIGN.md §5): packed
tiles are ~32*p smaller than fp32 masters, so re-seeding a repaired node
with serving weights costs ~1/128th the bytes at p=4.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import pack_bits, pack_conv_tile, packed_len
from repro.core.policy import TBNPolicy
from repro.core.tiling import (
    TileSpec,
    compute_alpha,
    plan_conv_tiling,
    plan_tiling,
    tile_vector,
)
from repro.nn import module as mod


def _derive_layer_spec(policy: TBNPolicy, layer_shape: Tuple[int, ...]):
    """Re-derive a layer's TileSpec from the policy (single source for every
    export branch, so a new policy field threads through exactly once)."""
    return plan_tiling(
        layer_shape,
        p=policy.p,
        min_size=policy.min_size,
        alpha_mode=policy.alpha_mode,
        alpha_source=policy.alpha_source,
        ste=policy.ste,
        require_aligned=policy.require_aligned,
    )


def _derive_spec(
    policy: TBNPolicy, layer_shape: Tuple[int, ...], tile_packed: int,
    n_alpha: int,
) -> TileSpec:
    """TileSpec for a flat-tile layer; cross-check vs the serve decl."""
    spec = _derive_layer_spec(policy, layer_shape)
    if spec is None:
        raise ValueError(f"policy does not tile layer of shape {layer_shape}")
    if packed_len(spec.q) != tile_packed or spec.n_alpha != n_alpha:
        raise ValueError(
            f"derived spec (q={spec.q}, n_alpha={spec.n_alpha}) does not match "
            f"serve decl (packed={tile_packed}, n_alpha={n_alpha}) "
            f"for shape {layer_shape}"
        )
    return spec


def _tile_and_alpha(w, a, spec: TileSpec):
    """The shipped (t ±1 (q,), alpha (n_alpha,)) — shared by every layout."""
    t = tile_vector(w.astype(jnp.float32), spec)
    src = a if (spec.alpha_source == "A" and a is not None) else w
    return t, compute_alpha(src.astype(jnp.float32), spec)


def _export_tiled(w, a, spec: TileSpec):
    """(packed int32 (ceil(q/32),), alpha (n_alpha,)) for one layer."""
    t, alpha = _tile_and_alpha(w, a, spec)
    return pack_bits(t), alpha


def _export_tiled_rows(w, a, spec: TileSpec):
    """Row-packed shipped form: (r, ceil(n_in/32)) int32 + alpha (n_alpha,).

    Same tile bits as ``_export_tiled`` laid out one word-padded packed row
    per unique weight row, so the leading axis is directly shardable over
    the tensor-parallel mesh axis ("tile_rows") and the matmul kernel
    streams a (block_r, block_k/32) block without crossing rows.
    """
    t, alpha = _tile_and_alpha(w, a, spec)
    r = spec.rows_per_tile
    n_in = spec.n // spec.shape[0]
    return pack_bits(t.reshape(r, n_in)), alpha


def _export_conv_tiled(w, a, spec: TileSpec):
    """Conv-layout packed tile (kh*kw, r, ceil(c_in/32)) + alpha.

    Same tile bits as ``_export_tiled``, laid out per kernel position so the
    fused im2col kernel (repro.kernels.tiled_conv) streams them directly —
    the serving host never re-shuffles, and the dense OIHW weight never
    exists on the serving path.
    """
    plan = plan_conv_tiling(spec)
    t, alpha = _tile_and_alpha(w, a, spec)
    kh, kw = plan.kernel
    return pack_conv_tile(t, plan.r, plan.c_in, kh, kw), alpha


def _export_bwnn(w):
    """Row-packed sign bits + single alpha for one weight tensor.

    Rows are the leading dim; trailing dims flatten into the packed axis
    (dense (n_out, n_in) rows and conv (c_out, c_in*kh*kw) filters alike).
    """
    alpha = jnp.mean(jnp.abs(w.astype(jnp.float32))).reshape(1)
    rows = jnp.where(w > 0, 1.0, -1.0).reshape(w.shape[0], -1)
    return pack_bits(rows), alpha


def _vmap_n(fn, n_lead: int):
    for _ in range(n_lead):
        fn = jax.vmap(fn)
    return fn


def export_serving_params(
    train_specs: mod.SpecTree,
    serve_specs: mod.SpecTree,
    train_params: Dict,
    policy: TBNPolicy,
) -> Dict:
    """Walk the two spec trees; emit the SERVE param tree from masters."""

    def convert(tr_spec, sv_spec, tr_par):
        if not isinstance(sv_spec, dict):
            raise TypeError(f"unexpected serve spec node {type(sv_spec)}")
        keys = set(sv_spec)
        if "tile_conv" in keys:  # tiled Conv2D (conv-layout packed tile)
            tile_decl: mod.ParamSpec = sv_spec["tile_conv"]
            alpha_decl: mod.ParamSpec = sv_spec["alpha"]
            w = tr_par["w"]
            a = tr_par.get("a")
            n_lead = len(tile_decl.shape) - 3
            layer_shape = tuple(w.shape[n_lead:])
            spec = _derive_layer_spec(policy, layer_shape)
            plan = plan_conv_tiling(spec)
            if plan is None or plan.packed_shape() != tile_decl.shape[n_lead:] \
                    or spec.n_alpha != alpha_decl.shape[-1]:
                raise ValueError(
                    f"derived conv plan does not match serve decl "
                    f"{tile_decl.shape} for shape {layer_shape}"
                )
            fn = _vmap_n(lambda we, ae: _export_conv_tiled(we, ae, spec), n_lead)
            tile, alpha = fn(w, w if a is None else a)
            out = {"tile_conv": tile, "alpha": alpha}
            if "b" in keys:
                out["b"] = tr_par["b"].astype(sv_spec["b"].dtype)
            return out
        if "tile" in keys:  # TBN layer (possibly stacked / expert bank)
            tile_decl: mod.ParamSpec = sv_spec["tile"]
            alpha_decl: mod.ParamSpec = sv_spec["alpha"]
            w = tr_par["w"]
            a = tr_par.get("a")
            # Layout dispatch: a row-packed decl is (*lead, r, words) over a
            # 2-D (n_out, n_in) layer; anything else is the flat q-bit form
            # (unaligned tilings, and 4-D conv fallbacks).
            exported = None
            if len(tile_decl.shape) >= 2:
                n_lead = len(tile_decl.shape) - 2
                layer_shape = tuple(w.shape[n_lead:])
                if len(layer_shape) == 2:
                    spec = _derive_layer_spec(policy, layer_shape)
                    if spec is not None and spec.aligned_rows:
                        rows = (spec.rows_per_tile, packed_len(layer_shape[1]))
                        if rows == tile_decl.shape[n_lead:] \
                                and spec.n_alpha == alpha_decl.shape[-1]:
                            fn = _vmap_n(
                                lambda we, ae: _export_tiled_rows(we, ae, spec),
                                n_lead,
                            )
                            exported = fn(w, w if a is None else a)
            if exported is None:
                n_lead = len(tile_decl.shape) - 1
                layer_shape = tuple(w.shape[n_lead:])
                spec = _derive_spec(
                    policy, layer_shape, tile_decl.shape[-1],
                    alpha_decl.shape[-1],
                )
                fn = _vmap_n(lambda we, ae: _export_tiled(we, ae, spec), n_lead)
                exported = fn(w, w if a is None else a)
            tile, alpha = exported
            out = {"tile": tile, "alpha": alpha}
            if "b" in keys:
                out["b"] = tr_par["b"].astype(sv_spec["b"].dtype)
            return out
        if "wbits" in keys:  # BWNN layer
            wb_decl: mod.ParamSpec = sv_spec["wbits"]
            w = tr_par["w"]
            n_lead = len(wb_decl.shape) - 2
            fn = _vmap_n(_export_bwnn, n_lead)
            bits, alpha = fn(w)
            out = {"wbits": bits, "alpha": alpha.reshape(alpha.shape[:n_lead] + (1,))
                   if n_lead else alpha}
            if "b" in keys:
                out["b"] = tr_par["b"].astype(sv_spec["b"].dtype)
            return out
        if isinstance(sv_spec.get("w"), mod.ParamSpec) or any(
            isinstance(v, mod.ParamSpec) for v in sv_spec.values()
        ):
            # leaf layer kept dense (fp32/below-lambda) or norm/embed node
            out = {}
            for k, decl in sv_spec.items():
                if isinstance(decl, mod.ParamSpec):
                    out[k] = tr_par[k].astype(decl.dtype)
                else:
                    out[k] = convert(tr_spec[k], decl, tr_par[k])
            return out
        return {
            k: convert(tr_spec[k], sv_spec[k], tr_par[k]) for k in sv_spec
        }

    return convert(train_specs, serve_specs, train_params)


def serving_bytes(params) -> int:
    """Exact bytes of a (serve-form) param tree."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(params)
    )


def _tile_leaves(params):
    """(path-key, leaf) for every packed-tile leaf (``tile``/``tile_conv``)."""
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        keys = [str(getattr(p, "key", p)) for p in path]
        if keys and keys[-1] in ("tile", "tile_conv"):
            yield "/".join(keys), leaf


def tile_serving_bytes(params) -> int:
    """Bytes of the packed tile bits alone (the 1/TP-scaling share)."""
    return sum(
        int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        for _, leaf in _tile_leaves(params)
    )


def per_device_tile_bytes(params) -> Dict[str, int]:
    """device -> bytes of packed tile bits RESIDENT on that device.

    For a mesh-placed param tree this is what each chip's HBM actually
    holds: sharded tiles count 1/TP of their bytes per device, replicated
    leaves count fully on every device. Unplaced (single-device) trees
    report one entry. The tensor-parallel acceptance check is that the
    per-device number scales as 1/TP of ``tile_serving_bytes``.
    """
    out: Dict[str, int] = {}
    for _, leaf in _tile_leaves(params):
        shards = getattr(leaf, "addressable_shards", None)
        if not shards:
            dev = str(getattr(leaf, "device", "host"))
            out[dev] = out.get(dev, 0) + leaf.nbytes
            continue
        for sh in shards:
            dev = str(sh.device)
            out[dev] = out.get(dev, 0) + int(np.prod(sh.data.shape)) \
                * jnp.dtype(sh.data.dtype).itemsize
    return out

"""ServableModel: the explicit model <-> engine serving contract.

Historically :class:`~repro.serve.engine.BatchedEngine` grew against one
concrete model class (``DecoderLM``) and the contract between them lived
implicitly in the engine's attribute accesses. This module names it, so a
second model family (the encoder-decoder backbone, the MoE decoder) can
plug into the SAME engine — same scheduler, same paged pool, same
preempt-and-resume — by implementing the protocol instead of by growing
``isinstance`` branches inside the tick loop.

The contract has three parts (DESIGN.md §6.5):

* **probes** — ``has_full_attn`` / ``has_recurrent_state`` /
  ``has_cross_attn`` booleans the engine reads ONCE at construction to
  decide which host-side machinery to stand up (attention page pool,
  boundary snapshots, cross-attention pool + ENCODE phase).
* **cache families** — ``cache_families()`` returns
  :class:`CacheFamily` descriptors declaring how each family of decode
  state is stored (paged pool vs per-slot rows) and whether decode may
  write it (cross-attention K/V is read-only after the encode phase).
  The engine surfaces these per family in ``stats()``.
* **tick methods** — ``init_caches`` / ``prefill`` / ``decode_step`` /
  ``extend`` plus the per-slot walkers (``merge_caches``,
  ``reset_slot_caches``, ``snapshot_slot_caches``,
  ``restore_slot_caches``). The jitted tick functions call ONLY these;
  a model that implements them with fixed shapes serves unchanged under
  chunked prefill, paged attention, prefix reuse, and preemption.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

try:                                   # 3.8+: typing.Protocol
    from typing import Protocol
except ImportError:                    # pragma: no cover
    Protocol = object

# Model families the serving stack can drive, with the engine path each
# takes. The launch CLI prints this matrix in --help; UnservableModelError
# lists the keys so an unsupported config fails with the menu attached.
SERVABLE_FAMILIES = {
    "dense": "DecoderLM; full-attention KV in the paged pool",
    "moe": "DecoderLM; expert tiles (E, r, words), drop-free serve dispatch",
    "ssm": "DecoderLM; per-slot (h, conv) state, boundary snapshots",
    "hybrid": "DecoderLM; pattern blocks mix paged KV + recurrent state",
    "vlm": "DecoderLM; early-fusion image embeddings, paged KV",
    "encdec": "EncDecModel; ENCODE phase + read-only cross-attention pool",
}

# The attribute surface the engine touches. ``ensure_servable`` checks
# presence, not signatures — the parity walls check semantics.
REQUIRED_ATTRS: Tuple[str, ...] = (
    "has_full_attn",
    "has_recurrent_state",
    "has_cross_attn",
    "cache_families",
    "init_caches",
    "prefill",
    "decode_step",
    "extend",
    "merge_caches",
    "reset_slot_caches",
    "snapshot_slot_caches",
    "restore_slot_caches",
)


@dataclasses.dataclass(frozen=True)
class CacheFamily:
    """How one family of decode-cache state is stored and written.

    ``paged`` families live in a shared page pool addressed through
    per-slot page-table rows (zero per-slot dense tensors); non-paged
    families are per-slot rows that snapshot/restore at boundaries.
    ``read_only`` families are written exactly once (the encode phase)
    and only read by decode/extend — preemption retains their pages but
    never re-snapshots them."""

    name: str                  # "self_attn" | "cross_attn" | "recurrent"
    paged: bool
    read_only: bool = False


class UnservableModelError(TypeError):
    """A model (or config family) the engine cannot drive. Carries the
    menu of servable families so the CLI/server error message tells the
    operator what WOULD work, not just what didn't."""

    def __init__(self, what: str, missing: Tuple[str, ...] = ()):
        menu = "; ".join(f"{k}: {v}" for k, v in SERVABLE_FAMILIES.items())
        detail = (
            f" (missing: {', '.join(missing)})" if missing else ""
        )
        super().__init__(
            f"{what} does not satisfy the ServableModel contract{detail}. "
            f"Servable families — {menu}"
        )
        self.missing = missing


class ServableModel(Protocol):
    """Typing surface of the contract (documentation + static checking;
    the runtime check is :func:`ensure_servable`)."""

    has_full_attn: bool
    has_recurrent_state: bool
    has_cross_attn: bool

    def cache_families(self) -> Tuple[CacheFamily, ...]: ...
    def init_caches(self, batch, max_len, dtype, *, page_tokens=None,
                    n_pages=None, **kw): ...
    def prefill(self, params, batch, max_len): ...
    def decode_step(self, params, tokens, caches, lengths, **kw): ...
    def extend(self, params, tokens, caches, lengths, n_new, **kw): ...
    def merge_caches(self, old, new, keep, paged=False): ...
    def reset_slot_caches(self, caches, slot, paged=False): ...
    def snapshot_slot_caches(self, caches, slot): ...
    def restore_slot_caches(self, caches, slot, snaps): ...


def ensure_servable(model) -> object:
    """Raise :class:`UnservableModelError` (listing what's missing AND
    the servable-family menu) unless ``model`` exposes the full contract;
    returns the model so engine constructors can check inline."""
    missing = tuple(a for a in REQUIRED_ATTRS if not hasattr(model, a))
    if missing:
        raise UnservableModelError(type(model).__name__, missing)
    return model

"""Jit-ready wrappers around the TBN Pallas kernels.

Public entry points:
  * ``tiled_dense_infer``  — serving-time FC layer from (packed tile, alpha)
    without materializing the dense weight. Pallas on TPU; pure-JAX
    structured math elsewhere (identical FLOPs — used by the SPMD dry-run).
    Small batches (m <= MATVEC_MAX_M, i.e. decode ticks) dispatch to the
    decode-blocked ``tiled_matvec_unique`` kernel instead of the 128-row
    matmul blocking. Under an active mesh whose rules map ``tile_rows`` to
    a >1 axis (distributed/sharding.py) the row-packed tile is
    tensor-parallel: a shard_map runs the same kernel per shard on r/TP
    unique rows (the decode dispatch applies per shard too) and the
    output stays sharded on the tile-row axis (DESIGN.md §5).
  * ``tiled_conv_infer``   — serving-time Conv2D from a conv-layout packed
    tile: fused im2col + tile-reuse matmul on TPU (the dense OIHW weight
    never exists); elsewhere the structured fallback runs the p-fold
    smaller tile bank through ``conv_general_dilated``. Same shard_map
    tensor-parallel path over the tile's unique filters.
  * ``tile_construct``     — (W[,A]) -> (packed tile, alpha) fused on TPU.
  * ``tbn_dense_train``    — training forward y = x @ B_hat^T that composes
    the two kernels (B_hat never hits HBM) with a custom VJP whose backward
    is the *paper-faithful* gradient (vjp of the pure-JAX reference), so the
    fused path is a drop-in for the reference during training.

Tile layouts accepted by ``tiled_dense_infer``:
  * flat  (ceil(q/32),) int32 — legacy/fused-train form; requires 32 | n_in
    on the Pallas path and never engages tensor parallelism.
  * rows  (r, ceil(n_in/32)) int32 — the shipped serve form: one packed
    word-padded row per unique weight row, shardable on its leading axis.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.packing import pack_bits, unpack_bits, unpack_conv_tile
from repro.core.tiling import (
    TileSpec,
    compute_alpha,
    plan_conv_tiling,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)
from repro.distributed.sharding import batch_shard_axes, tile_sharding
from repro.kernels.tile_construct import tile_construct_pallas
from repro.kernels.tiled_conv import tiled_conv_unique
from repro.kernels.tiled_matmul import tiled_matmul_unique
from repro.kernels.tiled_matvec import (
    DECODE_BLOCK_K,
    DECODE_BLOCK_R,
    MATVEC_MAX_M,
    sublane_rounded,
    tiled_matvec_unique,
)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------
# Inference matmul
# --------------------------------------------------------------------------
def _dense_unique_local(
    xm: jax.Array,
    packed_rows: jax.Array,
    *,
    n_in: int,
    use_pallas: bool,
    block_m: int,
    block_r: int,
    block_k: int,
) -> jax.Array:
    """u = x @ T^T against a row-packed tile slice.

    xm (m, n_in); packed_rows (r_loc, words) int32 with words*32 >= n_in
    (rows pad to whole words: pad bits unpack to -1 but only ever multiply
    zero-padded activation columns). Runs unchanged per shard under the
    tensor-parallel wrapper — r_loc is then r/TP.
    """
    m = xm.shape[0]
    r_loc, words = packed_rows.shape
    if not use_pallas:
        tm = unpack_bits(packed_rows, n_in, dtype=xm.dtype)  # (r_loc, n_in)
        return jnp.einsum("mk,rk->mr", xm, tm)
    xp = jnp.pad(xm, ((0, 0), (0, words * 32 - n_in)))
    if m <= MATVEC_MAX_M:
        # Decode fast path: m is the whole (sublane-rounded) batch, so the
        # matmul kernel's 128-row m blocks would be mostly zero padding.
        # The matvec variant takes the batch as ONE m block and widens the
        # r/k blocking to keep the unpack-dominant regime fed.
        br = min(DECODE_BLOCK_R, r_loc)
        bk = min(DECODE_BLOCK_K, words * 32)
        xp = _pad_to(_pad_to(xp, 0, sublane_rounded(m, xp.dtype)), 1, bk)
        tm_p = _pad_to(_pad_to(packed_rows, 0, br), 1, bk // 32)
        return tiled_matvec_unique(
            xp, tm_p, r=tm_p.shape[0], block_r=br, block_k=bk,
        )[:m, :r_loc]
    xp = _pad_to(_pad_to(xp, 0, block_m), 1, block_k)
    tm_p = _pad_to(_pad_to(packed_rows, 0, block_r), 1, block_k // 32)
    return tiled_matmul_unique(
        xp,
        tm_p,
        r=tm_p.shape[0],
        block_m=block_m,
        block_r=block_r,
        block_k=block_k,
    )[:m, :r_loc]


def _replicate_dense_out(u: jax.Array, alpha: jax.Array, spec: TileSpec):
    """u (m, r_loc) -> y (m, p, r_loc): the tile-replica broadcast-scale."""
    m, r_loc = u.shape
    alpha = alpha.astype(u.dtype)
    if spec.alpha_mode == "layer":
        return jnp.broadcast_to(u[:, None, :], (m, spec.p, r_loc)) \
            * alpha.reshape(1)
    return jnp.broadcast_to(u[:, None, :] * alpha[None, :, None],
                            (m, spec.p, r_loc))


def tiled_dense_infer(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    spec: TileSpec,
    *,
    use_pallas: Optional[bool] = None,
    block_m: int = 128,
    block_r: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """y = x @ W_hat^T from the shipped representation.

    x: (..., n_in); packed: int32, flat (ceil(q/32),) or row-packed
    (r, ceil(n_in/32)) — see module docstring; alpha: (n_alpha,).
    Weight logical shape spec.shape == (n_out, n_in), aligned tiling.

    Row-packed tiles are tensor-parallel under an active mesh: the tile
    rows shard over the ``tile_rows`` axis, each shard runs the same
    kernel on r/TP rows, and the (m, p, r) output stays sharded on its
    unique-row axis until the caller's reshape (DESIGN.md §5).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    r = spec.rows_per_tile
    lead = x.shape[:-1]
    xm = x.reshape(-1, n_in)
    m = xm.shape[0]

    row_form = packed.ndim == 2
    if not row_form:
        if not use_pallas:
            t = unpack_bits(packed, spec.q, dtype=x.dtype)
            y = tiled_matmul_reference(xm, t, alpha, spec)
            return y.reshape(*lead, n_out).astype(x.dtype)
        packed = packed.reshape(r, n_in // 32)  # flat form: needs 32 | n_in

    local = functools.partial(
        _dense_unique_local, n_in=n_in, use_pallas=use_pallas,
        block_m=block_m, block_r=block_r, block_k=block_k,
    )
    tp = tile_sharding(r) if row_form else None
    if tp is not None:
        mesh, ax, _ = tp
        m_ax = batch_shard_axes(ax, m) or None
        y3 = shard_map(
            lambda xl, pl_, al: _replicate_dense_out(local(xl, pl_), al, spec),
            mesh=mesh,
            in_specs=(P(m_ax, None), P(ax, None), P()),
            out_specs=P(m_ax, None, ax),
            check_vma=False,
        )(xm, packed, alpha)
    else:
        y3 = _replicate_dense_out(local(xm, packed), alpha, spec)
    return y3.reshape(*lead, n_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Inference conv
# --------------------------------------------------------------------------
Padding = Union[str, Sequence[Tuple[int, int]]]


def _conv_spatial(size: int, k: int, s: int, pad) -> Tuple[int, int, int]:
    """(out_size, pad_lo, pad_hi) with conv_general_dilated semantics."""
    if pad in ("SAME", "SAME_LOWER"):
        out = -(-size // s)
        total = max((out - 1) * s + k - size, 0)
        half = total // 2
        lo = half if pad == "SAME" else total - half
        return out, lo, total - lo
    if pad == "VALID":
        lo = hi = 0
    elif isinstance(pad, str):
        raise ValueError(f"unsupported padding {pad!r} for tiled conv")
    else:
        lo, hi = pad
    return (size + lo + hi - k) // s + 1, lo, hi


def resolve_conv_padding(
    hw: Tuple[int, int], kernel: Tuple[int, int], stride: Tuple[int, int],
    padding: Padding,
) -> Tuple[Tuple[int, int], Tuple[Tuple[int, int], Tuple[int, int]]]:
    """-> ((OH, OW), explicit ((lo_h, hi_h), (lo_w, hi_w)))."""
    pads = (padding, padding) if isinstance(padding, str) else tuple(padding)
    oh, lo_h, hi_h = _conv_spatial(hw[0], kernel[0], stride[0], pads[0])
    ow, lo_w, hi_w = _conv_spatial(hw[1], kernel[1], stride[1], pads[1])
    return (oh, ow), ((lo_h, hi_h), (lo_w, hi_w))


def _replicate_conv_out(u, alpha, spec: TileSpec):
    """u (N, OH, OW, r_loc) -> y (N, OH, OW, p, r_loc), replica-major.

    Kept unflattened so the tensor-parallel wrapper can declare the
    unique-filter axis sharded; callers reshape to (N, OH, OW, p*r)."""
    n, oh, ow, r_loc = u.shape
    alpha = alpha.astype(u.dtype)
    if spec.alpha_mode == "layer":
        return jnp.broadcast_to(u[..., None, :], (n, oh, ow, spec.p, r_loc)) \
            * alpha.reshape(1)
    return jnp.broadcast_to(
        u[..., None, :] * alpha[None, None, None, :, None],
        (n, oh, ow, spec.p, r_loc),
    )


def tiled_conv_infer(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    spec: TileSpec,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: Padding = "SAME",
    use_pallas: Optional[bool] = None,
    block_r: int = 128,
) -> jax.Array:
    """y = conv(x, W_hat) from the shipped conv representation.

    x: (N, H, W, C) NHWC; packed: (kh*kw, r, ceil(C/32)) int32 conv-layout
    tile (repro.core.packing.pack_conv_tile); alpha: (n_alpha,). The weight
    logical shape spec.shape == (c_out, C, kh, kw) with p | c_out.

    The dense weight is never materialized on either path: the conv runs
    against the r = c_out/p unique filters of the tile and the p replicas
    are a broadcast-scale on the output channels (exact conv analogue of
    ``tiled_matmul_reference`` — validated against
    ``kernels.ref.tiled_conv_ref``).
    """
    plan = plan_conv_tiling(spec)
    if plan is None:
        raise ValueError(f"spec {spec.shape} has no aligned conv tiling")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    kh, kw = plan.kernel
    sh, sw = stride
    n, h, w, c = x.shape
    assert c == plan.c_in, (c, plan.c_in)
    r = plan.r
    (oh, ow), pads = resolve_conv_padding((h, w), (kh, kw), stride, padding)

    if use_pallas:
        # Pallas path: pad spatially so every kernel read is in bounds
        # (Hp >= (OH-1)*sh + kh, Wp >= kw-1 + OW*sw), channels to whole
        # int32 lanes (zero activations x any tile bit contribute nothing);
        # the filter axis pads to block_r multiples per shard below.
        hp = max(h + pads[0][0] + pads[0][1], (oh - 1) * sh + kh)
        wp = max(w + pads[1][0] + pads[1][1], (kw - 1) + ow * sw)
        cpad = (-c) % 32
        xin = jnp.pad(
            x,
            (
                (0, 0),
                (pads[0][0], hp - h - pads[0][0]),
                (pads[1][0], wp - w - pads[1][0]),
                (0, cpad),
            ),
        )
    else:
        xin = x

    def local_u(x_l, packed_l):
        """u = conv(x, T_loc) against a conv-layout tile slice of r_loc
        unique filters (r_loc = r/TP under the tensor-parallel wrapper)."""
        r_loc = packed_l.shape[1]
        if not use_pallas:
            bank = unpack_conv_tile(packed_l, r_loc, c, kh, kw, dtype=x.dtype)
            return jax.lax.conv_general_dilated(
                x_l, bank, window_strides=stride, padding=pads,
                dimension_numbers=("NHWC", "OIHW", "NHWC"),
            )
        br = min(block_r, r_loc)
        packed_p = jnp.pad(packed_l, ((0, 0), (0, (-r_loc) % br), (0, 0)))
        return tiled_conv_unique(
            x_l,
            packed_p,
            kernel=(kh, kw),
            stride=stride,
            out_hw=(oh, ow),
            block_r=br,
        )[..., :r_loc]

    tp = tile_sharding(r)
    if tp is not None:
        mesh, ax, _ = tp
        n_ax = batch_shard_axes(ax, n) or None
        y5 = shard_map(
            lambda xl, pl_, al: _replicate_conv_out(local_u(xl, pl_), al, spec),
            mesh=mesh,
            in_specs=(P(n_ax, None, None, None), P(None, ax, None), P()),
            out_specs=P(n_ax, None, None, None, ax),
            check_vma=False,
        )(xin, packed, alpha)
    else:
        y5 = _replicate_conv_out(local_u(xin, packed), alpha, spec)
    return y5.reshape(n, oh, ow, spec.p * r).astype(x.dtype)


# --------------------------------------------------------------------------
# Construction
# --------------------------------------------------------------------------
def tile_construct(
    w: jax.Array,
    spec: TileSpec,
    a: Optional[jax.Array] = None,
    *,
    use_pallas: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Master weight(s) -> (packed tile int32, alpha (n_alpha,))."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    src = a if spec.alpha_source == "A" else None
    if not use_pallas:
        t = tile_vector(w, spec)
        alpha = compute_alpha(w if src is None else src, spec)
        return pack_bits(t), alpha.astype(jnp.float32)

    w2d = _pad_to(w.reshape(spec.p, spec.q), 1, 32)
    a2d = None if src is None else _pad_to(src.reshape(spec.p, spec.q), 1, 32)
    q_pad = w2d.shape[1]
    # pick a block that divides the padded q
    block_q = min(4096, q_pad)
    while q_pad % block_q:
        block_q -= 32
    packed, alpha_t = tile_construct_pallas(w2d, a2d, block_q=block_q)
    alpha_t = alpha_t * (q_pad / spec.q)  # kernel divides by padded q
    n_words = (spec.q + 31) // 32
    packed = packed[:n_words]
    if spec.alpha_mode == "layer":
        alpha = jnp.mean(alpha_t, keepdims=True)
    else:
        alpha = alpha_t
    return packed, alpha.astype(jnp.float32)


# --------------------------------------------------------------------------
# Fused training forward (custom VJP)
# --------------------------------------------------------------------------
def _train_ref_forward(x, w, a, spec: TileSpec):
    """Paper-faithful reference: materialize B_hat, dense matmul."""
    bhat = tiled_weight(w, spec, a=a, dtype=x.dtype)
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    return jnp.einsum("...k,ok->...o", x, bhat.reshape(n_out, n_in))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def tbn_dense_train(x, w, a, spec: TileSpec):
    """Training forward via the fused kernels; gradient == reference VJP.

    ``a`` may equal ``w`` (alpha_source == "W"); pass the same array.
    """
    packed, alpha = tile_construct(w, spec, a=a)
    return tiled_dense_infer(x, packed, alpha, spec).astype(x.dtype)


def _tbn_dense_train_fwd(x, w, a, spec):
    y = tbn_dense_train(x, w, a, spec)
    return y, (x, w, a)


def _tbn_dense_train_bwd(spec, res, g):
    x, w, a = res
    # Backward is the exact VJP of the paper-faithful reference forward —
    # recomputes B_hat once (remat) instead of storing it.
    _, vjp = jax.vjp(lambda x, w, a: _train_ref_forward(x, w, a, spec), x, w, a)
    return vjp(g)


tbn_dense_train.defvjp(_tbn_dense_train_fwd, _tbn_dense_train_bwd)

"""Jit-ready wrappers around the TBN Pallas kernels.

Public entry points:
  * ``tiled_dense_infer``  — serving-time FC layer from (packed tile, alpha)
    without materializing the dense weight. Pallas on TPU; pure-JAX
    structured math elsewhere (identical FLOPs — used by the SPMD dry-run).
  * ``tile_construct``     — (W[,A]) -> (packed tile, alpha) fused on TPU.
  * ``tbn_dense_train``    — training forward y = x @ B_hat^T that composes
    the two kernels (B_hat never hits HBM) with a custom VJP whose backward
    is the *paper-faithful* gradient (vjp of the pure-JAX reference), so the
    fused path is a drop-in for the reference during training.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import pack_bits, unpack_bits
from repro.core.tiling import (
    TileSpec,
    compute_alpha,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)
from repro.kernels.tile_construct import tile_construct_pallas
from repro.kernels.tiled_matmul import tiled_matmul_unique


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------
# Inference matmul
# --------------------------------------------------------------------------
def tiled_dense_infer(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    spec: TileSpec,
    *,
    use_pallas: Optional[bool] = None,
    block_m: int = 128,
    block_r: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """y = x @ W_hat^T from the shipped representation.

    x: (..., n_in); packed: int32 (ceil(q/32),); alpha: (n_alpha,).
    Weight logical shape spec.shape == (n_out, n_in), aligned tiling.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    r = spec.rows_per_tile
    lead = x.shape[:-1]
    xm = x.reshape(-1, n_in)
    m = xm.shape[0]

    if not use_pallas:
        t = unpack_bits(packed, spec.q, dtype=x.dtype)
        y = tiled_matmul_reference(xm, t, alpha, spec)
        return y.reshape(*lead, n_out).astype(x.dtype)

    # Pallas path: row-pack the tile as (r, n_in/32) and pad to blocks.
    tm_packed = packed.reshape(r, n_in // 32)
    xm_p = _pad_to(_pad_to(xm, 0, block_m), 1, block_k)
    tm_p = _pad_to(_pad_to(tm_packed, 0, block_r), 1, block_k // 32)
    u = tiled_matmul_unique(
        xm_p,
        tm_p,
        r=tm_p.shape[0],
        block_m=block_m,
        block_r=block_r,
        block_k=block_k,
    )[:m, :r]
    if spec.alpha_mode == "layer":
        y = jnp.broadcast_to(u[:, None, :], (m, spec.p, r)) * alpha.reshape(1)
    else:
        y = jnp.broadcast_to(
            u[:, None, :] * alpha[None, :, None], (m, spec.p, r)
        )
    return y.reshape(*lead, n_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Construction
# --------------------------------------------------------------------------
def tile_construct(
    w: jax.Array,
    spec: TileSpec,
    a: Optional[jax.Array] = None,
    *,
    use_pallas: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Master weight(s) -> (packed tile int32, alpha (n_alpha,))."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    src = a if spec.alpha_source == "A" else None
    if not use_pallas:
        t = tile_vector(w, spec)
        alpha = compute_alpha(w if src is None else src, spec)
        return pack_bits(t), alpha.astype(jnp.float32)

    w2d = _pad_to(w.reshape(spec.p, spec.q), 1, 32)
    a2d = None if src is None else _pad_to(src.reshape(spec.p, spec.q), 1, 32)
    q_pad = w2d.shape[1]
    # pick a block that divides the padded q
    block_q = min(4096, q_pad)
    while q_pad % block_q:
        block_q -= 32
    packed, alpha_t = tile_construct_pallas(w2d, a2d, block_q=block_q)
    alpha_t = alpha_t * (q_pad / spec.q)  # kernel divides by padded q
    n_words = (spec.q + 31) // 32
    packed = packed[:n_words]
    if spec.alpha_mode == "layer":
        alpha = jnp.mean(alpha_t, keepdims=True)
    else:
        alpha = alpha_t
    return packed, alpha.astype(jnp.float32)


# --------------------------------------------------------------------------
# Fused training forward (custom VJP)
# --------------------------------------------------------------------------
def _train_ref_forward(x, w, a, spec: TileSpec):
    """Paper-faithful reference: materialize B_hat, dense matmul."""
    bhat = tiled_weight(w, spec, a=a, dtype=x.dtype)
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    return jnp.einsum("...k,ok->...o", x, bhat.reshape(n_out, n_in))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def tbn_dense_train(x, w, a, spec: TileSpec):
    """Training forward via the fused kernels; gradient == reference VJP.

    ``a`` may equal ``w`` (alpha_source == "W"); pass the same array.
    """
    packed, alpha = tile_construct(w, spec, a=a)
    return tiled_dense_infer(x, packed, alpha, spec).astype(x.dtype)


def _tbn_dense_train_fwd(x, w, a, spec):
    y = tbn_dense_train(x, w, a, spec)
    return y, (x, w, a)


def _tbn_dense_train_bwd(spec, res, g):
    x, w, a = res
    # Backward is the exact VJP of the paper-faithful reference forward —
    # recomputes B_hat once (remat) instead of storing it.
    _, vjp = jax.vjp(lambda x, w, a: _train_ref_forward(x, w, a, spec), x, w, a)
    return vjp(g)


tbn_dense_train.defvjp(_tbn_dense_train_fwd, _tbn_dense_train_bwd)

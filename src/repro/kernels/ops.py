"""Jit-ready wrappers around the TBN Pallas kernels.

Public entry points:
  * ``tiled_dense_infer``  — serving-time FC layer from (packed tile, alpha)
    without materializing the dense weight. Pallas on TPU; pure-JAX
    structured math elsewhere (identical FLOPs — used by the SPMD dry-run).
    Small batches (m <= MATVEC_MAX_M, i.e. decode ticks) dispatch to the
    decode-blocked ``tiled_matvec_unique`` kernel instead of the 128-row
    matmul blocking. Under an active mesh whose rules map ``tile_rows`` to
    a >1 axis (distributed/sharding.py) the row-packed tile is
    tensor-parallel: a shard_map runs the same kernel per shard on r/TP
    unique rows (the decode dispatch applies per shard too) and the
    output stays sharded on the tile-row axis (DESIGN.md §5).
  * ``tiled_conv_infer``   — serving-time Conv2D from a conv-layout packed
    tile: fused im2col + tile-reuse matmul on TPU (the dense OIHW weight
    never exists); elsewhere the structured fallback runs the p-fold
    smaller tile bank through ``conv_general_dilated``. Same shard_map
    tensor-parallel path over the tile's unique filters.
  * ``tile_construct``     — (W[,A]) -> (packed tile, alpha) fused on TPU.
  * ``tbn_dense_train``    — training forward y = x @ B_hat^T that composes
    the two kernels (B_hat never hits HBM) with a custom VJP whose backward
    is the *paper-faithful* gradient (vjp of the pure-JAX reference), so the
    fused path is a drop-in for the reference during training.

Tile layouts accepted by ``tiled_dense_infer``:
  * flat  (ceil(q/32),) int32 — legacy/fused-train form; requires 32 | n_in
    on the Pallas path (enforced — ``FlatTileLayoutError``) and never
    engages tensor parallelism.
  * rows  (r, ceil(n_in/32)) int32 — the shipped serve form: one packed
    word-padded row per unique weight row, shardable on its leading axis.

Compute paths (``tiled_dense_infer(compute_path=...)``): "float" is the
byte-parity reference (unpack to ±1, MXU float MACs). "xnor" and "int8"
quantize the activations and accumulate in the INTEGER domain directly
against the packed tile words (kernels/tiled_xnor.py) — they engage at
decode m (<= MATVEC_MAX_M, per shard under tensor parallelism) on the
row-packed form; larger batches (prefill) fall back to the float path so
the MXU-fed matmul blocking keeps serving chunked prefill.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.packing import pack_bits, unpack_bits, unpack_conv_tile
from repro.core.tiling import (
    TileSpec,
    compute_alpha,
    plan_conv_tiling,
    tile_vector,
    tiled_matmul_reference,
    tiled_weight,
)
from repro.distributed.sharding import batch_shard_axes, tile_sharding
from repro.kernels.tile_construct import tile_construct_pallas
from repro.kernels.tiled_conv import tiled_conv_unique
from repro.kernels.tiled_matmul import tiled_matmul_unique
from repro.kernels.tiled_matvec import (
    DECODE_BLOCK_K,
    DECODE_BLOCK_R,
    MATVEC_MAX_M,
    sublane_rounded,
    tiled_matvec_unique,
)
from repro.kernels.tiled_xnor import (
    COMPUTE_PATHS,
    INT8_BLOCK_K,
    INT8_BLOCK_R,
    XNOR_BLOCK_R,
    XNOR_BLOCK_W,
    int8_matvec_packed,
    quantize_int8,
    quantize_sign,
    tiled_int8_matvec_unique,
    tiled_xnor_matvec_unique,
    xnor_matvec_words,
)


class FlatTileLayoutError(ValueError):
    """Flat-form packed tile fed to a path that needs whole packed rows.

    The flat (ceil(q/32),) layout packs the tile as ONE bit stream; the
    row-packed Pallas kernels index it as (r, n_in/32) words, which is
    only the same bits when 32 | n_in. Raised instead of letting
    ``reshape`` fail with an opaque size mismatch (or worse, silently
    mis-slice rows on a future refactor)."""


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# --------------------------------------------------------------------------
# Inference matmul
# --------------------------------------------------------------------------
def _dense_unique_int_local(
    xm: jax.Array,
    packed_rows: jax.Array,
    *,
    n_in: int,
    use_pallas: bool,
    compute_path: str,
) -> jax.Array:
    """Integer-domain u = Q(x) . T^T against a row-packed tile slice.

    Quantizes the activation rows (sign-binarize for "xnor", per-row
    symmetric int8 for "int8"), runs the integer kernel (Pallas) or its
    packed-word structured twin (pure jnp — non-TPU backends stay in the
    integer domain too), and rescales: ``u = scale * acc``. The int32
    accumulator is bit-identical between the two backends and the ref.py
    oracles, so dispatch parity is exact, not approximate. Runs
    unchanged per shard under the tensor-parallel wrapper (rows shard on
    r; every shard sees full activation rows, so per-row quantization is
    shard-invariant).
    """
    m = xm.shape[0]
    r_loc, words = packed_rows.shape
    if compute_path == "xnor":
        xq, scale = quantize_sign(xm, n_in)          # (m, words), (m, 1)
        if not use_pallas:
            acc = xnor_matvec_words(xq, packed_rows, n_in=n_in)
        else:
            bw = min(XNOR_BLOCK_W, words)
            br = min(XNOR_BLOCK_R, r_loc)
            xq_p = _pad_to(
                _pad_to(xq, 0, sublane_rounded(m, jnp.int32)), 1, bw
            )
            tm_p = _pad_to(_pad_to(packed_rows, 0, br), 1, bw)
            acc = tiled_xnor_matvec_unique(
                xq_p, tm_p, n_in=n_in, block_r=br, block_w=bw,
            )[:m, :r_loc]
    else:  # int8
        q, scale = quantize_int8(xm, n_in)           # (m, n_in), (m, 1)
        if not use_pallas:
            acc = int8_matvec_packed(q, packed_rows, n_in=n_in)
        else:
            bk = min(INT8_BLOCK_K, words * 32)
            br = min(INT8_BLOCK_R, r_loc)
            q_p = jnp.pad(q, ((0, 0), (0, words * 32 - n_in)))
            q_p = _pad_to(
                _pad_to(q_p, 0, sublane_rounded(m, jnp.int8)), 1, bk
            )
            tm_p = _pad_to(_pad_to(packed_rows, 0, br), 1, bk // 32)
            acc = tiled_int8_matvec_unique(
                q_p, tm_p, r=tm_p.shape[0], block_r=br, block_k=bk,
            )[:m, :r_loc]
    return scale * acc.astype(jnp.float32)


def _dense_unique_local(
    xm: jax.Array,
    packed_rows: jax.Array,
    *,
    n_in: int,
    use_pallas: bool,
    block_m: int,
    block_r: int,
    block_k: int,
    compute_path: str = "float",
) -> jax.Array:
    """u = x @ T^T against a row-packed tile slice.

    xm (m, n_in); packed_rows (r_loc, words) int32 with words*32 >= n_in
    (rows pad to whole words: pad bits unpack to -1 but only ever multiply
    zero-padded activation columns). Runs unchanged per shard under the
    tensor-parallel wrapper — r_loc is then r/TP.

    ``compute_path`` "xnor"/"int8" routes decode-sized batches
    (m <= MATVEC_MAX_M) to the integer-domain kernels; bigger batches
    keep the float matmul blocking (prefill stays MXU-fed).
    """
    m = xm.shape[0]
    r_loc, words = packed_rows.shape
    if compute_path != "float" and m <= MATVEC_MAX_M:
        return _dense_unique_int_local(
            xm, packed_rows, n_in=n_in, use_pallas=use_pallas,
            compute_path=compute_path,
        )
    if not use_pallas:
        tm = unpack_bits(packed_rows, n_in, dtype=xm.dtype)  # (r_loc, n_in)
        return jnp.einsum("mk,rk->mr", xm, tm)
    xp = jnp.pad(xm, ((0, 0), (0, words * 32 - n_in)))
    if m <= MATVEC_MAX_M:
        # Decode fast path: m is the whole (sublane-rounded) batch, so the
        # matmul kernel's 128-row m blocks would be mostly zero padding.
        # The matvec variant takes the batch as ONE m block and widens the
        # r/k blocking to keep the unpack-dominant regime fed.
        br = min(DECODE_BLOCK_R, r_loc)
        bk = min(DECODE_BLOCK_K, words * 32)
        xp = _pad_to(_pad_to(xp, 0, sublane_rounded(m, xp.dtype)), 1, bk)
        tm_p = _pad_to(_pad_to(packed_rows, 0, br), 1, bk // 32)
        return tiled_matvec_unique(
            xp, tm_p, r=tm_p.shape[0], block_r=br, block_k=bk,
        )[:m, :r_loc]
    xp = _pad_to(_pad_to(xp, 0, block_m), 1, block_k)
    tm_p = _pad_to(_pad_to(packed_rows, 0, block_r), 1, block_k // 32)
    return tiled_matmul_unique(
        xp,
        tm_p,
        r=tm_p.shape[0],
        block_m=block_m,
        block_r=block_r,
        block_k=block_k,
    )[:m, :r_loc]


def _replicate_dense_out(u: jax.Array, alpha: jax.Array, spec: TileSpec):
    """u (m, r_loc) -> y (m, p, r_loc): the tile-replica broadcast-scale."""
    m, r_loc = u.shape
    alpha = alpha.astype(u.dtype)
    if spec.alpha_mode == "layer":
        return jnp.broadcast_to(u[:, None, :], (m, spec.p, r_loc)) \
            * alpha.reshape(1)
    return jnp.broadcast_to(u[:, None, :] * alpha[None, :, None],
                            (m, spec.p, r_loc))


def tiled_dense_infer(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    spec: TileSpec,
    *,
    use_pallas: Optional[bool] = None,
    block_m: int = 128,
    block_r: int = 128,
    block_k: int = 512,
    compute_path: str = "float",
) -> jax.Array:
    """y = x @ W_hat^T from the shipped representation.

    x: (..., n_in); packed: int32, flat (ceil(q/32),) or row-packed
    (r, ceil(n_in/32)) — see module docstring; alpha: (n_alpha,).
    Weight logical shape spec.shape == (n_out, n_in), aligned tiling.

    Row-packed tiles are tensor-parallel under an active mesh: the tile
    rows shard over the ``tile_rows`` axis, each shard runs the same
    kernel on r/TP rows, and the (m, p, r) output stays sharded on its
    unique-row axis until the caller's reshape (DESIGN.md §5).

    ``compute_path`` (see module docstring): "float" (default, byte-
    parity reference) | "int8" | "xnor". The integer paths quantize the
    activations, so outputs are approximate w.r.t. the float path — the
    exactness contract moves to the integer accumulator (bit-identical
    to the ref.py oracles). They apply at decode m on row-packed (or
    Pallas-reshaped flat) tiles; elsewhere the call silently keeps the
    float path rather than failing mid-model.
    """
    if compute_path not in COMPUTE_PATHS:
        raise ValueError(
            f"unknown compute_path {compute_path!r}: expected one of "
            f"{COMPUTE_PATHS}"
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    r = spec.rows_per_tile
    lead = x.shape[:-1]
    xm = x.reshape(-1, n_in)
    m = xm.shape[0]

    row_form = packed.ndim == 2
    if not row_form:
        if not use_pallas:
            t = unpack_bits(packed, spec.q, dtype=x.dtype)
            y = tiled_matmul_reference(xm, t, alpha, spec)
            return y.reshape(*lead, n_out).astype(x.dtype)
        if n_in % 32:
            raise FlatTileLayoutError(
                f"flat-form packed tile cannot be viewed as packed rows: "
                f"n_in={n_in} is not a multiple of 32 (spec.shape="
                f"{spec.shape}), so row boundaries fall mid-word. Ship "
                f"the row-packed (r, ceil(n_in/32)) serve form (each row "
                f"padded to whole words) for the Pallas path."
            )
        packed = packed.reshape(r, n_in // 32)

    local = functools.partial(
        _dense_unique_local, n_in=n_in, use_pallas=use_pallas,
        block_m=block_m, block_r=block_r, block_k=block_k,
        compute_path=compute_path,
    )
    tp = tile_sharding(r) if row_form else None
    if tp is not None:
        mesh, ax, _ = tp
        m_ax = batch_shard_axes(ax, m) or None
        y3 = shard_map(
            lambda xl, pl_, al: _replicate_dense_out(local(xl, pl_), al, spec),
            mesh=mesh,
            in_specs=(P(m_ax, None), P(ax, None), P()),
            out_specs=P(m_ax, None, ax),
            check_vma=False,
        )(xm, packed, alpha)
    else:
        y3 = _replicate_dense_out(local(xm, packed), alpha, spec)
    return y3.reshape(*lead, n_out).astype(x.dtype)


# --------------------------------------------------------------------------
# Inference conv
# --------------------------------------------------------------------------
Padding = Union[str, Sequence[Tuple[int, int]]]


def _conv_spatial(size: int, k: int, s: int, pad) -> Tuple[int, int, int]:
    """(out_size, pad_lo, pad_hi) with conv_general_dilated semantics."""
    if pad in ("SAME", "SAME_LOWER"):
        out = -(-size // s)
        total = max((out - 1) * s + k - size, 0)
        half = total // 2
        lo = half if pad == "SAME" else total - half
        return out, lo, total - lo
    if pad == "VALID":
        lo = hi = 0
    elif isinstance(pad, str):
        raise ValueError(f"unsupported padding {pad!r} for tiled conv")
    else:
        lo, hi = pad
    return (size + lo + hi - k) // s + 1, lo, hi


def resolve_conv_padding(
    hw: Tuple[int, int], kernel: Tuple[int, int], stride: Tuple[int, int],
    padding: Padding,
) -> Tuple[Tuple[int, int], Tuple[Tuple[int, int], Tuple[int, int]]]:
    """-> ((OH, OW), explicit ((lo_h, hi_h), (lo_w, hi_w)))."""
    pads = (padding, padding) if isinstance(padding, str) else tuple(padding)
    oh, lo_h, hi_h = _conv_spatial(hw[0], kernel[0], stride[0], pads[0])
    ow, lo_w, hi_w = _conv_spatial(hw[1], kernel[1], stride[1], pads[1])
    return (oh, ow), ((lo_h, hi_h), (lo_w, hi_w))


def _replicate_conv_out(u, alpha, spec: TileSpec):
    """u (N, OH, OW, r_loc) -> y (N, OH, OW, p, r_loc), replica-major.

    Kept unflattened so the tensor-parallel wrapper can declare the
    unique-filter axis sharded; callers reshape to (N, OH, OW, p*r)."""
    n, oh, ow, r_loc = u.shape
    alpha = alpha.astype(u.dtype)
    if spec.alpha_mode == "layer":
        return jnp.broadcast_to(u[..., None, :], (n, oh, ow, spec.p, r_loc)) \
            * alpha.reshape(1)
    return jnp.broadcast_to(
        u[..., None, :] * alpha[None, None, None, :, None],
        (n, oh, ow, spec.p, r_loc),
    )


def tiled_conv_infer(
    x: jax.Array,
    packed: jax.Array,
    alpha: jax.Array,
    spec: TileSpec,
    *,
    stride: Tuple[int, int] = (1, 1),
    padding: Padding = "SAME",
    use_pallas: Optional[bool] = None,
    block_r: int = 128,
) -> jax.Array:
    """y = conv(x, W_hat) from the shipped conv representation.

    x: (N, H, W, C) NHWC; packed: (kh*kw, r, ceil(C/32)) int32 conv-layout
    tile (repro.core.packing.pack_conv_tile); alpha: (n_alpha,). The weight
    logical shape spec.shape == (c_out, C, kh, kw) with p | c_out.

    The dense weight is never materialized on either path: the conv runs
    against the r = c_out/p unique filters of the tile and the p replicas
    are a broadcast-scale on the output channels (exact conv analogue of
    ``tiled_matmul_reference`` — validated against
    ``kernels.ref.tiled_conv_ref``).
    """
    plan = plan_conv_tiling(spec)
    if plan is None:
        raise ValueError(f"spec {spec.shape} has no aligned conv tiling")
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    kh, kw = plan.kernel
    sh, sw = stride
    n, h, w, c = x.shape
    assert c == plan.c_in, (c, plan.c_in)
    r = plan.r
    (oh, ow), pads = resolve_conv_padding((h, w), (kh, kw), stride, padding)

    if use_pallas:
        # Pallas path: pad spatially so every kernel read is in bounds
        # (Hp >= (OH-1)*sh + kh, Wp >= kw-1 + OW*sw), channels to whole
        # int32 lanes (zero activations x any tile bit contribute nothing);
        # the filter axis pads to block_r multiples per shard below.
        hp = max(h + pads[0][0] + pads[0][1], (oh - 1) * sh + kh)
        wp = max(w + pads[1][0] + pads[1][1], (kw - 1) + ow * sw)
        cpad = (-c) % 32
        xin = jnp.pad(
            x,
            (
                (0, 0),
                (pads[0][0], hp - h - pads[0][0]),
                (pads[1][0], wp - w - pads[1][0]),
                (0, cpad),
            ),
        )
    else:
        xin = x

    def local_u(x_l, packed_l):
        """u = conv(x, T_loc) against a conv-layout tile slice of r_loc
        unique filters (r_loc = r/TP under the tensor-parallel wrapper)."""
        r_loc = packed_l.shape[1]
        if not use_pallas:
            bank = unpack_conv_tile(packed_l, r_loc, c, kh, kw, dtype=x.dtype)
            return jax.lax.conv_general_dilated(
                x_l, bank, window_strides=stride, padding=pads,
                dimension_numbers=("NHWC", "OIHW", "NHWC"),
            )
        br = min(block_r, r_loc)
        packed_p = jnp.pad(packed_l, ((0, 0), (0, (-r_loc) % br), (0, 0)))
        return tiled_conv_unique(
            x_l,
            packed_p,
            kernel=(kh, kw),
            stride=stride,
            out_hw=(oh, ow),
            block_r=br,
        )[..., :r_loc]

    tp = tile_sharding(r)
    if tp is not None:
        mesh, ax, _ = tp
        n_ax = batch_shard_axes(ax, n) or None
        y5 = shard_map(
            lambda xl, pl_, al: _replicate_conv_out(local_u(xl, pl_), al, spec),
            mesh=mesh,
            in_specs=(P(n_ax, None, None, None), P(None, ax, None), P()),
            out_specs=P(n_ax, None, None, None, ax),
            check_vma=False,
        )(xin, packed, alpha)
    else:
        y5 = _replicate_conv_out(local_u(xin, packed), alpha, spec)
    return y5.reshape(n, oh, ow, spec.p * r).astype(x.dtype)


# --------------------------------------------------------------------------
# Construction
# --------------------------------------------------------------------------
def tile_construct(
    w: jax.Array,
    spec: TileSpec,
    a: Optional[jax.Array] = None,
    *,
    use_pallas: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Master weight(s) -> (packed tile int32, alpha (n_alpha,))."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    src = a if spec.alpha_source == "A" else None
    if not use_pallas:
        t = tile_vector(w, spec)
        alpha = compute_alpha(w if src is None else src, spec)
        return pack_bits(t), alpha.astype(jnp.float32)

    w2d = _pad_to(w.reshape(spec.p, spec.q), 1, 32)
    a2d = None if src is None else _pad_to(src.reshape(spec.p, spec.q), 1, 32)
    q_pad = w2d.shape[1]
    # pick a block that divides the padded q
    block_q = min(4096, q_pad)
    while q_pad % block_q:
        block_q -= 32
    packed, alpha_t = tile_construct_pallas(w2d, a2d, block_q=block_q)
    alpha_t = alpha_t * (q_pad / spec.q)  # kernel divides by padded q
    n_words = (spec.q + 31) // 32
    packed = packed[:n_words]
    if spec.alpha_mode == "layer":
        alpha = jnp.mean(alpha_t, keepdims=True)
    else:
        alpha = alpha_t
    return packed, alpha.astype(jnp.float32)


# --------------------------------------------------------------------------
# Fused training forward (custom VJP)
# --------------------------------------------------------------------------
def _train_ref_forward(x, w, a, spec: TileSpec):
    """Paper-faithful reference: materialize B_hat, dense matmul."""
    bhat = tiled_weight(w, spec, a=a, dtype=x.dtype)
    n_out, n_in = spec.shape[0], spec.n // spec.shape[0]
    return jnp.einsum("...k,ok->...o", x, bhat.reshape(n_out, n_in))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def tbn_dense_train(x, w, a, spec: TileSpec):
    """Training forward via the fused kernels; gradient == reference VJP.

    ``a`` may equal ``w`` (alpha_source == "W"); pass the same array.
    """
    packed, alpha = tile_construct(w, spec, a=a)
    return tiled_dense_infer(x, packed, alpha, spec).astype(x.dtype)


def _tbn_dense_train_fwd(x, w, a, spec):
    y = tbn_dense_train(x, w, a, spec)
    return y, (x, w, a)


def _tbn_dense_train_bwd(spec, res, g):
    x, w, a = res
    # Backward is the exact VJP of the paper-faithful reference forward —
    # recomputes B_hat once (remat) instead of storing it.
    _, vjp = jax.vjp(lambda x, w, a: _train_ref_forward(x, w, a, spec), x, w, a)
    return vjp(g)


tbn_dense_train.defvjp(_tbn_dense_train_fwd, _tbn_dense_train_bwd)

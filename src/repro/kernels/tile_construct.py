"""Pallas TPU kernel: fused training-time tile construction.

Training forward needs (t, alpha) from the master weight every step. The
naive path materializes the binarized full tensor B_hat (N elements) in HBM;
this kernel fuses reshape -> column-sum over the p replicas -> sign ->
bit-pack (+ per-tile |.|_1 for alpha) in one pass over W, so only q bits +
p floats ever leave the core. Beyond-paper training-memory optimization
(DESIGN.md §2).

Layout: the wrapper passes W already reshaped (p, q). Grid over q blocks;
each step loads a (p, bq) strip of W (and optionally of the alpha source A),
reduces over the replica axis, packs bq/32 int32 words, and accumulates the
per-tile |.|_1 partial sums into a (1, p) accumulator output.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

LANE_BITS = 32


def _construct_kernel(w_ref, a_ref, packed_ref, alpha_ref, *, bq: int):
    qi = pl.program_id(0)

    w = w_ref[...]  # (p, bq)
    s = jnp.sum(w.astype(jnp.float32), axis=0)  # (bq,)
    bits = (s > 0).astype(jnp.uint32)
    words = bits.reshape(bq // LANE_BITS, LANE_BITS)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, words.shape, 1)
    packed = jnp.sum(words << shifts, axis=1, dtype=jnp.uint32)
    packed_ref[0, :] = packed.astype(jnp.int32)

    @pl.when(qi == 0)
    def _init():
        alpha_ref[...] = jnp.zeros_like(alpha_ref)

    partial_l1 = jnp.sum(jnp.abs(a_ref[...].astype(jnp.float32)), axis=1)  # (p,)
    alpha_ref[0, :] += partial_l1


def tile_construct_pallas(
    w2d: jax.Array,
    a2d: Optional[jax.Array] = None,
    *,
    block_q: int = 4096,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(p, q) -> (packed int32 (q/32,), per-tile alpha (p,)).

    ``a2d`` is the alpha source strip (defaults to ``w2d`` — Eq. 7 family);
    q must be a multiple of 32 and of block_q (wrapper pads).
    """
    p, q = w2d.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    block_q = min(block_q, q)
    assert q % LANE_BITS == 0 and q % block_q == 0 and block_q % LANE_BITS == 0
    if a2d is None:
        a2d = w2d

    kernel = functools.partial(_construct_kernel, bq=block_q)
    packed, alpha_acc = pl.pallas_call(
        kernel,
        grid=(q // block_q,),
        in_specs=[
            pl.BlockSpec((p, block_q), lambda qi: (0, qi)),
            pl.BlockSpec((p, block_q), lambda qi: (0, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q // LANE_BITS), lambda qi: (0, qi)),
            pl.BlockSpec((1, p), lambda qi: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, q // LANE_BITS), jnp.int32),
            jax.ShapeDtypeStruct((1, p), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(w2d, a2d)
    return packed[0], alpha_acc[0] / q
